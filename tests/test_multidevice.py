"""Multi-device behaviour under 8 forced host devices (subprocess: the
device count must be set before jax initializes, and the main test
process keeps the real 1-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(body: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_ring_allreduce_and_compression():
    pytest.importorskip("repro.dist", reason="repro.dist not implemented")
    out = run_script("""
        import jax, numpy as np
        import repro
        from jax.sharding import PartitionSpec as P
        from repro.dist.overlap import ring_all_reduce
        from repro.dist.compression import compressed_psum_leaf
        mesh = jax.make_mesh((8,), ('data',))
        x = np.random.default_rng(0).standard_normal((8, 32)).astype('float32')
        f = jax.shard_map(lambda a: ring_all_reduce(a, 'data'), mesh=mesh,
                          in_specs=P('data'), out_specs=P('data'),
                          check_vma=False)
        out = np.asarray(f(x))
        assert np.allclose(out, np.tile(x.sum(0), (8, 1)), atol=1e-5)
        g = jax.shard_map(lambda a, e: compressed_psum_leaf(a, e, 'data'),
                          mesh=mesh, in_specs=(P('data'), P('data')),
                          out_specs=(P('data'), P('data')), check_vma=False)
        r, err = g(x, np.zeros_like(x))
        scale = np.abs(x).max() / 127
        assert np.allclose(np.asarray(r), np.tile(x.mean(0), (8, 1)),
                           atol=scale * 2)
        # error feedback: second round recovers quantization residue
        r2, _ = g(np.zeros_like(x), err)
        approx = np.asarray(r) + np.asarray(r2)
        assert (np.abs(approx - np.tile(x.mean(0), (8, 1))).max()
                < np.abs(np.asarray(r) - np.tile(x.mean(0), (8, 1))).max()
                + 1e-6)
        print('OK')
    """)
    assert "OK" in out


def test_spmd_join_step_matches_local():
    pytest.importorskip("repro.dist", reason="repro.dist not implemented")
    out = run_script("""
        import jax, numpy as np, jax.numpy as jnp
        import repro
        from repro.core import GraphDB, get_query, VLFTJ
        from repro.dist.sharded_join import spmd_join_step, spmd_spmv_step
        from repro.graphs import powerlaw_cluster
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        g = powerlaw_cluster(256, 4, seed=0)
        gdb = GraphDB(g, {})
        # one triangle expansion level: frontier = sorted edge pairs (a<b)
        ea = g.edge_array()
        fr = ea[ea[:, 0] < ea[:, 1]].astype(np.int32)
        pad = (-len(fr)) % 8
        fr = np.pad(fr, ((0, pad), (0, 0)))
        mult = np.ones(len(fr), np.int64); mult[len(fr)-pad:] = 0
        kw = dict(probe_cols=(0, 1), n_unary=0, lower_cols=(1,),
                  upper_cols=(), width=128, n_iter=gdb.bsearch_iters,
                  needs_degree=False)
        step = spmd_join_step(mesh, kw)
        total = int(step(gdb.dev('indptr'), gdb.dev('indices'),
                         jnp.asarray(fr), jnp.asarray(mult)))
        ref = VLFTJ(get_query('3-clique'), gdb).count()
        assert total == ref, (total, ref)
        # edge-sharded SpMV == scatter oracle (edges trimmed to the
        # shard boundary; production pads, see configs/wcoj.py)
        e8 = (g.n_edges // 8) * 8
        idx = np.asarray(gdb.dev('indices'))[:e8]
        sid = np.asarray(gdb.dev('src_ids'))[:e8]
        spmv = spmd_spmv_step(mesh, g.n_nodes)
        c = np.arange(g.n_nodes, dtype=np.int64)
        y = np.asarray(spmv(jnp.asarray(idx), jnp.asarray(sid),
                            jnp.asarray(c)))
        oracle = np.zeros(g.n_nodes, np.int64)
        np.add.at(oracle, sid, c[idx])
        assert np.array_equal(y, oracle)
        print('OK', total)
    """)
    assert "OK" in out


def test_sharded_train_step_and_elastic_restore():
    out = run_script("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        import repro
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.transformer import (TransformerConfig,
                                              init_params, loss_fn,
                                              param_specs)
        from repro.train.loop import make_train_step
        from repro.train.optimizer import OptimizerConfig, init_opt_state
        from repro.train.checkpoint import CheckpointManager
        cfg = TransformerConfig(name='t', n_layers=2, d_model=64,
                                n_heads=4, n_kv_heads=2, d_ff=128,
                                vocab_size=256, dtype=jnp.float32,
                                remat=False)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        p = init_params(jax.random.PRNGKey(0), cfg)
        specs = param_specs(cfg)
        shard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
        p = jax.device_put(p, shard)
        opt = init_opt_state(p)
        step = jax.jit(make_train_step(
            lambda pp, b: loss_fn(pp, b, cfg, mesh), OptimizerConfig()))
        toks = np.random.default_rng(0).integers(0, 256, (4, 16),
                                                 dtype=np.int32)
        batch = {'tokens': toks, 'labels': toks}
        p2, opt2, m = step(p, opt, batch)
        assert np.isfinite(float(m['loss']))
        # save sharded, restore under a DIFFERENT mesh (elastic)
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, {'params': p2}, blocking=True)
            mesh2 = jax.make_mesh((4, 2), ('data', 'model'))
            shard2 = jax.tree.map(lambda s: NamedSharding(mesh2, s),
                                  specs,
                                  is_leaf=lambda x: isinstance(x, P))
            like = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), p2)
            r = cm.restore(1, {'params': like},
                           shardings={'params': shard2})
            for a, b in zip(jax.tree.leaves(p2),
                            jax.tree.leaves(r['params'])):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
        print('OK', float(m['loss']))
    """)
    assert "OK" in out


def test_moe_shard_map_matches_local():
    out = run_script("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        import repro
        from repro.layers.moe import MoEConfig, init_moe_params, moe_ffn
        from repro.models.transformer import _moe_ffn_local
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                        capacity_factor=8.0)
        params = init_moe_params(jax.random.PRNGKey(0), 64, cfg, 1)
        lp = jax.tree.map(lambda a: a[0], params)
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((8, 16, 64)), jnp.float32)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        y_dist, aux_d = moe_ffn(x, lp, cfg, mesh, dtype=jnp.float32)
        # local oracle
        mcfg = dataclasses.replace(cfg)
        class FakeCfg:  # minimal cfg shim for the local helper
            moe = cfg; act = 'silu'; dtype = jnp.float32
        y_loc, aux_l = _moe_ffn_local(x, lp, FakeCfg)
        # distributed capacity differs (per-shard) but with huge
        # capacity_factor nothing drops -> results match
        np.testing.assert_allclose(np.asarray(y_dist), np.asarray(y_loc),
                                   atol=2e-4, rtol=2e-4)
        print('OK')
    """)
    assert "OK" in out
