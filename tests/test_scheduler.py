"""Preemptive multi-tenant scheduling (repro.serve.scheduler).

The contract under test: quantum-sliced execution is *lossless* —
suspend/resume at GAO level boundaries yields exactly the counts and
rows of uninterrupted execution on every tier-1 query shape — and
*deterministic* — the rows-expanded meter, preemption points, and
virtual-clock completion times are identical across runs.  On top of
that: round-robin fairness bounds small-query completion under a
concurrent heavy enumeration, per-tenant quotas reject 429-style, and
parked snapshots share the cursor registry's eviction/restart
semantics (an evicted job restarts, never duplicates, never fails).
"""
import numpy as np
import pytest

from repro.core import VLFTJ, count, get_query
from repro.core import engine as engine_mod
from repro.graphs import powerlaw_cluster
from repro.serve import (AdmissionError, PlanSnapshot, Preempted,
                         QuantumBudget, QuantumScheduler, QueryRequest,
                         QueryServer, TenantQuota)

TIER1_SHAPES = ["3-clique", "4-clique", "4-cycle", "3-path",
                "2-lollipop", "3-lollipop"]


@pytest.fixture(scope="module")
def csr():
    return powerlaw_cluster(n=300, m_per_node=4, seed=0)


@pytest.fixture()
def server(csr):
    return QueryServer(csr, page_rows=256)


def _direct_gdb(server):
    return server._gdb_for(server.default_selectivity, 0)


# ---------------------------------------------------------------------------
# suspend/resume parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", TIER1_SHAPES)
def test_count_parity_under_preemption(server, shape):
    """A tiny quantum forces many suspensions; the count must equal the
    uninterrupted engine count row-for-row (weighted)."""
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest(shape, engine="vlftj"))
    (res,) = sched.run()
    ref = count(get_query(shape), _direct_gdb(server), engine="vlftj")
    assert res.count == ref
    assert res.stats["quanta"] >= 1
    assert res.stats["rows_expanded"] > 0


@pytest.mark.parametrize("shape", TIER1_SHAPES)
def test_rows_parity_under_preemption(server, shape):
    """Enumeration through the scheduler must deliver exactly the rows
    of uninterrupted enumeration, in the same (GAO-lex) order."""
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest(shape, engine="vlftj", limit=10**9))
    (res,) = sched.run()
    direct = engine_mod.enumerate(get_query(shape), _direct_gdb(server),
                                  plan=res.plan, order=res.row_vars)
    assert res.next_cursor is None          # limit covered everything
    assert res.count == direct.count()
    assert np.array_equal(res.rows, direct.rows)


def test_preemption_actually_happens(server):
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest("3-path", engine="vlftj", limit=10**9))
    (res,) = sched.run()
    assert res.stats["preemptions"] > 0
    assert res.stats["quanta"] == res.stats["preemptions"] + 1


def test_limit_completes_early_and_hands_back_cursor(server):
    sched = QuantumScheduler(server, quantum_rows=10**9)
    sched.submit(QueryRequest("3-path", engine="vlftj", limit=100))
    (res,) = sched.run()
    assert res.count == 100 and res.rows.shape == (100, 4)
    assert res.next_cursor is not None
    cont = server.execute(QueryRequest("3-path", limit=10**9,
                                       cursor=res.next_cursor))
    direct = engine_mod.enumerate(get_query("3-path"), _direct_gdb(server),
                                  plan=res.plan, order=res.row_vars)
    assert np.array_equal(np.concatenate([res.rows, cont.rows]),
                          direct.rows)


# ---------------------------------------------------------------------------
# the serializable snapshot contract
# ---------------------------------------------------------------------------

def test_snapshot_bytes_roundtrip():
    snap = PlanSnapshot("3-path", ("v1", "v2"),
                        np.arange(8, dtype=np.int32).reshape(4, 2),
                        np.ones(4, dtype=np.int64), phase="final",
                        offset=2, partial_total=17, rows_emitted=5)
    back = PlanSnapshot.from_bytes(snap.to_bytes())
    assert back.query_name == "3-path" and back.gao == ("v1", "v2")
    assert back.phase == "final" and back.offset == 2
    assert back.partial_total == 17 and back.rows_emitted == 5
    assert np.array_equal(back.frontier, snap.frontier)
    assert np.array_equal(back.mult, snap.mult)
    assert back.start_level == 2
    assert back.nbytes == snap.nbytes


@pytest.mark.parametrize("shape", ["3-path", "3-lollipop"])
def test_resume_count_from_serialized_snapshot(server, shape):
    """Preempt mid-frontier, serialize, restore, resume on a *fresh*
    executor: the resumed count equals the uninterrupted count."""
    gdb = _direct_gdb(server)
    q = get_query(shape)
    plan, _ = server._plan_for(QueryRequest(shape, engine="vlftj"), gdb)
    budget = QuantumBudget(32, shape, plan.gao)
    ex = VLFTJ(q, gdb, plan=plan.with_level_callback(budget))
    with pytest.raises(Preempted) as ei:
        ex.count()
    wire = ei.value.snapshot.to_bytes()
    snap = PlanSnapshot.from_bytes(wire)
    fresh = VLFTJ(q, gdb, plan=plan)
    assert fresh.resume_count(snap.frontier, snap.mult) == \
        count(q, gdb, engine="vlftj")


def test_resume_rows_from_snapshot_with_skip(server):
    """The cursor half of the contract: resume from a suspended
    frontier and skip already-delivered rows — continues row-for-row."""
    from repro.results import ResultCursor
    gdb = _direct_gdb(server)
    q = get_query("3-path")
    plan, _ = server._plan_for(QueryRequest("3-path", engine="vlftj",
                                            limit=1), gdb, output="rows")
    full = VLFTJ(q, gdb, plan=plan)
    cur = ResultCursor(full, page_rows=128)
    first = cur.take(300)
    assert cur.penultimate is not None
    resumed = ResultCursor(VLFTJ(q, gdb, plan=plan), page_rows=128,
                           frontier=cur.penultimate,
                           skip_rows=cur.rows_emitted)
    rest = np.concatenate(list(resumed)) if not cur.exhausted else \
        np.zeros((0, 4), dtype=np.int64)
    direct = VLFTJ(q, gdb, plan=plan).enumerate()
    assert np.array_equal(np.concatenate([first, rest]), direct)
    assert resumed.rows_emitted == direct.shape[0]


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def _fair_workload(sched):
    # heavy: full-graph samples (selectivity=1) make the enumeration
    # dominate; smalls use the default sparse samples
    sched.submit(QueryRequest("3-path", engine="vlftj", limit=10**9,
                              selectivity=1.0), collect_rows=False)
    for i in range(4):
        sched.submit(QueryRequest("3-clique", engine="vlftj", seed=i % 2))
    return sched.run()


def test_quantum_meter_deterministic(csr):
    runs = []
    for _ in range(2):
        sched = QuantumScheduler(QueryServer(csr, page_rows=256),
                                 quantum_rows=2048)
        res = _fair_workload(sched)
        runs.append([(r.stats["rows_expanded"], r.stats["vclock_done"],
                      r.stats["quanta"], r.stats["preemptions"])
                     for r in res])
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# fairness
# ---------------------------------------------------------------------------

def test_round_robin_beats_fifo_on_small_query_completion(csr):
    """With a heavy enumeration in flight, small queries complete at a
    bounded virtual time under quantum scheduling — and far earlier
    than under FIFO, at (identically) conserved total work."""
    outcomes = {}
    for policy in ("quantum", "fifo"):
        sched = QuantumScheduler(QueryServer(csr, page_rows=256),
                                 quantum_rows=2048, policy=policy)
        res = _fair_workload(sched)
        heavy, smalls = res[0], res[1:]
        outcomes[policy] = {
            "small_done": [r.stats["vclock_done"] for r in smalls],
            "total": sum(r.stats["rows_expanded"] for r in res),
            "heavy_work": heavy.stats["rows_expanded"],
        }
    q, f = outcomes["quantum"], outcomes["fifo"]
    # work conservation: suspension repeats no expansion
    assert q["total"] == f["total"]
    # FIFO: every small finishes after the whole heavy job
    assert min(f["small_done"]) > f["heavy_work"]
    # quantum: p99 (= max here) small completion at least 5x earlier
    assert max(q["small_done"]) * 5 <= max(f["small_done"])


# ---------------------------------------------------------------------------
# quotas / admission control
# ---------------------------------------------------------------------------

def test_max_in_flight_rejects_429(server):
    sched = QuantumScheduler(
        server, quotas={"t1": TenantQuota(max_in_flight=2)})
    sched.submit(QueryRequest("3-clique", tenant="t1"))
    sched.submit(QueryRequest("3-clique", tenant="t1", seed=1))
    with pytest.raises(AdmissionError) as ei:
        sched.submit(QueryRequest("3-clique", tenant="t1", seed=2))
    assert ei.value.status == 429 and ei.value.tenant == "t1"
    # other tenants are unaffected; completion frees the slot
    sched.submit(QueryRequest("3-clique", tenant="t2"))
    sched.run()
    sched.submit(QueryRequest("3-clique", tenant="t1", seed=2))
    assert sched.stats["rejected"] == 1


def test_frontier_bytes_quota_fails_oversized_park(server):
    """A suspended frontier larger than the tenant's byte quota cannot
    park: the job fails mid-flight with a 429-style result."""
    sched = QuantumScheduler(
        server, quantum_rows=64,
        quotas={"t1": TenantQuota(max_frontier_bytes=128)})
    sched.submit(QueryRequest("3-path", engine="vlftj", tenant="t1"))
    (res,) = sched.run()
    assert res.engine == "rejected"
    assert res.stats["status"] == 429
    assert "max_frontier_bytes" in res.stats["error"]


def test_frontier_bytes_quota_evicts_oldest_parked(server):
    """Two preempting jobs of one tenant under a quota that fits only
    one parked frontier: the older parked job is evicted (reason
    'quota') and restarts, and both still finish correctly."""
    sched = QuantumScheduler(
        server, quantum_rows=64,
        quotas={"t1": TenantQuota(max_frontier_bytes=200_000)})
    sched.submit(QueryRequest("3-clique", engine="vlftj", tenant="t1"))
    sched.submit(QueryRequest("4-cycle", engine="vlftj", tenant="t1",
                              seed=1))
    res = sched.run()
    gdb0 = server._gdb_for(server.default_selectivity, 0)
    gdb1 = server._gdb_for(server.default_selectivity, 1)
    assert res[0].count == count(get_query("3-clique"), gdb0,
                                 engine="vlftj")
    assert res[1].count == count(get_query("4-cycle"), gdb1,
                                 engine="vlftj")
    if sched.stats["parked_evictions"]:
        assert server.cursor_info()["closed"].get("quota", 0) > 0


# ---------------------------------------------------------------------------
# registry eviction / restart semantics
# ---------------------------------------------------------------------------

def test_evicted_snapshot_restarts_correctly(csr):
    server = QueryServer(csr, page_rows=256, max_open_cursors=2)
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest("3-path", engine="vlftj"))
    assert sched.step()                    # preempts; snapshot parked
    assert "sched-1" in server._cursors
    # pagination traffic floods the LRU registry past its cap
    for s in range(3):
        server.execute(QueryRequest("3-clique", engine="vlftj", limit=1,
                                    seed=s))
    assert "sched-1" not in server._cursors
    while sched.step():
        pass
    (res,) = [j.result for j in sched._jobs]
    assert res.stats["restarts"] >= 1
    assert res.count == count(get_query("3-path"), _direct_gdb(server),
                              engine="vlftj")


def test_evicted_rows_job_never_duplicates(csr):
    server = QueryServer(csr, page_rows=256, max_open_cursors=2)
    sched = QuantumScheduler(server, quantum_rows=300)
    sched.submit(QueryRequest("3-path", engine="vlftj", limit=10**9))
    job = sched._jobs[0]
    while job.rows_collected == 0 and job.result is None:
        assert sched.step()                 # until pages collected + parked
    assert job.result is None               # still mid-flight
    for s in range(3):
        server.execute(QueryRequest("3-clique", engine="vlftj", limit=1,
                                    seed=s))
    while sched.step():
        pass
    (res,) = [j.result for j in sched._jobs]
    direct = engine_mod.enumerate(get_query("3-path"),
                                  _direct_gdb(server),
                                  plan=res.plan, order=res.row_vars)
    assert res.stats["restarts"] >= 1
    assert np.array_equal(res.rows, direct.rows)


def test_mutual_eviction_terminates_via_restart_backoff(csr):
    """Registry smaller than the concurrency level: parked snapshots
    mutually evict, so every quantum used to restart from scratch —
    livelock.  Restart backoff (quantum doubles per eviction restart)
    guarantees convergence; all jobs still return exact counts."""
    server = QueryServer(csr, page_rows=256, max_open_cursors=1)
    sched = QuantumScheduler(server, quantum_rows=64)
    for s in range(3):
        sched.submit(QueryRequest("3-clique", engine="vlftj", seed=s))
    for _ in range(400):
        if not sched.step():
            break
    else:
        pytest.fail("mutual-eviction livelock: no convergence in 400 steps")
    assert sched.stats["restarts"] > 0
    for job in sched._jobs:
        gdb = server._gdb_for(server.default_selectivity, job.req.seed)
        assert job.result.count == count(get_query("3-clique"), gdb,
                                         engine="vlftj")


# ---------------------------------------------------------------------------
# non-preemptible engines, server API, stats surface
# ---------------------------------------------------------------------------

def test_opaque_engine_completes_in_one_quantum(server):
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest("3-path", engine="yannakakis"))
    (res,) = sched.run()
    assert res.count == count(get_query("3-path"), _direct_gdb(server))
    assert res.stats["quanta"] == 1 and res.stats["preemptions"] == 0


def test_execute_concurrent_positions_and_rejections(server):
    reqs = [QueryRequest("3-clique", engine="vlftj", tenant="t1"),
            QueryRequest("3-path", engine="vlftj", limit=50, tenant="t1"),
            QueryRequest("3-clique", tenant="t1", seed=1)]
    res = server.execute_concurrent(
        reqs, quantum_rows=256,
        quotas={"t1": TenantQuota(max_in_flight=2)})
    assert len(res) == 3
    assert res[0].count == count(get_query("3-clique"),
                                 _direct_gdb(server), engine="vlftj")
    assert res[1].count == 50 and res[1].rows.shape == (50, 4)
    assert res[2].engine == "rejected" and res[2].stats["status"] == 429


def test_result_stats_surface(server):
    r = server.execute(QueryRequest("3-clique"))
    assert r.stats["plan_cache"]["misses"] >= 1
    assert r.stats["cursors"] == {"open": 0, "closed": {}}
    r1 = server.execute(QueryRequest("3-path", limit=10))
    assert r1.stats["cursors"]["open"] == 1
    r2 = server.execute(QueryRequest("3-path", limit=10**9,
                                     cursor=r1.next_cursor))
    assert r2.stats["cursors"]["closed"].get("exhausted") == 1
    assert r2.stats["cursors"]["open"] == 0


def test_budget_chains_inner_callback(server):
    """The quantum budget composes with an existing level_callback
    (e.g. the dist rebalancer): the inner hook still runs and its
    frontier replacement is honoured."""
    gdb = _direct_gdb(server)
    q = get_query("3-path")
    plan, _ = server._plan_for(QueryRequest("3-path", engine="vlftj"),
                               gdb)
    calls = []

    def inner(level, frontier, mult):
        calls.append(level)
        return frontier[::-1], mult[::-1]   # pure permutation

    budget = QuantumBudget(None, "3-path", plan.gao, inner=inner)
    ex = VLFTJ(q, gdb, plan=plan.with_level_callback(budget))
    assert ex.count() == count(q, gdb, engine="vlftj")
    assert calls and budget.total_rows > 0
