"""PartitionedJoin edge cases, partition/schedule invariants, the real
worker pool, and the QueryServer -> dist routing path (all single-device
host-side)."""
import math

import jax
import numpy as np
import pytest

from repro.core import GraphDB, count, get_query
from repro.core.plan import executor_geometry, stripe_partition
from repro.dist.pool import WorkerPool, pick_backend
from repro.dist.sharded_join import PartitionedJoin, spmd_join_step
from repro.graphs import node_sample, powerlaw_cluster
from repro.serve import QueryRequest, QueryServer


@pytest.fixture(scope="module")
def gdb():
    g = powerlaw_cluster(300, 4, seed=11)
    unary = {f"v{i}": node_sample(g.n_nodes, 6, seed=i)
             for i in range(1, 5)}
    return GraphDB(g, unary)


def test_stripe_partition_balances_sizes_and_costs():
    rng = np.random.default_rng(0)
    costs = rng.pareto(1.5, size=97) + 1.0   # power-law skew
    parts = stripe_partition(costs, 8)
    assert len(parts) == 8
    all_items = np.sort(np.concatenate(parts))
    assert np.array_equal(all_items, np.arange(97))
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1
    # no partition can beat the largest single item; the snake deal keeps
    # the spread within that bound
    loads = np.array([costs[p].sum() for p in parts])
    assert loads.max() - loads.min() <= costs.max()


def test_stripe_partition_more_parts_than_items():
    parts = stripe_partition(np.ones(3), 8)
    assert len(parts) == 8
    assert sum(len(p) for p in parts) == 3
    assert sum(len(p) == 0 for p in parts) == 5


@pytest.mark.parametrize("qname", ["3-clique", "4-cycle", "3-path"])
def test_partitioned_count_matches_planner_count(gdb, qname):
    ref = count(get_query(qname), gdb, engine="vlftj")
    pj = PartitionedJoin(get_query(qname), gdb, n_workers=3, granularity=2)
    assert pj.count() == ref


def test_empty_frontier_shard_counts_zero(gdb):
    pj = PartitionedJoin(get_query("3-clique"), gdb, n_workers=2,
                         granularity=1)
    c = pj.executor.seeded_count(np.empty(0, np.int32),
                                 np.empty(0, np.int64))
    assert c == 0


def test_empty_and_sparse_parts_still_exact(gdb):
    ref = count(get_query("3-clique"), gdb, engine="vlftj")
    pj = PartitionedJoin(get_query("3-clique"), gdb, n_workers=64,
                         granularity=8)   # 512 parts >> any balance
    assert pj.count() == ref
    assert pj.stats["parts"] == 512
    assert len(pj.stats["worker_time"]) == 64
    sizes = pj.stats["part_sizes"]
    assert max(sizes) - min(sizes) <= 1
    # with 300 nodes and 512 parts many shards are empty frontiers
    assert sum(s == 0 for s in sizes) > 0


def test_stats_invariants(gdb):
    pj = PartitionedJoin(get_query("3-clique"), gdb, n_workers=4,
                         granularity=3)
    pj.count()
    st = pj.stats
    assert st["parts"] == 12
    assert st["makespan"] <= st["total_time"] + 1e-9
    assert abs(sum(st["worker_time"]) - st["total_time"]) < 1e-9
    assert len(st["part_time"]) == 12 and len(st["part_counts"]) == 12
    # static deal: every worker owns exactly `granularity` parts
    assert all(len(v) == 3 for v in pj.schedule.values())
    # cost-balanced parts: sizes within one of each other
    assert max(st["part_sizes"]) - min(st["part_sizes"]) <= 1


def test_dead_worker_redeal_covers_all_parts(gdb):
    ref = count(get_query("3-path"), gdb, engine="vlftj")
    pj = PartitionedJoin(get_query("3-path"), gdb, n_workers=4,
                         granularity=2, dead={1})
    assert pj.count() == ref
    owned = sorted(p for parts in pj.schedule.values() for p in parts)
    assert owned == list(range(8))
    assert 1 not in pj.schedule
    assert pj.stats["worker_time"][1] == 0.0


def test_pool_equals_sequential_partitioned_join(gdb):
    """The satellite property: the concurrent pool computes exactly what
    the old sequential walk did, part for part."""
    for qname in ("3-clique", "3-path"):
        seq = PartitionedJoin(get_query(qname), gdb, n_workers=3,
                              granularity=2, backend="sequential")
        pool = PartitionedJoin(get_query(qname), gdb, n_workers=3,
                               granularity=2, backend="thread")
        assert seq.count() == pool.count()
        assert seq.stats["part_counts"] == pool.stats["part_counts"]
        assert seq.stats["backend"] == "sequential"
        assert pool.stats["backend"] == "thread"
        assert pool.stats["wall_time"] > 0


def test_auto_backend_routes_device_payload_to_threads(gdb):
    pj = PartitionedJoin(get_query("3-clique"), gdb, n_workers=2,
                         granularity=2)
    ref = count(get_query("3-clique"), gdb, engine="vlftj")
    assert pj.count() == ref
    # the join task closes over jitted/device state: never a process
    assert pj.stats["backend"] == "thread"
    assert pick_backend(pj._count_part, pj.parts[0]) == "thread"
    # a pure-python payload may cross a process boundary
    assert pick_backend(math.factorial, 5) == "process"


def test_worker_pool_process_backend_roundtrip():
    sched = {0: [0, 2], 1: [1, 3]}
    res, ptime, wall, backend = WorkerPool(sched, backend="auto").run(
        math.factorial, [5, 6, 7, 8])
    assert backend == "process"
    assert res == {0: 120, 1: 720, 2: 5040, 3: 40320}
    assert set(ptime) == {0, 1, 2, 3} and wall > 0


def test_pool_respects_dead_worker_schedule(gdb):
    ref = count(get_query("3-path"), gdb, engine="vlftj")
    pj = PartitionedJoin(get_query("3-path"), gdb, n_workers=4,
                         granularity=2, dead={2}, backend="thread")
    assert pj.count() == ref
    assert pj.stats["worker_time"][2] == 0.0
    assert 2 not in pj.schedule


def test_spmd_join_step_pads_non_divisible_frontier(gdb):
    """Regression (satellite): callers no longer pre-pad the frontier to
    the shard multiple or hand-zero the padding's mult."""
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    g = gdb.csr
    ea = g.edge_array()
    fr = ea[ea[:, 0] < ea[:, 1]].astype(np.int32)
    # odd length: under >1 device the wrapper must pad internally
    if fr.shape[0] % 2 == 0:
        fr = fr[:-1]
    width, _ = executor_geometry(gdb.max_degree)
    kw = dict(probe_cols=(0, 1), n_unary=0, lower_cols=(1,), upper_cols=(),
              width=width, n_iter=gdb.bsearch_iters, needs_degree=False)
    step = spmd_join_step(mesh, kw)
    mult = np.ones(fr.shape[0], np.int64)
    got = int(step(gdb.dev("indptr"), gdb.dev("indices"), fr, mult))
    # oracle: per-edge sorted-intersection triangle count over fr
    ind, ptr = g.indices, g.indptr
    ref = 0
    for a, b in fr:
        inter = np.intersect1d(ind[ptr[a]:ptr[a + 1]],
                               ind[ptr[b]:ptr[b + 1]], assume_unique=True)
        ref += int((inter > b).sum())
    assert got == ref


def test_query_server_routes_large_graphs_to_partitioned():
    g = powerlaw_cluster(300, 4, seed=3)
    plain = QueryServer(g)                       # threshold far above g
    routed = QueryServer(g, dist_edge_threshold=1)
    req = QueryRequest("3-clique", selectivity=8, seed=0, engine="vlftj")
    r_plain = plain.execute(req)
    r_routed = routed.execute(req)
    assert r_plain.engine == "vlftj"
    assert r_routed.engine == "vlftj+partitioned"
    assert r_routed.count == r_plain.count
    st = routed.last_dist_stats
    assert st is not None and st["parts"] == 8   # 4 workers x 2
    assert st["makespan"] <= st["total_time"] + 1e-9
    # non-vlftj plans never take the dist route
    r_y = routed.execute(QueryRequest("3-path", selectivity=8, seed=0,
                                      engine="yannakakis"))
    assert r_y.engine == "yannakakis"


def test_execute_many_keeps_dist_route():
    g = powerlaw_cluster(300, 4, seed=3)
    routed = QueryServer(g, dist_edge_threshold=1)
    res = routed.execute_many(
        [QueryRequest("3-clique", selectivity=8, seed=0, engine="vlftj")] * 2)
    assert all(r.engine == "vlftj+partitioned" for r in res)
    assert res[0].count == res[1].count
