"""Cross-engine enumeration parity and the repro.results subsystem.

The contract (``repro.results``): every engine's ``enumerate`` emits
int64 tuples, columns in its ``output_vars`` order, rows sorted
lexicographically, and ``limit`` truncates *after* that ordering.  The
unified ``core.engine.enumerate`` normalizes all six engines to the same
column order (default: ``query.variables``), so results must agree
row-for-row with the scalar LFTJ oracle; cursor pages must concatenate
to the full result under a bounded tail buffer; factorized results must
expand to exactly the flat rows.
"""
import numpy as np
import pytest

from repro.core import (GraphDB, GraphStats, LFTJ, Minesweeper, PlanCache,
                        VLFTJ, BinaryJoin, CountingYannakakis, HybridJoin,
                        count, get_query)
from repro.core import engine as engine_mod
from repro.core.planner import estimate_emission, plan_query
from repro.graphs import CSRGraph, node_sample, powerlaw_cluster
from repro.results import FactorizedResult, ResultSet, factorize_vlftj
from repro.serve import QueryRequest, QueryServer

from conftest import make_gdb

FIXTURE_QUERIES = ["3-clique", "4-cycle", "3-path", "1-tree", "2-comb",
                   "2-lollipop"]
#: engines with full query coverage; yannakakis only plans filter-free
#: β-acyclic forests, so it gets its own (deterministic) pairing below.
GENERAL_ENGINES = ["vlftj", "binary", "minesweeper_ref", "hybrid", "auto"]
ACYCLIC_QUERIES = ["3-path", "1-tree", "2-comb"]


@pytest.fixture(scope="module")
def gdb():
    return make_gdb(50, 3, seed=3)


@pytest.fixture(scope="module")
def ref_rows(gdb):
    cache = {}

    def get(qname):
        if qname not in cache:
            cache[qname] = engine_mod.enumerate(
                get_query(qname), gdb, engine="lftj_ref", mode="flat")
        return cache[qname]

    return get


# ---------------------------------------------------------------------------
# unified engine.enumerate parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", FIXTURE_QUERIES)
@pytest.mark.parametrize("engine", GENERAL_ENGINES)
def test_enumerate_matches_lftj_ref(gdb, ref_rows, qname, engine):
    q = get_query(qname)
    ref = ref_rows(qname)
    res = engine_mod.enumerate(q, gdb, engine=engine)
    assert res.vars == ref.vars == q.variables
    got = res.expand()          # flat or factorized — same API
    assert got.dtype == np.int64
    np.testing.assert_array_equal(got, ref.rows)
    assert res.count() == count(q, gdb, engine="lftj_ref")


@pytest.mark.parametrize("qname", ACYCLIC_QUERIES)
def test_enumerate_yannakakis_matches_lftj_ref(gdb, ref_rows, qname):
    res = engine_mod.enumerate(get_query(qname), gdb, engine="yannakakis")
    np.testing.assert_array_equal(res.expand(), ref_rows(qname).rows)


def test_enumerate_order_and_plan_cache(gdb):
    q = get_query("3-clique")
    order = ("c", "a", "b")
    res = engine_mod.enumerate(q, gdb, engine="vlftj", order=order,
                               mode="flat")
    assert res.vars == order
    back = res.reorder(q.variables)
    np.testing.assert_array_equal(
        back.rows,
        engine_mod.enumerate(q, gdb, engine="vlftj", mode="flat").rows)
    # enumeration plans cache separately from counting plans
    cache = PlanCache()
    stats = GraphStats.of(gdb)
    p_rows = cache.get_or_plan(q, stats, "vlftj", output="rows")
    p_cnt = cache.get_or_plan(q, stats, "vlftj")
    assert p_rows.output_mode in ("flat", "factorized")
    assert p_cnt.output_mode == "count"
    assert cache.misses == 2
    assert cache.get_or_plan(q, stats, "vlftj", output="rows") is p_rows


# ---------------------------------------------------------------------------
# the normalized per-engine contract
# ---------------------------------------------------------------------------

def _engines_for(q, gdb):
    db = gdb.to_database()
    engines = [LFTJ(q, db), Minesweeper(q, db), BinaryJoin(q, db),
               VLFTJ(q, gdb), HybridJoin(q, gdb)]
    try:
        engines.append(CountingYannakakis(q, gdb))
    except ValueError:
        pass
    return engines


@pytest.mark.parametrize("qname", ["3-clique", "3-path"])
def test_engine_method_contract(gdb, qname):
    """One contract: int64, columns = output_vars, lex order, limit
    truncates after ordering."""
    q = get_query(qname)
    for eng in _engines_for(q, gdb):
        rows = eng.enumerate()
        assert rows.dtype == np.int64
        assert rows.shape[1] == len(eng.output_vars)
        assert set(eng.output_vars) == set(q.variables)
        order = np.lexsort(rows.T[::-1])
        assert (order == np.arange(rows.shape[0])).all(), type(eng)
        np.testing.assert_array_equal(eng.enumerate(limit=7), rows[:7])
        assert eng.enumerate(limit=0).shape == (0, rows.shape[1])


def test_lftj_limit_truncates_after_ordering(gdb):
    """The documented lftj_ref semantics: emission order is the lex
    order, so limit= equals post-sort truncation (the cursor contract)."""
    q = get_query("3-clique")
    eng = LFTJ(q, gdb.to_database())
    full = eng.enumerate()
    assert full.shape[0] > 10
    for m in (1, 5, full.shape[0], full.shape[0] + 10):
        np.testing.assert_array_equal(eng.enumerate(limit=m), full[:m])


def test_empty_result_all_engines():
    g = powerlaw_cluster(40, 3, seed=5)
    empty = {f"v{i}": np.zeros(0, dtype=np.int64) for i in range(1, 5)}
    gdb = GraphDB(g, empty)
    q = get_query("3-path")
    k = len(q.variables)
    for engine in ["lftj_ref", "minesweeper_ref", "binary", "vlftj",
                   "yannakakis", "hybrid", "auto"]:
        res = engine_mod.enumerate(q, gdb, engine=engine)
        assert res.count() == 0
        assert res.expand().shape == (0, k), engine
    cur = engine_mod.stream(q, gdb, engine="vlftj")
    assert cur.next_page() is None
    assert cur.exhausted


# ---------------------------------------------------------------------------
# cursor: pages concatenate, bounded memory
# ---------------------------------------------------------------------------

def test_cursor_pages_concatenate_and_stay_bounded():
    gdb = make_gdb(200, 4, seed=2)
    q = get_query("3-path")                       # large fanout output
    page = 256
    cur = engine_mod.stream(q, gdb, engine="vlftj", page_rows=page)
    pages = list(cur)
    assert all(p.shape[0] == page for p in pages[:-1])
    assert 0 < pages[-1].shape[0] <= page
    rows = np.concatenate(pages)
    ex = VLFTJ(q, gdb)
    full = engine_mod.enumerate(q, gdb, engine="vlftj", order=cur.vars,
                                mode="flat").rows
    assert full.shape[0] > 4 * page               # paging is non-trivial
    np.testing.assert_array_equal(rows, full)
    # the documented bound: one page plus one expansion chunk
    assert cur.stats["peak_buffer_rows"] <= page + max(ex.width, page)
    assert cur.stats["chunks"] > 1


def test_cursor_bounded_on_dense_final_level():
    """A final level with no bound edge neighbor fans out by the unary
    domain, not the adjacency width — the cursor must stream it row by
    row, slicing extension runs to the page size (regression: the
    chunked path used to buffer cf x |domain| rows here)."""
    from repro.core import parse
    from repro.results import ResultCursor

    gdb = make_gdb(200, 4, seed=2)
    q = parse("edge(a,b), v1(c)", "edge-x-unary")
    page = 64
    ex = VLFTJ(q, gdb, gao=("a", "b", "c"))   # c is dense by construction
    cur = ResultCursor(ex, page_rows=page)
    pages = list(cur)
    rows = np.concatenate(pages)
    ref = engine_mod.enumerate(q, gdb, engine="lftj_ref",
                               order=("a", "b", "c"), mode="flat")
    assert ref.count() > 10 * page
    np.testing.assert_array_equal(rows, ref.rows)
    assert cur.stats["peak_buffer_rows"] <= 2 * page


def test_server_cursor_registry_is_capped(gdb300):
    srv = QueryServer(gdb300.csr, page_rows=8, max_open_cursors=3)
    tokens = []
    for i in range(5):
        r = srv.execute(QueryRequest("3-clique", selectivity=8, seed=0,
                                     engine="vlftj", limit=8))
        assert r.next_cursor is not None
        tokens.append(r.next_cursor)
    assert len(srv._cursors) == 3
    with pytest.raises(ValueError):              # oldest were evicted
        srv.execute(QueryRequest("3-clique", cursor=tokens[0]))
    assert srv.execute(                          # newest still resumes
        QueryRequest("3-clique", cursor=tokens[-1])).rows.shape[0] == 8


def test_server_distinguishes_evicted_vs_exhausted_cursor(gdb300):
    """Clients need to know whether to restart pagination: an evicted
    stream is restartable, an exhausted one was fully delivered."""
    srv = QueryServer(gdb300.csr, page_rows=8, max_open_cursors=2)
    # open three cursors: the first (oldest open) is evicted at the cap
    tokens = [srv.execute(QueryRequest("3-clique", selectivity=8, seed=0,
                                       engine="vlftj", limit=8)).next_cursor
              for _ in range(3)]
    assert all(t is not None for t in tokens)
    assert list(srv._cursors) == tokens[1:]
    with pytest.raises(ValueError, match="evicted.*restart"):
        srv.execute(QueryRequest("3-clique", cursor=tokens[0]))
    # drain the newest to exhaustion -> a different, do-not-restart error
    tok = tokens[-1]
    while tok is not None:
        last = tok
        tok = srv.execute(
            QueryRequest("3-clique", cursor=tok, limit=512)).next_cursor
    with pytest.raises(ValueError, match="exhausted.*not restart"):
        srv.execute(QueryRequest("3-clique", cursor=last))
    # a token the server never issued is neither
    with pytest.raises(ValueError, match="unknown"):
        srv.execute(QueryRequest("3-clique", cursor="cur-999"))


def test_cursor_take_and_exhaustion(gdb):
    q = get_query("3-clique")
    full = engine_mod.enumerate(q, gdb, engine="vlftj", mode="flat")
    cur = engine_mod.stream(q, gdb, engine="vlftj", page_rows=8)
    first = cur.take(11)
    rest = []
    while not cur.exhausted:
        rest.append(cur.take(17))
    got = np.concatenate([first] + rest)
    np.testing.assert_array_equal(
        got, full.reorder(cur.vars).rows)
    assert cur.take(5).shape == (0, 3)            # drained stays drained


# ---------------------------------------------------------------------------
# factorized results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qname", ["3-path", "2-lollipop", "3-clique"])
def test_factorized_expand_matches_flat(gdb, qname):
    q = get_query(qname)
    flat = engine_mod.enumerate(q, gdb, engine="vlftj", mode="flat")
    fact = engine_mod.enumerate(q, gdb, engine="vlftj", mode="factorized")
    assert isinstance(fact, FactorizedResult)
    assert fact.count() == flat.count()
    np.testing.assert_array_equal(fact.expand(), flat.rows)


def test_factorized_native_vs_from_rows(gdb):
    """The native builder (no flat materialization) must equal the
    trie-compression of the flat rows, level by level."""
    q = get_query("3-path")
    plan = plan_query(q, GraphStats.of(gdb), engine="vlftj", output="rows")
    ex = VLFTJ(q, gdb, plan=plan)
    native = factorize_vlftj(ex)
    flat = ex.enumerate()
    rebuilt = FactorizedResult.from_rows(plan.gao, flat, sort=False)
    assert native.vars == rebuilt.vars
    for a, b in zip(native.levels, rebuilt.levels):
        np.testing.assert_array_equal(a.values, b.values)
        np.testing.assert_array_equal(a.parent, b.parent)
    # fanout query: the trie is smaller than the flat materialization
    assert native.nbytes < flat.nbytes
    # prefix projection truncates the trie (distinct prefixes, no expand)
    prefix = native.project(plan.gao[:2])
    expect = np.unique(flat[:, :2], axis=0)
    np.testing.assert_array_equal(prefix.rows, expect)


def test_result_set_project_and_reorder(gdb):
    q = get_query("3-clique")
    rs = engine_mod.enumerate(q, gdb, engine="vlftj", mode="flat")
    pr = rs.project(("a", "b"))
    np.testing.assert_array_equal(pr.rows, np.unique(rs.rows[:, :2], axis=0))
    assert isinstance(rs.reorder(("b", "c", "a")), ResultSet)
    assert estimate_emission(q, rs.vars, GraphStats.of(gdb))[0] > 0


# ---------------------------------------------------------------------------
# backward expansion engines under random graphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_property_backward_expansion_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n, m = 24, 70
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    g = CSRGraph.from_edges(src[keep], dst[keep], n_nodes=n)
    unary = {f"v{i}": rng.choice(n, 7, replace=False) for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    for qname in ["3-path", "2-comb", "2-lollipop"]:
        q = get_query(qname)
        ref = engine_mod.enumerate(q, gdb, engine="lftj_ref", mode="flat")
        for engine in (["yannakakis", "hybrid"]
                       if qname != "2-lollipop" else ["hybrid"]):
            got = engine_mod.enumerate(q, gdb, engine=engine, mode="flat")
            np.testing.assert_array_equal(got.rows, ref.rows), (qname,
                                                                engine)


# ---------------------------------------------------------------------------
# dist + serve
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gdb300():
    g = powerlaw_cluster(300, 4, seed=11)
    unary = {f"v{i}": node_sample(g.n_nodes, 6, seed=i)
             for i in range(1, 5)}
    return GraphDB(g, unary)


@pytest.mark.parametrize("qname", ["3-clique", "3-path"])
def test_partitioned_enumerate_merges_parts(gdb300, qname):
    from repro.dist.sharded_join import PartitionedJoin
    q = get_query(qname)
    pj = PartitionedJoin(q, gdb300, n_workers=3, granularity=2)
    rs = pj.enumerate(page_rows=128)
    assert rs.vars == pj.executor.gao
    ref = engine_mod.enumerate(q, gdb300, engine="vlftj",
                               order=pj.executor.gao, mode="flat")
    np.testing.assert_array_equal(rs.rows, ref.rows)
    np.testing.assert_array_equal(
        pj.enumerate(limit=13, page_rows=5).rows, ref.rows[:13])


def test_server_pagination_roundtrip(gdb300):
    g = gdb300.csr
    srv = QueryServer(g, page_rows=64)
    first = srv.execute(QueryRequest("3-clique", selectivity=8, seed=0,
                                     engine="vlftj", limit=50))
    assert first.rows.shape[0] == 50
    assert first.count == 50
    assert first.next_cursor is not None
    assert first.plan is not None and first.plan.output_mode != "count"
    pages, tok = [first.rows], first.next_cursor
    while tok is not None:
        nxt = srv.execute(QueryRequest("3-clique", cursor=tok, limit=50))
        pages.append(nxt.rows)
        tok = nxt.next_cursor
    got = np.concatenate(pages)
    gdb = srv._gdb_for(8, 0)
    full = engine_mod.enumerate(get_query("3-clique"), gdb,
                                engine="vlftj", order=first.row_vars,
                                mode="flat")
    np.testing.assert_array_equal(got, full.rows)
    assert not srv._cursors                        # drained and dropped
    with pytest.raises(ValueError):
        srv.execute(QueryRequest("3-clique", cursor="cur-999"))
    # same-shape rows requests hit the enumeration plan cache entry
    again = srv.execute(QueryRequest("3-clique", selectivity=8, seed=0,
                                     engine="vlftj", limit=10))
    assert again.plan_cached


def test_server_pagination_dist_route(gdb300):
    srv = QueryServer(gdb300.csr, dist_edge_threshold=1, page_rows=64)
    res = srv.execute(QueryRequest("3-clique", selectivity=8, seed=0,
                                   engine="vlftj", limit=40))
    assert res.engine == "vlftj+partitioned"
    pages, tok = [res.rows], res.next_cursor
    while tok is not None:
        nxt = srv.execute(QueryRequest("3-clique", cursor=tok, limit=40))
        pages.append(nxt.rows)
        tok = nxt.next_cursor
    plain = QueryServer(gdb300.csr, page_rows=64)
    ref = plain.execute(QueryRequest("3-clique", selectivity=8, seed=0,
                                     engine="vlftj",
                                     limit=10 ** 9))
    np.testing.assert_array_equal(np.concatenate(pages), ref.rows)


def test_execute_many_mixes_counts_rows_and_cursors(gdb300):
    srv = QueryServer(gdb300.csr, page_rows=32)
    res = srv.execute_many([
        QueryRequest("3-clique", selectivity=8, seed=0, limit=20),
        QueryRequest("3-clique", selectivity=8, seed=0, limit=20),
        QueryRequest("3-clique", selectivity=8, seed=0),
    ])
    assert res[0].rows.shape == (20, 3) and res[1].rows.shape == (20, 3)
    np.testing.assert_array_equal(res[0].rows, res[1].rows)
    assert res[1].plan_cached                      # same enumeration plan
    assert res[2].rows is None and res[2].count > 0
    cont = srv.execute_many(
        [QueryRequest("3-clique", cursor=res[0].next_cursor, limit=20)])
    assert cont[0].rows.shape[0] == 20
    assert not np.array_equal(cont[0].rows, res[0].rows)
