"""Checkpoint fault tolerance + data-pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import LMTokenPipeline, lm_synthetic_batch, \
    recsys_synthetic_batch
from repro.graphs import NeighborSampler, powerlaw_cluster
from repro.models.gnn.data import pad_graph, random_graph_batch
from repro.train.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int64),
                       "c": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree()
    cm.save(10, t, blocking=True)
    assert cm.latest_step() == 10
    r = cm.restore(10, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        cm.save(s, _tree(s))
    cm.wait()
    assert cm.steps() == [3, 4]


def test_corruption_detected_and_skipped(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree(1), blocking=True)
    cm.save(2, _tree(2), blocking=True)
    # corrupt the newest checkpoint
    d = os.path.join(str(tmp_path), "step-00000002")
    victim = os.path.join(d, "leaf-00000.npy")
    with open(victim, "r+b") as f:
        f.seek(120)
        f.write(b"\xde\xad\xbe\xef")
    assert not cm.verify(2)
    assert cm.latest_step() == 1  # falls back to the last good one


def test_torn_write_invisible(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(5, _tree(), blocking=True)
    os.makedirs(os.path.join(str(tmp_path), ".tmp-9"), exist_ok=True)
    assert cm.steps() == [5]


def test_restore_across_dtypes_and_structs(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree()
    cm.save(3, t, blocking=True)
    like = jax.tree.map(jnp.zeros_like, t)
    r = cm.restore(3, like)
    assert r["nested"]["b"].dtype == t["nested"]["b"].dtype


def test_lm_pipeline_determinism():
    a = lm_synthetic_batch(7, 8, 32, 1000, seed=3, shard=1, n_shards=2)
    b = lm_synthetic_batch(7, 8, 32, 1000, seed=3, shard=1, n_shards=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_synthetic_batch(8, 8, 32, 1000, seed=3, shard=1, n_shards=2)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_pipeline_file_backed(tmp_path):
    tokens = np.arange(10_000, dtype=np.int32)
    f = tmp_path / "toks.bin"
    tokens.tofile(f)
    pipe = LMTokenPipeline(batch=4, seq=16, vocab=50_000,
                           token_file=str(f))
    b0 = pipe.get_batch(0)
    b0b = pipe.get_batch(0)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    np.testing.assert_array_equal(b0["labels"][:, :-1],
                                  b0["tokens"][:, 1:])


def test_recsys_pipeline_shapes():
    b = recsys_synthetic_batch(0, 64, 39, 1000)
    assert b["ids"].shape == (64, 39)
    assert b["ids"].max() < 1000


def test_neighbor_sampler_shapes_and_mask():
    g = powerlaw_cluster(300, 3, seed=0)
    s = NeighborSampler(g, (5, 3), seed=1)
    hops = s.sample(np.arange(16))
    assert hops[0]["nbr"].shape == (16, 5)
    assert hops[1]["nbr"].shape[1] == 3
    # sampled neighbors are real neighbors
    for i in range(16):
        nbrs = set(g.neighbors(i).tolist())
        if nbrs:
            assert set(hops[0]["nbr"][i].tolist()) <= nbrs


def test_pad_graph():
    g = random_graph_batch(10, 20, 4, seed=0)
    p = pad_graph(g, 16, 40)
    assert p.node_feat.shape == (16, 4)
    assert p.src.shape == (40,)
