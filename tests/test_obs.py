"""Observability (repro.obs): tracing, metrics, EXPLAIN ANALYZE.

The contract under test, in tiers:

* **Schema** — every engine path emits the unified stats schema
  (``ENGINE_REQUIRED_KEYS``) through ``execute_stats``.
* **Zero-cost when off** — a disabled tracer adds *no* device
  dispatches: the vlftj dispatch meters (chunks / ll_calls /
  candidates) are identical with tracing on and off, and counts agree.
* **Complete traces end to end** — a scheduled query's trace carries
  preempt/resume (and restart) events; a dist-routed query's trace
  carries per-level exchange events; both with count parity against
  the untraced run.
* **EXPLAIN ANALYZE** — a Zipf-skewed triangle shows per-level
  est-vs-observed cardinality and a finite Q-error.
* **Registry** — counters/gauges/histograms aggregate by label and
  snapshot as flat prometheus-style keys; the server surfaces them.
"""
import json

import numpy as np
import pytest

from repro.core import (GraphDB, GraphStats, count, execute_stats,
                        get_query, plan_query)
from repro.dist.sharded_csr import ShardedGraphDB, sharded_count
from repro.graphs import node_sample, powerlaw_cluster
from repro.graphs.generators import zipf_graph
from repro.obs import (ENGINE_REQUIRED_KEYS, MetricsRegistry, QueryTrace,
                       current_trace, explain_analyze, normalize_engine_stats,
                       qerror)
from repro.serve import QuantumScheduler, QueryRequest, QueryServer

from conftest import make_gdb

# engine -> a query shape it supports (yannakakis needs β-acyclic)
SIX_ENGINES = [("vlftj", "3-clique"), ("lftj_ref", "3-clique"),
               ("binary", "3-clique"), ("minesweeper_ref", "3-clique"),
               ("yannakakis", "3-path"), ("hybrid", "2-lollipop")]


@pytest.fixture(scope="module")
def gdb():
    return make_gdb(60, 3, seed=5)


def zipf_gdb(n=500, m=2500, seed=0):
    g = zipf_graph(n, m, seed=seed)
    unary = {f"v{i}": node_sample(g.n_nodes, 4, seed=seed + i)
             for i in range(1, 5)}
    return GraphDB(g, unary)


# ---------------------------------------------------------------------------
# satellite 1: unified engine stats schema
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,qname", SIX_ENGINES)
def test_every_engine_emits_unified_stats(gdb, engine, qname):
    q = get_query(qname)
    plan = plan_query(q, GraphStats.of(gdb), engine=engine)
    c, stats = execute_stats(plan, gdb)
    assert c == count(q, gdb, engine="lftj_ref")
    assert tuple(sorted(stats)) == tuple(sorted(ENGINE_REQUIRED_KEYS))
    assert stats["name"] == engine
    assert isinstance(stats["rows_expanded"], int)
    assert isinstance(stats["raw"], dict)
    for d in (stats["level_rows"], stats["level_wall_s"],
              stats["level_paths"]):
        assert all(isinstance(k, int) for k in d)


def test_normalize_is_total_on_empty_stats():
    out = normalize_engine_stats("mystery", None)
    assert tuple(sorted(out)) == tuple(sorted(ENGINE_REQUIRED_KEYS))
    assert out["rows_expanded"] == 0 and out["raw"] == {}


# ---------------------------------------------------------------------------
# satellite 3: tracing on/off parity + zero-dispatch guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,qname", SIX_ENGINES)
def test_traced_count_matches_untraced(gdb, engine, qname):
    q = get_query(qname)
    plan = plan_query(q, GraphStats.of(gdb), engine=engine)
    ref, _ = execute_stats(plan, gdb)
    tr = QueryTrace(qname, plan.gao, engine)
    with tr.activate():
        traced, _ = execute_stats(plan, gdb)
    assert traced == ref
    assert tr.summary["count"] == ref


def test_disabled_tracer_adds_zero_device_dispatches(gdb):
    """The whole-point guard: with no active trace, the vlftj dispatch
    meters are identical to a run that never imported repro.obs —
    capture is host-side harvesting of counters vlftj keeps anyway."""
    q = get_query("4-cycle")
    plan = plan_query(q, GraphStats.of(gdb), engine="vlftj")
    assert current_trace() is None
    _, off = execute_stats(plan, gdb)
    tr = QueryTrace("4-cycle", plan.gao, "vlftj")
    with tr.activate():
        _, on = execute_stats(plan, gdb)
    for meter in ("chunks", "ll_calls", "candidates"):
        assert on["raw"][meter] == off["raw"][meter], meter
    assert on["kernel_dispatches"] == off["kernel_dispatches"]
    assert on["jit_calls"] == off["jit_calls"]
    # static agreement: the obs-device-free lint pass proves the same
    # property by construction — the harvest modules never touch jax,
    # so the runtime meter parity above is not a coincidence of this
    # query shape
    import ast as ast_mod
    from conftest import REPO_ROOT, load_lint_module
    lint = load_lint_module()
    rule = lint.ObsHostPurity()
    import os
    for rel in rule.scope:
        src = open(os.path.join(REPO_ROOT, rel), encoding="utf-8").read()
        assert rule.check(ast_mod.parse(src), rel, src) == [], rel


def test_vlftj_levels_carry_est_obs_and_paths(gdb):
    q = get_query("3-clique")
    plan = plan_query(q, GraphStats.of(gdb), engine="vlftj")
    tr = QueryTrace("3-clique", plan.gao, "vlftj")
    with tr.activate():
        c, _ = execute_stats(plan, gdb)
    assert len(plan.level_est_rows) == len(plan.gao)
    for lv in range(len(plan.gao)):
        rec = tr.levels[lv]
        assert rec["var"] == plan.gao[lv]
        assert rec["obs_rows"] >= 0
        assert rec["est_rows"] == pytest.approx(plan.level_est_rows[lv])
        assert rec["q_error"] >= 1.0
    # interior levels record which kernel path expanded their rows
    assert any("kernel" in tr.levels[lv] for lv in range(1, len(plan.gao)))
    assert tr.summary["count"] == c


# ---------------------------------------------------------------------------
# acceptance: scheduled query -> complete trace with preempt/resume
# ---------------------------------------------------------------------------

def test_scheduled_trace_has_preempt_resume_and_parity():
    csr = powerlaw_cluster(n=300, m_per_node=4, seed=0)
    server = QueryServer(csr, page_rows=256)
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest("3-path", engine="vlftj", trace=True))
    (res,) = sched.run()
    gdb = server._gdb_for(server.default_selectivity, 0)
    assert res.count == count(get_query("3-path"), gdb, engine="vlftj")
    tr = res.trace
    assert tr is not None
    preempts = tr.events_named("preempt")
    resumes = tr.events_named("resume")
    assert len(preempts) >= 1
    assert len(resumes) >= 1
    assert all("quantum" in e or "phase" in e for e in preempts)
    assert tr.summary["count"] == res.count
    assert tr.summary["quanta"] == res.stats["quanta"]
    # the full trace serializes: preempt/resume events survive JSONL
    back = QueryTrace.from_jsonl(tr.to_jsonl())
    assert len(back.events_named("preempt")) == len(preempts)
    assert back.summary["count"] == res.count
    # untraced request: no trace object, same count
    plain = QueryServer(csr, page_rows=256)
    s2 = QuantumScheduler(plain, quantum_rows=64)
    s2.submit(QueryRequest("3-path", engine="vlftj"))
    (r2,) = s2.run()
    assert r2.trace is None and r2.count == res.count


def test_restart_backoff_visible_in_stats_and_trace():
    """Satellite 6: eviction restarts double the quantum invisibly —
    now exposed as stats['quantum_rows_final'] and a per-restart trace
    event carrying the grown quantum."""
    csr = powerlaw_cluster(n=300, m_per_node=4, seed=0)
    server = QueryServer(csr, page_rows=256, max_open_cursors=2)
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest("3-path", engine="vlftj", trace=True))
    assert sched.step()                    # preempts; snapshot parked
    for s in range(3):                     # flood the LRU registry
        server.execute(QueryRequest("3-clique", engine="vlftj", limit=1,
                                    seed=s))
    while sched.step():
        pass
    (res,) = [j.result for j in sched._jobs]
    assert res.stats["restarts"] >= 1
    assert res.stats["quantum_rows_initial"] == 64
    assert (res.stats["quantum_rows_final"]
            == 64 * 2 ** res.stats["restarts"])
    restarts = res.trace.events_named("restart")
    assert len(restarts) == res.stats["restarts"]
    assert restarts[0]["quantum_rows"] == 128
    assert restarts[0]["reason"] in ("evicted", "quota")


def test_server_trace_flag_roundtrip():
    csr = powerlaw_cluster(n=200, m_per_node=3, seed=1)
    server = QueryServer(csr)
    res = server.execute(QueryRequest("3-clique", engine="vlftj",
                                      trace=True))
    assert res.trace is not None
    assert res.trace.summary["count"] == res.count
    assert res.stats["engine"]["name"] == "vlftj"
    off = server.execute(QueryRequest("3-clique", engine="vlftj"))
    assert off.trace is None and off.count == res.count


# ---------------------------------------------------------------------------
# acceptance: dist-routed query -> trace with exchange events
# ---------------------------------------------------------------------------

def test_sharded_trace_has_exchange_events_and_parity():
    g = zipf_graph(800, 4000, seed=2)
    unary = {f"v{i}": node_sample(g.n_nodes, 4, seed=i) for i in (1, 2)}
    sg = ShardedGraphDB(g, 4, unary)
    q = get_query("3-path")
    ref = sharded_count(q, sg)
    tr = QueryTrace("3-path", (), "sharded")
    sg2 = ShardedGraphDB(g, 4, unary)
    with tr.activate():
        traced = sharded_count(q, sg2)
    assert traced == ref
    ex = tr.events_named("exchange")
    assert len(ex) >= 2                       # one per level at least
    assert {e["level"] for e in ex} >= {0, 1}
    assert any(e["values"] > 0 for e in ex)   # adjacency actually shipped
    assert all(e["bytes"] == e["values"] * 8 for e in ex)
    # per-level observed cardinalities are recorded alongside
    assert tr.levels[0]["obs_rows"] > 0
    # the full trace serializes: exchange events survive JSONL
    back = QueryTrace.from_jsonl(tr.to_jsonl())
    assert len(back.events_named("exchange")) == len(ex)


# ---------------------------------------------------------------------------
# acceptance: EXPLAIN ANALYZE on a Zipf triangle
# ---------------------------------------------------------------------------

def test_explain_analyze_zipf_triangle():
    gdb = zipf_gdb()
    res = explain_analyze(get_query("3-clique"), gdb, engine="vlftj")
    assert res.count == count(get_query("3-clique"), gdb, engine="vlftj")
    assert len(res.levels) == 3
    for rec in res.levels:
        assert rec["est_rows"] is not None and rec["obs_rows"] is not None
        assert np.isfinite(rec["q_error"]) and rec["q_error"] >= 1.0
    text = res.render()
    assert "est=" in text and "obs=" in text and "q=" in text
    assert "max q-error" in text
    assert np.isfinite(res.max_q_error)


# ---------------------------------------------------------------------------
# trace object + JSONL round-trip
# ---------------------------------------------------------------------------

def test_qerror_edge_cases():
    assert qerror(10, 10) == 1.0
    assert qerror(5, 20) == 4.0
    assert qerror(20, 5) == 4.0
    assert qerror(0, 0) == 1.0
    assert qerror(0, 7) == float("inf")
    assert qerror(7, 0) == float("inf")


def test_trace_jsonl_roundtrip(tmp_path, gdb):
    q = get_query("3-path")
    plan = plan_query(q, GraphStats.of(gdb), engine="vlftj")
    tr = QueryTrace("3-path", plan.gao, "vlftj")
    with tr.activate():
        execute_stats(plan, gdb)
    tr.event("custom", detail="x")
    path = tmp_path / "t.jsonl"
    tr.to_jsonl(path)
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    kinds = [ln["kind"] for ln in lines]
    assert kinds[0] == "header" and kinds[-1] == "summary"
    assert kinds.count("level") == len(tr.levels)
    back = QueryTrace.from_jsonl(path)
    assert back.summary["count"] == tr.summary["count"]
    assert set(back.levels) == set(tr.levels)
    assert [e["name"] for e in back.events] == [e["name"] for e in tr.events]


def test_trace_inactive_by_default():
    assert current_trace() is None
    tr = QueryTrace("q", ("a",), "vlftj")
    with tr.activate():
        assert current_trace() is tr
        with QueryTrace("inner", ("b",), "vlftj").activate() as inner:
            assert current_trace() is inner
        assert current_trace() is tr
    assert current_trace() is None


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.counter("reqs", route="a").inc()
    reg.counter("reqs", route="a").inc(2)
    reg.counter("reqs", route="b").inc()
    reg.gauge("open").set(5)
    reg.gauge("open").dec(2)
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["reqs{route=a}"] == 3
    assert snap["reqs{route=b}"] == 1
    assert snap["open"] == 3
    assert snap["lat_count"] == 3
    assert snap["lat_sum"] == pytest.approx(5.55)
    assert snap["lat_bucket{le=0.1}"] == 1
    assert snap["lat_bucket{le=1}"] == 2
    assert snap["lat_bucket{le=+Inf}"] == 3
    with pytest.raises(ValueError):
        reg.counter("reqs", route="a").inc(-1)
    reg.reset()
    assert len(reg) == 0


def test_registry_handles_are_live():
    reg = MetricsRegistry()
    c = reg.counter("x")
    c.inc()
    assert reg.counter("x").value == 1     # same underlying series


def test_server_metrics_endpoint():
    csr = powerlaw_cluster(n=200, m_per_node=3, seed=1)
    reg = MetricsRegistry()
    server = QueryServer(csr, metrics=reg)
    server.execute(QueryRequest("3-clique", engine="vlftj"))
    server.execute(QueryRequest("3-clique", engine="vlftj"))
    snap = server.metrics()
    assert snap["server_plan_cache{outcome=miss}"] == 1
    assert snap["server_plan_cache{outcome=hit}"] == 1
    assert snap["server_plan_cache_size"] >= 1
    assert snap["server_metrics_snapshots"] == 1
    assert "server_open_cursors" in snap


def test_scheduler_quanta_counted_in_registry():
    csr = powerlaw_cluster(n=200, m_per_node=3, seed=1)
    reg = MetricsRegistry()
    server = QueryServer(csr, metrics=reg)
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest("3-clique", engine="vlftj"))
    sched.run()
    snap = server.metrics()
    assert snap["scheduler_quanta"] == sched.stats["quanta"]
    assert (snap.get("scheduler_preemptions", 0)
            == sched.stats["preemptions"])


def test_pool_worker_makespans_observed():
    from repro.dist.pool import WorkerPool
    from repro.obs import get_registry
    reg = get_registry()
    before = reg.snapshot().get(
        "pool_worker_seconds_count{backend=thread}", 0)
    pool = WorkerPool({0: [0, 2], 1: [1]}, backend="thread")
    results, part_time, _, backend = pool.run(lambda x: x * 2,
                                              [1, 2, 3])
    assert backend == "thread"
    assert results == {0: 2, 1: 4, 2: 6}
    after = reg.snapshot()["pool_worker_seconds_count{backend=thread}"]
    assert after == before + 2             # one observation per worker
