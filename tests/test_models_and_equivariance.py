"""Model-level behaviour: decode==forward, MoE balance, equivariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from scipy.stats import special_ortho_group

from repro.layers.moe import MoEConfig
from repro.models.gnn import random_graph_batch
from repro.models.gnn.egnn import EGNNConfig, egnn_forward, init_egnn
from repro.models.gnn.mace import (MACEConfig, gaunt_tensor, init_mace,
                                   mace_energy, real_sph_harm)
from repro.models.transformer import (TransformerConfig, decode_step,
                                      forward, init_params, _lm_logits,
                                      loss_fn, prefill)

CFG = TransformerConfig(name="t", n_layers=3, d_model=64, n_heads=4,
                        n_kv_heads=2, d_ff=128, vocab_size=256,
                        dtype=jnp.float32, remat=False, max_cache_len=48)


@pytest.fixture(scope="module")
def tiny_lm():
    return init_params(jax.random.PRNGKey(0), CFG)


def test_decode_matches_full_forward(tiny_lm):
    p = tiny_lm
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 256)
    cache, _ = prefill(p, toks, CFG, max_len=48)
    cur = cache
    nxt = toks[:, :1]
    outs = []
    for i in range(4):
        lg, cur = decode_step(p, cur, nxt, CFG)
        outs.append(lg)
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    # oracle: full forward over the concatenated stream
    stream = jnp.concatenate([toks, toks[:, :1]], axis=1)
    for i in range(3):
        x, _ = forward(p, stream, CFG)
        full = _lm_logits(x[:, -1:, :], p, CFG, None)
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(full),
                                   atol=2e-4, rtol=2e-4)
        stream = jnp.concatenate(
            [stream, jnp.argmax(full, -1).astype(jnp.int32)], axis=1)


def test_vocab_padding_masks_loss():
    cfg = dataclasses.replace(CFG, vocab_size=250)  # pads to 256
    assert cfg.padded_vocab == 256
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 250)
    loss = loss_fn(p, {"tokens": toks, "labels": toks}, cfg)
    assert np.isfinite(float(loss))
    # padded logits must be -inf-masked: argmax never lands there
    x, _ = forward(p, toks, cfg)
    lg = _lm_logits(x, p, cfg, None)
    assert int(jnp.max(jnp.argmax(lg, -1))) < 250


def test_moe_local_every_token_routed():
    cfg = dataclasses.replace(
        CFG, d_ff=0,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0))  # huge capacity: no drops
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    loss, grads = jax.value_and_grad(
        lambda pp: loss_fn(pp, {"tokens": toks, "labels": toks}, cfg))(p)
    assert np.isfinite(float(loss))
    g = grads["moe"]["w_down"]
    assert np.isfinite(np.asarray(g)).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_egnn_equivariance_property(seed):
    g = random_graph_batch(40, 160, 8, seed=seed % 100, coords=True)
    cfg = EGNNConfig(d_in=8, n_layers=2, d_hidden=16)
    p = init_egnn(jax.random.PRNGKey(seed % 97), cfg)
    rot = special_ortho_group.rvs(3, random_state=seed % 1000)
    shift = np.asarray([1.0, -2.0, 0.5])
    g2 = dataclasses.replace(
        g, coords=(np.asarray(g.coords) @ rot.T + shift).astype(np.float32))
    h1, x1 = egnn_forward(p, g, cfg)
    h2, x2 = egnn_forward(p, g2, cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(x1) @ rot.T + shift,
                               np.asarray(x2), atol=1e-3, rtol=1e-3)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_mace_rotation_invariance_property(seed):
    g = random_graph_batch(30, 120, 8, seed=seed % 100, coords=True,
                           n_graphs=3)
    cfg = MACEConfig(d_in=8, d_hidden=16)
    p = init_mace(jax.random.PRNGKey(seed % 89), cfg)
    rot = special_ortho_group.rvs(3, random_state=seed % 1000)
    g2 = dataclasses.replace(
        g, coords=(np.asarray(g.coords) @ rot.T).astype(np.float32))
    e1 = mace_energy(p, g, cfg)
    e2 = mace_energy(p, g2, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               atol=1e-4, rtol=1e-4)


def test_gaunt_orthonormality():
    """G[0,a,b] = Y00·δ_ab (orthonormality through the l=0 channel)."""
    g = gaunt_tensor()
    y00 = 0.5 / np.sqrt(np.pi)
    np.testing.assert_allclose(g[0], np.eye(9) * y00, atol=1e-12)
    # full symmetry of the Gaunt tensor
    np.testing.assert_allclose(g, np.transpose(g, (1, 0, 2)), atol=1e-12)
    np.testing.assert_allclose(g, np.transpose(g, (2, 1, 0)), atol=1e-12)


def test_sph_harm_unit_norm():
    """Σ_m Y_lm² is constant on the sphere for each l (addition thm)."""
    rng = np.random.default_rng(0)
    v = rng.standard_normal((100, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    y = np.asarray(real_sph_harm(jnp.asarray(v)))
    for l, sl in [(0, slice(0, 1)), (1, slice(1, 4)), (2, slice(4, 9))]:
        s = (y[:, sl] ** 2).sum(axis=1)
        expect = (2 * l + 1) / (4 * np.pi)
        np.testing.assert_allclose(s, expect, rtol=1e-6)
