"""Plan IR + cost-based planner + plan cache.

Every engine must produce the oracle count when handed an explicit
:class:`JoinPlan`; the plan cache must hit on repeated query structure and
invalidate when the graph-stats fingerprint changes; the server must serve
repeated shapes from the cache.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core import (ENGINES, GraphDB, GraphStats, JoinPlan, PlanCache,
                        count, execute, get_query, lftj_count, pick_engine,
                        plan_query)
from repro.core.planner import candidate_gaos, candidate_plans, \
    decompose_hybrid
from repro.graphs import CSRGraph

from conftest import make_gdb

# cyclic, acyclic, and lollipop-shaped shapes from the paper suite
PLAN_QUERIES = ["3-clique", "4-clique", "4-cycle",          # cyclic
                "3-path", "2-comb", "1-tree",               # acyclic
                "2-lollipop", "3-lollipop"]                 # lollipop

ALL_ENGINES = [e for e in ENGINES if e != "auto"]


@pytest.fixture(scope="module")
def gdb():
    return make_gdb(50, 3, seed=3)


@pytest.fixture(scope="module")
def stats(gdb):
    return GraphStats.of(gdb)


@pytest.fixture(scope="module")
def oracle(gdb):
    return {q: lftj_count(get_query(q), gdb.to_database())
            for q in PLAN_QUERIES}


# -- plan construction -------------------------------------------------------

@pytest.mark.parametrize("qname", PLAN_QUERIES)
def test_plans_are_frozen_and_hashable(stats, qname):
    q = get_query(qname)
    p1 = plan_query(q, stats)
    p2 = plan_query(q, stats)
    assert isinstance(p1, JoinPlan)
    assert p1 == p2 and hash(p1) == hash(p2)     # deterministic + hashable
    assert {p1: "v"}[p2] == "v"                  # usable as a dict key
    with pytest.raises(Exception):
        p1.engine = "other"                      # frozen
    if p1.decomposition is None:
        assert set(p1.gao) == set(q.variables)
    else:  # hybrid plans carry the cyclic-core GAO only
        assert set(p1.gao) == set(p1.decomposition.core_gao)
    assert p1.est_cost > 0
    assert p1.stats_fingerprint == stats.fingerprint()


@pytest.mark.parametrize("qname", PLAN_QUERIES)
def test_plan_cost_annotations(stats, qname):
    q = get_query(qname)
    p = plan_query(q, stats, engine="vlftj")
    assert len(p.levels) == len(p.gao)
    assert len(p.level_costs) == len(p.gao)
    assert p.agm_log2 is not None
    assert np.isfinite(p.agm_log2)


def test_planner_picks_cheapest_candidate(stats):
    q = get_query("3-path")
    plans = candidate_plans(q, stats)
    auto = plan_query(q, stats)
    assert auto.est_cost == min(p.est_cost for p in plans)


def test_candidate_gaos_include_legacy_pick():
    from repro.core import choose_gao
    for qname in PLAN_QUERIES:
        q = get_query(qname)
        assert choose_gao(q) in candidate_gaos(q)


def test_hybrid_decomposition_lives_in_planner():
    hp = decompose_hybrid(get_query("2-lollipop"))
    assert hp is not None
    assert hp.attachment == "c"
    assert hp.core_gao[0] == "c"
    assert decompose_hybrid(get_query("3-clique")) is None
    assert decompose_hybrid(get_query("3-path")) is None


# -- every engine executes an explicit plan ----------------------------------

@pytest.mark.parametrize("qname", PLAN_QUERIES)
@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_engine_agrees_on_explicit_plan(gdb, stats, oracle, qname, engine):
    from repro.core.yannakakis import NotTreeShaped
    q = get_query(qname)
    try:
        plan = plan_query(q, stats, engine=engine)
    except NotTreeShaped:
        assert engine == "yannakakis"   # only counts filter-free forests
        return
    assert plan.engine == engine
    assert execute(plan, gdb) == oracle[qname], (qname, engine)


@pytest.mark.parametrize("qname", PLAN_QUERIES)
def test_auto_plan_agrees(gdb, stats, oracle, qname):
    q = get_query(qname)
    plan = plan_query(q, stats, engine="auto")
    assert execute(plan, gdb) == oracle[qname], (qname, plan.engine)
    assert count(q, gdb, engine="auto") == oracle[qname]
    assert count(q, gdb, plan=plan) == oracle[qname]


def test_engines_accept_plan_constructor_kw(gdb, stats, oracle):
    """The six engine classes all take plan= directly."""
    from repro.core import (VLFTJ, LFTJ, BinaryJoin, CountingYannakakis,
                            HybridJoin, Minesweeper)
    db = gdb.to_database()
    q = get_query("3-clique")
    p = plan_query(q, stats, engine="vlftj")
    assert VLFTJ(q, gdb, plan=p).count() == oracle["3-clique"]
    assert LFTJ(q, db, plan=plan_query(q, stats, engine="lftj_ref")
                ).count() == oracle["3-clique"]
    assert Minesweeper(q, db, plan=plan_query(
        q, stats, engine="minesweeper_ref")).count() == oracle["3-clique"]
    assert BinaryJoin(q, db, plan=plan_query(
        q, stats, engine="binary")).count() == oracle["3-clique"]
    qt = get_query("3-path")
    assert CountingYannakakis(qt, gdb, plan=plan_query(
        qt, stats, engine="yannakakis")).count() == oracle["3-path"]
    ql = get_query("2-lollipop")
    assert HybridJoin(ql, gdb, plan=plan_query(
        ql, stats, engine="hybrid")).count() == oracle["2-lollipop"]


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(10, 30),
       density=st.integers(1, 4))
def test_property_planned_engines_agree(seed, n, density):
    rng = np.random.default_rng(seed)
    m = n * density
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        return
    g = CSRGraph.from_edges(src[keep], dst[keep], n_nodes=n)
    unary = {f"v{i}": rng.choice(n, max(1, n // 3), replace=False)
             for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    stats = GraphStats.of(gdb)
    for qname in ["3-clique", "4-cycle", "3-path", "2-comb", "2-lollipop"]:
        q = get_query(qname)
        ref = lftj_count(q, gdb.to_database())
        for engine in ("vlftj", "auto"):
            plan = plan_query(q, stats, engine=engine)
            assert execute(plan, gdb) == ref, (qname, plan.engine)


# -- routing ----------------------------------------------------------------

def test_pick_engine_structural_matches_paper_heuristic():
    assert pick_engine(get_query("3-clique")) == "vlftj"
    assert pick_engine(get_query("3-path")) == "yannakakis"
    assert pick_engine(get_query("2-lollipop")) == "hybrid"


def test_pick_engine_cost_based_routes_all(stats):
    for qname in PLAN_QUERIES:
        assert pick_engine(get_query(qname), stats) in ALL_ENGINES


# -- plan cache -------------------------------------------------------------

def test_plan_cache_hit_miss(stats):
    cache = PlanCache()
    q = get_query("4-cycle")
    p1 = cache.get_or_plan(q, stats)
    assert (cache.hits, cache.misses) == (0, 1)
    p2 = cache.get_or_plan(q, stats)
    assert (cache.hits, cache.misses) == (1, 1)
    assert p1 is p2
    # a different requested engine is a different entry
    cache.get_or_plan(q, stats, engine="vlftj")
    assert cache.misses == 2


def test_plan_cache_keyed_by_structure_not_name(stats):
    from repro.core import Query
    cache = PlanCache()
    q = get_query("3-clique")
    renamed = Query(q.atoms, q.filters, "same-shape-different-name")
    cache.get_or_plan(q, stats)
    cache.get_or_plan(renamed, stats)
    assert (cache.hits, cache.misses) == (1, 1)


def test_plan_cache_stats_fingerprint_invalidation():
    gdb_a = make_gdb(50, 3, seed=3)
    gdb_b = make_gdb(50, 3, seed=4)     # different graph + samples
    sa, sb = GraphStats.of(gdb_a), GraphStats.of(gdb_b)
    assert sa.fingerprint() != sb.fingerprint()
    cache = PlanCache()
    q = get_query("3-clique")
    cache.get_or_plan(q, sa)
    cache.get_or_plan(q, sb)            # stats changed -> replan
    assert (cache.hits, cache.misses) == (0, 2)
    cache.get_or_plan(q, sa)
    assert cache.hits == 1


def test_plan_cache_lru_eviction(stats):
    cache = PlanCache(maxsize=2)
    qs = [get_query(n) for n in ["3-clique", "4-cycle", "3-path"]]
    for q in qs:
        cache.get_or_plan(q, stats)
    assert len(cache) == 2
    cache.get_or_plan(qs[0], stats)     # evicted -> replanned
    assert cache.misses == 4


# -- server integration -----------------------------------------------------

def test_query_server_plan_cache_counter():
    from repro.graphs import powerlaw_cluster
    from repro.serve import QueryRequest, QueryServer
    srv = QueryServer(powerlaw_cluster(200, 3, seed=1))
    req = QueryRequest("3-clique", selectivity=8, seed=0)
    r1 = srv.execute(req)
    assert not r1.plan_cached
    r2 = srv.execute(req)
    assert r2.plan_cached                       # repeated shape: cache hit
    assert r1.count == r2.count
    info = srv.plan_cache_info()
    assert info["hits"] >= 1 and info["misses"] == 1


def test_query_server_execute_many_matches_batch():
    from repro.graphs import powerlaw_cluster
    from repro.serve import QueryRequest, QueryServer
    g = powerlaw_cluster(200, 3, seed=2)
    reqs = [QueryRequest(n, selectivity=8, seed=0)
            for n in ["3-clique", "3-path", "3-clique", "2-lollipop",
                      "3-path", "3-clique"]]
    srv_a, srv_b = QueryServer(g), QueryServer(g)
    batch = srv_a.execute_batch(list(reqs))
    many = srv_b.execute_many(list(reqs))
    assert [r.count for r in many] == [r.count for r in batch]
    assert [r.engine for r in many] == [r.engine for r in batch]
    # 3 distinct shapes -> 3 misses, the rest plan-cache hits
    info = srv_b.plan_cache_info()
    assert info["misses"] == 3 and info["hits"] == 3
