"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.intersect import intersect_count_pallas
from repro.kernels.ref import (flash_attention_ref, intersect_count_ref,
                               searchsorted_segments_ref)
from repro.kernels.searchsorted import searchsorted_segments_pallas

RNG = np.random.default_rng(0)


def _sorted_rows(r, width, max_len, domain):
    lens = RNG.integers(0, max_len + 1, r)
    arr = np.zeros((r, width), np.int32)
    for i in range(r):
        arr[i, :lens[i]] = np.sort(
            RNG.choice(domain, size=lens[i], replace=False))
    return arr, lens.astype(np.int32)


@pytest.mark.parametrize("m,r,w", [(64, 8, 128), (1000, 16, 128),
                                   (4096, 32, 256)])
def test_searchsorted_sweep(m, r, w):
    vals = np.sort(RNG.integers(0, 4 * m, m)).astype(np.int32)
    lo = RNG.integers(0, m // 2, (r, 1)).astype(np.int32)
    hi = (lo + RNG.integers(0, m // 2, (r, 1))).astype(np.int32)
    q = RNG.integers(0, 4 * m, (r, w)).astype(np.int32)
    n_iter = int(np.ceil(np.log2(m))) + 1
    p1, f1 = searchsorted_segments_ref(jnp.asarray(vals), jnp.asarray(lo),
                                       jnp.asarray(hi), jnp.asarray(q),
                                       n_iter=n_iter)
    p2, f2 = searchsorted_segments_pallas(
        jnp.asarray(vals), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(q), n_iter=n_iter)
    assert_allclose(np.asarray(p1), np.asarray(p2))
    assert_allclose(np.asarray(f1), np.asarray(f2))


def test_searchsorted_unroll_matches_loop():
    vals = np.sort(RNG.integers(0, 100, 64)).astype(np.int32)
    q = RNG.integers(0, 100, (8, 128)).astype(np.int32)
    lo = np.zeros((8, 1), np.int32)
    hi = np.full((8, 1), 64, np.int32)
    a = searchsorted_segments_ref(jnp.asarray(vals), lo, hi,
                                  jnp.asarray(q), n_iter=8, unroll=False)
    b = searchsorted_segments_ref(jnp.asarray(vals), lo, hi,
                                  jnp.asarray(q), n_iter=8, unroll=True)
    assert_allclose(np.asarray(a[0]), np.asarray(b[0]))
    assert_allclose(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("r,la,lb", [(8, 128, 128), (16, 256, 384),
                                     (24, 512, 128)])
def test_intersect_sweep(r, la, lb):
    a, alen = _sorted_rows(r, la, la - 5, 4000)
    b, blen = _sorted_rows(r, lb, lb - 5, 4000)
    c1 = intersect_count_ref(jnp.asarray(a), jnp.asarray(alen),
                             jnp.asarray(b), jnp.asarray(blen))
    c2 = intersect_count_pallas(jnp.asarray(a), jnp.asarray(alen),
                                jnp.asarray(b), jnp.asarray(blen))
    assert_allclose(np.asarray(c1), np.asarray(c2))
    # numpy oracle double-check
    for i in range(r):
        expect = np.intersect1d(a[i, :alen[i]], b[i, :blen[i]]).size
        assert int(np.asarray(c2)[i]) == expect


def test_intersect_disjoint_tiles_skip_path():
    """Tile pairs with disjoint value ranges take the gap-box skip branch
    (lax.cond) — counts must match the oracle exactly either way."""
    # A in [0, 512), B in [100000, 100512): every tile pair disjoint
    a = np.tile(np.arange(512, dtype=np.int32), (8, 1))
    b = a + 100000
    full = np.full(8, 512, np.int32)
    c = intersect_count_pallas(jnp.asarray(a), jnp.asarray(full),
                               jnp.asarray(b), jnp.asarray(full))
    assert np.asarray(c).sum() == 0
    # mixed: second half of B overlaps A's range
    b2 = np.concatenate([a[:, :256] + 100000, a[:, :256]], axis=1)
    b2 = np.sort(b2, axis=1)
    c2 = intersect_count_pallas(jnp.asarray(a), jnp.asarray(full),
                                jnp.asarray(b2), jnp.asarray(full))
    np.testing.assert_array_equal(np.asarray(c2), np.full(8, 256))


def test_intersect_empty_rows():
    a = np.zeros((8, 128), np.int32)
    b = np.zeros((8, 128), np.int32)
    alen = np.zeros(8, np.int32)
    blen = np.full(8, 100, np.int32)
    c = intersect_count_pallas(jnp.asarray(a), jnp.asarray(alen),
                               jnp.asarray(b), jnp.asarray(blen))
    assert np.asarray(c).sum() == 0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, hq, hkv, causal):
    b, t, d = 2, 256, 64
    q = jnp.asarray(RNG.standard_normal((b, hq, t, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, t, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, t, d)), dtype)
    o1 = flash_attention_ref(q, k, v, causal=causal)
    o2 = flash_attention_pallas(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert_allclose(np.asarray(o1, np.float32), np.asarray(o2, np.float32),
                    atol=tol, rtol=tol)


def test_flash_attention_decode_shape():
    """Tq=1 against a longer KV stream (decode step)."""
    b, hq, hkv, tk, d = 2, 4, 2, 256, 64
    q = jnp.asarray(RNG.standard_normal((b, hq, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    o1 = flash_attention_ref(q, k, v, causal=True)
    o2 = flash_attention_pallas(q, k, v, causal=True)
    assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)
