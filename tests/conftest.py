import importlib.util
import os

import pytest

import repro  # noqa: F401  (enables x64; device count stays at 1 here)
from repro.core import GraphDB
from repro.graphs import node_sample, powerlaw_cluster

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_lint_module():
    """Import ``tools/lint_repro.py`` (not a package) for rule-level
    tests and the static/runtime agreement guards."""
    path = os.path.join(REPO_ROOT, "tools", "lint_repro.py")
    spec = importlib.util.spec_from_file_location("lint_repro", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def make_gdb(n=60, m_per_node=3, seed=0, selectivity=4, n_samples=4):
    g = powerlaw_cluster(n, m_per_node, seed=seed)
    unary = {f"v{i}": node_sample(g.n_nodes, selectivity, seed=seed + i)
             for i in range(1, n_samples + 1)}
    return GraphDB(g, unary)


@pytest.fixture(scope="session")
def gdb_small():
    return make_gdb(40, 3, seed=1)


@pytest.fixture(scope="session")
def gdb_medium():
    return make_gdb(200, 4, seed=2)
