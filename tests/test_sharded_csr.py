"""Sharded CSR: owner-map/layout invariants, count parity vs the
replicated engines on every tier-1 query shape, and the SPMD ring step
(runs on however many devices the process has — 1 in tier-1, 8 in the
CI multidevice job)."""
import jax
import numpy as np
import pytest

from repro.core import GraphDB, GraphStats, count, get_query
from repro.core.plan import executor_geometry
from repro.core.vlftj import VLFTJ
from repro.dist.sharded_csr import (ShardedGraphDB, sharded_count,
                                    spmd_sharded_join_step)
from repro.graphs import node_sample, powerlaw_cluster, zipf_graph

TIER1_QUERIES = ("3-clique", "4-clique", "4-cycle", "3-path",
                 "2-lollipop", "3-lollipop")


@pytest.fixture(scope="module")
def graph():
    return powerlaw_cluster(300, 4, seed=11)


@pytest.fixture(scope="module")
def unary(graph):
    return {f"v{i}": node_sample(graph.n_nodes, 6, seed=i)
            for i in range(1, 5)}


@pytest.fixture(scope="module")
def gdb(graph, unary):
    return GraphDB(graph, unary)


def test_shard_layout_and_owner_map(graph):
    sg = ShardedGraphDB(graph, 4)
    # owner ranges cover the node domain exactly
    assert sg.bounds[0] == 0 and sg.bounds[-1] == graph.n_nodes
    assert np.all(np.diff(sg.bounds) >= 0)
    v = np.arange(graph.n_nodes)
    own = sg.owner_of(v)
    for s in range(4):
        in_range = (v >= sg.bounds[s]) & (v < sg.bounds[s + 1])
        assert np.array_equal(own == s, in_range)
    # per-shard pieces reassemble to the original CSR
    r = sg.replicated()
    assert np.array_equal(r.indptr, graph.indptr)
    assert np.array_equal(r.indices, graph.indices)
    # shard edges balance (the split criterion) and sum exactly
    nodes, edges = zip(*sg.shard_sizes)
    assert sum(nodes) == graph.n_nodes
    assert sum(edges) == graph.n_edges
    assert max(edges) <= graph.n_edges // 4 + graph.max_degree + 1


def test_sharded_accessors_match_csr(graph):
    sg = ShardedGraphDB(graph, 3)
    v = np.array([0, 7, 150, 299, 42])
    assert np.array_equal(sg.degrees_of(v), graph.degrees[v])
    deg, flat, reps = sg.gather_segments(v)
    offs = np.concatenate([[0], np.cumsum(deg)])
    for i, u in enumerate(v):
        assert np.array_equal(flat[offs[i]:offs[i + 1]],
                              graph.neighbors(int(u)))
        assert np.all(reps[offs[i]:offs[i + 1]] == i)
    assert sg.exchange["gathers"] >= 2
    assert sg.exchange["values"] == int(deg.sum())


def test_graph_stats_from_shards_only(graph, unary, gdb):
    sg = ShardedGraphDB(graph, 4, unary)
    assert sg.graph_stats() == GraphStats.of(gdb)


@pytest.mark.parametrize("qname", TIER1_QUERIES)
def test_sharded_count_parity_all_tier1_shapes(graph, unary, gdb, qname):
    """The acceptance property: the row-partitioned layout answers every
    benchmarked query shape with exactly the replicated-CSR count."""
    ref = count(get_query(qname), gdb, engine="vlftj")
    sg = ShardedGraphDB(graph, 4, unary)
    assert sharded_count(get_query(qname), sg) == ref
    assert sg.exchange["values"] > 0          # it really exchanged


def test_sharded_count_shard_count_invariance(graph, unary, gdb):
    ref = count(get_query("4-cycle"), gdb, engine="vlftj")
    for s in (1, 2, 7):
        assert sharded_count(
            get_query("4-cycle"), ShardedGraphDB(graph, s, unary)) == ref


def test_sharded_count_on_zipf_skew():
    g = zipf_graph(1500, 9000, alpha=1.4, seed=2)
    unary = {f"v{i}": node_sample(g.n_nodes, 6, seed=i)
             for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    ref = count(get_query("3-path"), gdb, engine="vlftj")
    assert sharded_count(get_query("3-path"),
                         ShardedGraphDB(g, 8, unary)) == ref


def test_spmd_sharded_join_step_matches_replicated():
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    g = powerlaw_cluster(400, 5, seed=1)
    gdb = GraphDB(g, {})
    ea = g.edge_array()
    fr = ea[ea[:, 0] < ea[:, 1]].astype(np.int32)
    mult = np.ones(len(fr), np.int64)
    width, _ = executor_geometry(gdb.max_degree)
    kw = dict(probe_cols=(0, 1), n_unary=0, lower_cols=(1,),
              upper_cols=(), width=width, n_iter=gdb.bsearch_iters,
              needs_degree=False)
    ref = VLFTJ(get_query("3-clique"), gdb).count()
    step = spmd_sharded_join_step(mesh, kw, ShardedGraphDB(g, n_dev))
    # frontier length is typically not a shard multiple: the wrapper
    # pads and zeroes the padded mult itself
    assert step(fr, mult) == ref


def test_spmd_sharded_join_step_rejects_mismatched_shards():
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    g = powerlaw_cluster(100, 3, seed=0)
    kw = dict(probe_cols=(0, 1), n_unary=0, lower_cols=(1,),
              upper_cols=(), width=8, n_iter=4, needs_degree=False)
    with pytest.raises(ValueError, match="sharded"):
        spmd_sharded_join_step(mesh, kw, ShardedGraphDB(g, n_dev + 1))
