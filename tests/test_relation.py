"""Sorted-array trie: range navigation + gaps vs numpy oracles."""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import Database, Relation
from repro.core.relation import NEG_INF, POS_INF


def test_dedup_and_sort():
    r = Relation(np.array([[3, 1], [1, 2], [3, 1], [1, 1]]))
    np.testing.assert_array_equal(
        r.data, np.array([[1, 1], [1, 2], [3, 1]]))


def test_child_range_and_contains():
    r = Relation(np.array([[1, 5], [1, 7], [2, 3], [4, 0]]))
    lo, hi = r.child_range(0, len(r), 0, 1)
    assert (lo, hi) == (0, 2)
    assert r.contains((1, 7))
    assert not r.contains((1, 6))
    assert not r.contains((3, 0))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=40),
       st.integers(0, 55))
def test_gap_around_oracle(values, probe):
    arr = np.array(sorted(set(values)))
    r = Relation(arr)
    l, rgt = r.gap_around(0, len(r), 0, probe)
    if probe in set(arr.tolist()):
        assert (l, rgt) == (probe, probe)
    else:
        lows = arr[arr < probe]
        highs = arr[arr > probe]
        assert l == (int(lows.max()) if lows.size else NEG_INF)
        assert rgt == (int(highs.min()) if highs.size else POS_INF)


def test_database_index_cache():
    r = Relation(np.array([[1, 5], [2, 3]]), "edge")
    db = Database({"edge": r})
    a = db.indexed("edge", (1, 0))
    b = db.indexed("edge", (1, 0))
    assert a is b
    np.testing.assert_array_equal(a.data, np.array([[3, 2], [5, 1]]))
