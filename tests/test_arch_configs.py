"""Per-architecture smoke tests (reduced configs, one real step on CPU)
+ abstract dry-run cell construction on a 1x1 mesh (shape plumbing)."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS

LM_ARCHS = ["stablelm-3b", "chatglm3-6b", "command-r-plus-104b",
            "moonshot-v1-16b-a3b", "granite-moe-3b-a800m"]
GNN_ARCHS = ["gatedgcn", "egnn", "pna", "mace"]


@pytest.mark.parametrize("arch_id", list(ARCHS))
def test_arch_smoke(arch_id):
    out = ARCHS[arch_id].smoke()
    for v in out.values():
        assert np.isfinite(v)


@pytest.mark.parametrize("arch_id", list(ARCHS))
def test_cells_constructible(arch_id):
    """Every (arch x shape) cell builds abstract args + shardings on a
    1x1 mesh (divisibility-independent plumbing check; the 256/512-chip
    lower+compile happens in launch/dryrun.py)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    arch = ARCHS[arch_id]
    for shape_name in arch.shapes:
        cell = arch.cell(shape_name, mesh)
        if cell.skip:
            assert arch_id in LM_ARCHS and shape_name == "long_500k"
            continue
        assert cell.fn is not None
        assert len(jax.tree.leaves(cell.args)) > 0
        assert cell.model_flops > 0


def test_lm_cell_counts():
    """35 runnable LM+GNN+recsys cells + 5 documented skips = 40
    (excluding the §Perf opt-variant shapes, which carry a 'base' key)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    runnable, skipped = 0, 0
    for arch_id in LM_ARCHS + GNN_ARCHS + ["xdeepfm"]:
        arch = ARCHS[arch_id]
        for shape_name, sh in arch.shapes.items():
            if isinstance(sh, dict) and "base" in sh:
                continue  # §Perf variant, not an assigned cell
            cell = arch.cell(shape_name, mesh)
            if cell.skip:
                skipped += 1
            else:
                runnable += 1
    assert runnable + skipped == 40
    assert skipped == 5  # the five full-attention long_500k cells
