"""Graph substrate vs networkx oracles."""
import networkx as nx
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import GraphDB, count, get_query
from repro.graphs import CSRGraph, load_edgelist, save_edgelist
from repro.graphs.csr import triangle_count_csr
from repro.graphs.generators import make_snap_like, powerlaw_cluster


def test_csr_build_symmetrize_dedup():
    g = CSRGraph.from_edges([0, 1, 1, 2], [1, 0, 2, 2])
    # loops dropped, dedup, symmetric
    assert g.n_edges == 4  # (0,1),(1,0),(1,2),(2,1)
    np.testing.assert_array_equal(g.neighbors(1), [0, 2])


def test_edge_array_is_sorted_relation():
    g = powerlaw_cluster(100, 3, seed=0)
    ea = g.edge_array()
    assert (np.diff(ea[:, 0]) >= 0).all()
    rel = g.to_relation()
    assert len(rel) == g.n_edges


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_triangle_count_matches_networkx(seed):
    G = nx.gnm_random_graph(40, 120, seed=seed)
    src = np.array([u for u, v in G.edges()] or [0])
    dst = np.array([v for u, v in G.edges()] or [0])
    g = CSRGraph.from_edges(src, dst, n_nodes=40)
    expect = sum(nx.triangles(G).values()) // 3
    assert triangle_count_csr(g) == expect
    gdb = GraphDB(g, {})
    assert count(get_query("3-clique"), gdb, engine="vlftj") == expect


def test_io_roundtrip(tmp_path):
    g = powerlaw_cluster(80, 3, seed=1)
    p = tmp_path / "edges.txt"
    save_edgelist(g, str(p))
    g2 = load_edgelist(str(p))
    assert g2.n_edges == g.n_edges
    assert triangle_count_csr(g2) == triangle_count_csr(g)


def test_snap_like_sizes():
    g = make_snap_like("ca-GrQc", scale=0.2)
    assert g.n_nodes > 500
    assert g.n_edges > 1000


def test_padded_neighbors():
    g = powerlaw_cluster(50, 3, seed=2)
    pn, mask = g.padded_neighbors(pad_to=8)
    assert pn.shape == (50, 8)
    for v in range(50):
        nbrs = g.neighbors(v)
        k = min(8, nbrs.shape[0])
        np.testing.assert_array_equal(pn[v, :k], nbrs[:k])
        assert mask[v].sum() == k
