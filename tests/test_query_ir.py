"""Query IR, hypergraph, NEO/GAO, and AGM-bound unit tests."""
import math

from _hypothesis_compat import given, settings, strategies as st

from repro.core import (Hypergraph, PAPER_QUERIES, agm_bound, all_neos,
                        choose_gao, fractional_edge_cover, get_query,
                        is_beta_acyclic, is_neo, parse)


def test_parse_roundtrip():
    q = parse("edge(a,b), edge(b,c), edge(a,c), a<b, b<c", "tri")
    assert q.num_vars == 3
    assert len(q.atoms) == 3
    assert len(q.filters) == 2


def test_acyclicity_classification():
    cyclic = {"3-clique", "4-clique", "4-cycle", "2-lollipop",
              "3-lollipop"}
    for name, mk in PAPER_QUERIES.items():
        hg = Hypergraph.of(mk())
        assert is_beta_acyclic(hg) == (name not in cyclic), name


def test_paper_neo_orders_4path():
    """Table 4's NEO vs non-NEO classification, verbatim."""
    q = get_query("4-path")
    hg = Hypergraph.of(q)
    for order in ["abcde", "bacde", "bcade", "cbade", "cbdae"]:
        assert is_neo(hg, tuple(order)), order
    for order in ["abdce", "badce"]:
        assert not is_neo(hg, tuple(order)), order


def test_choose_gao_prefers_long_path_neo():
    q = get_query("4-path")
    assert choose_gao(q) == tuple("abcde")


def test_all_neos_are_neos():
    for name in ["3-path", "1-tree", "2-comb", "2-tree"]:
        q = get_query(name)
        hg = Hypergraph.of(q)
        neos = all_neos(hg)
        assert neos, name
        for o in neos[:50]:
            assert is_neo(hg, o)


def test_agm_triangle_n_to_three_halves():
    q = get_query("3-clique")
    n = 10_000
    bound = agm_bound(q, {"edge": n})
    assert math.isclose(bound, n ** 1.5, rel_tol=1e-6)


def test_agm_cover_is_feasible():
    q = get_query("2-lollipop")
    sizes = {"edge": 5000, "v1": 100}
    x, _ = fractional_edge_cover(q, sizes)
    for v in q.variables:
        cover = sum(x[j] for j, a in enumerate(q.atoms) if v in a.vars)
        assert cover >= 1 - 1e-9, v


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 10 ** 6), m=st.integers(1, 10 ** 5))
def test_agm_path_bound_formula(n, m):
    """3-path bound = |v1|·|edge|·... LP must beat the trivial cover."""
    q = get_query("3-path")
    bound = agm_bound(q, {"edge": n, "v1": m, "v2": m})
    trivial = float(m) * n * m  # v1 ⋈ middle edge ⋈ v2 covers all vars
    assert bound <= trivial * 1.001
