"""Straggler policy + compressed DP training (subprocess, 8 devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.train.stragglers import StepTimeTracker, reassign_shards

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_step_time_tracker_flags_outliers():
    t = StepTimeTracker(window=20, threshold=3.0)
    flagged = [t.record(1.0 + 0.01 * (i % 3)) for i in range(30)]
    assert not any(flagged[10:])
    assert t.record(5.0)       # 5x median -> straggler
    assert not t.record(1.01)  # back to normal


def test_reassign_shards_covers_everything():
    plan = reassign_shards(8, dead={2, 5}, granularity=4)
    all_parts = sorted(p for parts in plan.values() for p in parts)
    assert all_parts == list(range(32))
    assert 2 not in plan and 5 not in plan
    loads = [len(v) for v in plan.values()]
    assert max(loads) - min(loads) <= 2  # balanced re-deal


def test_compressed_training_converges():
    pytest.importorskip("repro.dist", reason="repro.dist not implemented")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    body = """
        import jax, jax.numpy as jnp, numpy as np
        import repro
        from repro.models.transformer import (TransformerConfig,
                                              init_params, loss_fn)
        from repro.train.optimizer import OptimizerConfig, init_opt_state
        from repro.train.loop import make_train_step
        from repro.dist.compressed_step import (make_compressed_train_step,
                                                init_compressed_state)
        cfg = TransformerConfig(name='t', n_layers=2, d_model=64,
                                n_heads=4, n_kv_heads=2, d_ff=128,
                                vocab_size=256, dtype=jnp.float32,
                                remat=False)
        mesh = jax.make_mesh((8,), ('data',))
        lf = lambda p, b: loss_fn(p, b, cfg)
        oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=30)
        p0 = init_params(jax.random.PRNGKey(0), cfg)

        def run(compressed):
            p = jax.tree.map(jnp.copy, p0)
            opt = init_opt_state(p)
            err = init_compressed_state(p)
            step_c = make_compressed_train_step(lf, oc, mesh)
            step_u = jax.jit(make_train_step(lf, oc))
            losses = []
            for s in range(25):
                rng = np.random.default_rng(s)
                toks = rng.integers(0, 64, (16, 32), dtype=np.int32)
                batch = {'tokens': toks, 'labels': (toks * 3 + 7) % 256}
                if compressed:
                    p, opt, err, m = step_c(p, opt, err, batch)
                else:
                    p, opt, m = step_u(p, opt, batch)
                losses.append(float(m['loss']))
            return losses

        lc = run(True)
        lu = run(False)
        print('compressed first/last', lc[0], lc[-1])
        print('uncompressed first/last', lu[0], lu[-1])
        assert lc[-1] < lc[0] * 0.8, 'compressed run must learn'
        assert abs(lc[-1] - lu[-1]) < 0.35 * lu[0], 'trajectories close'
        print('OK')
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
