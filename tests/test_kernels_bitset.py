"""Bitset intersection kernels vs numpy set oracles.

Both Pallas kernels (interpret mode) and their jnp references must agree
with ``np.intersect1d`` on randomly packed neighborhoods, including the
padding identities (zero words for AND+popcount, masked lanes for the
gather-test kernel).
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops as kops
from repro.kernels.intersect_bitset import (bitset_intersect_count_pallas,
                                            bitset_member_count_pallas)
from repro.kernels.ref import (bitset_intersect_count_ref, bitset_member_ref,
                               bitset_member_count_ref, popcount32)


def _pack(sets, n_words):
    """Pack a list of sorted id arrays into (R, n_words) uint32 rows."""
    words = np.zeros((len(sets), n_words), dtype=np.uint32)
    for i, s in enumerate(sets):
        s = np.asarray(s, dtype=np.int64)
        np.bitwise_or.at(words[i], s >> 5,
                         np.uint32(1) << (s & 31).astype(np.uint32))
    return words


def _rand_sets(rng, rows, domain, max_size):
    return [np.unique(rng.integers(0, domain,
                                   int(rng.integers(0, max_size + 1))))
            for _ in range(rows)]


def test_popcount32_matches_bit_count():
    rng = np.random.default_rng(0)
    v = rng.integers(0, 1 << 32, 256, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(popcount32(jnp.asarray(v)))
    want = np.array([bin(x).count("1") for x in v.tolist()])
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), rows=st.sampled_from([8, 16]),
       tile=st.sampled_from([128, 256]))
def test_bitset_intersect_count_vs_intersect1d(seed, rows, tile):
    rng = np.random.default_rng(seed)
    n_words = tile  # domain = 32 * tile ids, one word tile per grid step
    domain = 32 * n_words
    a_sets = _rand_sets(rng, rows, domain, 600)
    b_sets = _rand_sets(rng, rows, domain, 600)
    a, b = _pack(a_sets, n_words), _pack(b_sets, n_words)
    want = np.array([len(np.intersect1d(x, y))
                     for x, y in zip(a_sets, b_sets)])
    got_p = np.asarray(bitset_intersect_count_pallas(
        jnp.asarray(a), jnp.asarray(b), tile=tile))
    got_r = np.asarray(bitset_intersect_count_ref(
        jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got_p, want)
    np.testing.assert_array_equal(got_r, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), rows=st.sampled_from([8, 16]))
def test_bitset_member_count_vs_intersect1d(seed, rows):
    rng = np.random.default_rng(seed)
    n_words, lb = 64, 256
    domain = 32 * n_words
    w_sets = _rand_sets(rng, rows, domain, 500)
    b_sets = _rand_sets(rng, rows, domain, lb)
    words = _pack(w_sets, n_words)
    b = np.zeros((rows, lb), dtype=np.int32)
    b_len = np.zeros(rows, dtype=np.int32)
    for i, s in enumerate(b_sets):
        b[i, :len(s)] = s
        b_len[i] = len(s)
        b[i, len(s):] = 7  # poison the padding: must be masked out
    want = np.array([len(np.intersect1d(x, y))
                     for x, y in zip(w_sets, b_sets)])
    got_p = np.asarray(bitset_member_count_pallas(
        jnp.asarray(words), jnp.asarray(b), jnp.asarray(b_len)))
    got_r = np.asarray(bitset_member_count_ref(
        jnp.asarray(words), jnp.asarray(b), jnp.asarray(b_len)))
    np.testing.assert_array_equal(got_p, want)
    np.testing.assert_array_equal(got_r, want)


def test_bitset_member_mask():
    words = _pack([[0, 5, 37], [1]], 4)
    q = np.array([[0, 1, 5, 37], [0, 1, 5, 37]], dtype=np.int32)
    got = np.asarray(bitset_member_ref(jnp.asarray(words), jnp.asarray(q)))
    np.testing.assert_array_equal(
        got, [[True, False, True, True], [False, True, False, False]])


def test_zero_padding_is_identity():
    """Zero words contribute nothing to AND+popcount; a zero-length
    array row counts zero even when its buffer is non-zero."""
    a = _pack([[1, 2, 3]], 8)
    b = _pack([[2, 3, 4]], 8)
    assert int(bitset_intersect_count_pallas(
        jnp.asarray(a), jnp.asarray(b), rows_per_blk=1, tile=8)[0]) == 2
    buf = np.full((1, 128), 2, dtype=np.int32)
    assert int(bitset_member_count_pallas(
        jnp.asarray(a), jnp.asarray(buf),
        jnp.asarray(np.zeros(1, np.int32)), rows_per_blk=1)[0]) == 0


@pytest.mark.parametrize("use_pallas", [False, True])
def test_ops_wrappers_route_both_paths(use_pallas, monkeypatch):
    monkeypatch.setattr(kops, "_USE_PALLAS", use_pallas)
    rng = np.random.default_rng(3)
    a_sets = _rand_sets(rng, 8, 32 * 128, 300)
    b_sets = _rand_sets(rng, 8, 32 * 128, 300)
    a, b = _pack(a_sets, 128), _pack(b_sets, 128)
    want = np.array([len(np.intersect1d(x, y))
                     for x, y in zip(a_sets, b_sets)])
    np.testing.assert_array_equal(
        np.asarray(kops.bitset_intersect_count(jnp.asarray(a),
                                               jnp.asarray(b))), want)
    lb = 128
    arr = np.zeros((8, lb), np.int32)
    alen = np.zeros(8, np.int32)
    for i, s in enumerate(b_sets):
        s = s[:lb]
        arr[i, :len(s)] = s
        alen[i] = len(s)
    want2 = np.array([len(np.intersect1d(x, y[:lb]))
                      for x, y in zip(a_sets, b_sets)])
    np.testing.assert_array_equal(
        np.asarray(kops.bitset_member_count(
            jnp.asarray(a), jnp.asarray(arr), jnp.asarray(alen))), want2)
