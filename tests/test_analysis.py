"""Static analysis (repro.analysis) + repo lint (tools/lint_repro.py).

The contract under test, in tiers:

* **Rejection** — the verifier rejects every seeded malformed plan
  (uncovered GAO var, bitset level without layout metadata, wrong
  ``bitset_words``, unserializable ``level_callback``, shape/dtype
  drift, over-budget recompilation, …) with the documented rule id.
* **Acceptance** — planner output for all six tier-1 shapes passes with
  zero errors, both against the synthetic CI stats profiles and against
  a real graph through ``verify_for_execution``.
* **Enforcement** — ``engine.count(plan=...)`` raises
  ``PlanVerificationError`` on a rejected plan; ``verify=False``
  bypasses; ``explain_analyze`` surfaces the findings without raising.
* **Recompile auditor** — the statically-enumerated compile-key count
  upper-bounds the ``DeviceProfile`` compiles observed on a real run
  (the acceptance criterion keeping the shape model honest).
* **Lint** — every rule fires on its bad fixture, stays quiet on its
  good one, honors ``# repro: noqa-<rule>``, and the repo itself lints
  clean of errors.
"""
import ast
import dataclasses
import json
import types

import numpy as np
import pytest

from repro.analysis import (DEFAULT_RECOMPILE_BUDGET, Finding, FindingReport,
                            PlanVerificationError, audit_recompilation,
                            check_runtime, filter_suppressed,
                            filters_quotient_automorphism,
                            verify_for_execution, verify_plan,
                            verify_snapshot)
from repro.analysis.__main__ import (STATS_PROFILES, TIER1_SHAPES,
                                     self_test as tier1_self_test,
                                     tier1_plans)
from repro.analysis.recompile import chunk_shape_count
from repro.core import (GraphStats, HybridGraphDB, count, execute_stats,
                        get_query, plan_query)
from repro.graphs import powerlaw_cluster
from repro.obs import DeviceProfile, explain_analyze

from conftest import load_lint_module, make_gdb

HYBRID_STATS = STATS_PROFILES["hybrid"]
ARRAY_STATS = STATS_PROFILES["array"]


@pytest.fixture(scope="module")
def gdb():
    return make_gdb(60, 3, seed=5)


@pytest.fixture(scope="module")
def good_plan():
    return plan_query(get_query("3-clique"), HYBRID_STATS, engine="vlftj")


def errors_of(findings):
    return sorted({f.rule for f in findings if f.severity == "error"})


# ---------------------------------------------------------------------------
# rejection: seeded malformed plans (the >= 6 of the acceptance gate)
# ---------------------------------------------------------------------------

def test_rejects_uncovered_gao_variable(good_plan):
    bad = dataclasses.replace(good_plan, gao=good_plan.gao[:-1],
                              levels=good_plan.levels)
    assert "V101" in errors_of(verify_plan(bad, HYBRID_STATS))


def test_rejects_repeated_gao_variable(good_plan):
    bad = dataclasses.replace(good_plan, gao=(good_plan.gao[0],) * 3,
                              levels=good_plan.levels)
    assert "V101" in errors_of(verify_plan(bad, HYBRID_STATS))


def test_rejects_bitset_level_without_layout(good_plan):
    bad = dataclasses.replace(
        good_plan, level_layouts=("bitset",) * len(good_plan.gao))
    # the array profile carries no hub/bitset metadata
    assert "V105" in errors_of(verify_plan(bad, ARRAY_STATS))


def test_rejects_wrong_bitset_words(good_plan):
    plan = dataclasses.replace(
        good_plan, level_layouts=("mixed",) * len(good_plan.gao))
    # 1 word spans 32 vertex slots << 10k nodes: membership reads OOB
    stats = dataclasses.replace(HYBRID_STATS, bitset_words=1)
    assert "V105" in errors_of(verify_plan(plan, stats))


def test_rejects_unserializable_callback(good_plan):
    jnp = pytest.importorskip("jax.numpy")
    pinned = jnp.arange(4)

    def cb(level, frontier, mult):
        assert pinned is not None       # closes over a device array
        return None

    bad = good_plan.with_level_callback(cb)
    found = verify_plan(bad, HYBRID_STATS)
    assert "V108" in errors_of(found)
    assert any("pinned" in f.message for f in found if f.rule == "V108")


def test_rejects_wrong_arity_callback(good_plan):
    bad = good_plan.with_level_callback(lambda: None)
    assert "V108" in errors_of(verify_plan(bad, HYBRID_STATS))


def test_rejects_nonfinite_estimate_drift(good_plan):
    k = len(good_plan.gao)
    bad = dataclasses.replace(good_plan,
                              level_est_rows=(float("nan"),) * k)
    assert "V104" in errors_of(verify_plan(bad, HYBRID_STATS))


def test_rejects_growth_after_empty_frontier(good_plan):
    k = len(good_plan.gao)
    bad = dataclasses.replace(good_plan,
                              level_est_rows=(0.0,) + (5.0,) * (k - 1))
    assert "V104" in errors_of(verify_plan(bad, HYBRID_STATS))


def test_rejects_int32_overflowing_graph(good_plan):
    stats = dataclasses.replace(HYBRID_STATS, n_nodes=2 ** 31)
    assert "V104" in errors_of(verify_plan(good_plan, stats))


def test_rejects_over_budget_recompilation(good_plan):
    found = verify_plan(good_plan, HYBRID_STATS, recompile_budget=1)
    assert "V107" in errors_of(found)


def test_rejects_unbounded_paging(good_plan):
    found = verify_plan(good_plan, HYBRID_STATS, paging_configs=None)
    assert "V107" in errors_of(found)
    assert any("unbounded" in f.message for f in found
               if f.rule == "V107")


def test_rejects_hand_edited_levels(good_plan):
    bad = dataclasses.replace(good_plan,
                              levels=tuple(reversed(good_plan.levels)))
    assert "V102" in errors_of(verify_plan(bad, HYBRID_STATS))


def test_rejects_unknown_output_mode(good_plan):
    bad = dataclasses.replace(good_plan, output_mode="tuples",
                              levels=good_plan.levels)
    assert "V109" in errors_of(verify_plan(bad, HYBRID_STATS))


def test_rejects_foreign_yannakakis_root():
    plan = plan_query(get_query("3-path"), ARRAY_STATS,
                      engine="yannakakis")
    bad = dataclasses.replace(plan, root="zz")
    assert "V102" in errors_of(verify_plan(bad, ARRAY_STATS))


def test_module_self_test_gate_fires():
    """`python -m repro.analysis --self-test` proves the gate can fail."""
    assert tier1_self_test() == 0


# ---------------------------------------------------------------------------
# acceptance: tier-1 planner output verifies clean
# ---------------------------------------------------------------------------

def test_tier1_static_profiles_verify_clean():
    n = 0
    for label, plan, stats in tier1_plans():
        n += 1
        found = verify_plan(plan, stats)
        assert errors_of(found) == [], (label, found)
    assert n >= len(TIER1_SHAPES) * 2       # both profiles covered


@pytest.mark.parametrize("shape", TIER1_SHAPES)
def test_tier1_shapes_verify_on_real_db(gdb, shape):
    plan = plan_query(get_query(shape), GraphStats.of(gdb), engine="auto")
    findings = verify_for_execution(plan, gdb)      # must not raise
    assert errors_of(findings) == []
    # memoized second pass agrees
    assert verify_for_execution(plan, gdb) == findings


# ---------------------------------------------------------------------------
# enforcement: engine / explain integration
# ---------------------------------------------------------------------------

def test_count_rejects_and_verify_false_bypasses(gdb):
    jnp = pytest.importorskip("jax.numpy")
    q = get_query("3-clique")
    plan = plan_query(q, GraphStats.of(gdb), engine="vlftj")
    pinned = jnp.arange(3)

    def cb(level, frontier, mult):
        assert pinned is not None
        return None

    bad = plan.with_level_callback(cb)
    with pytest.raises(PlanVerificationError) as ei:
        count(q, gdb, plan=bad)
    assert any(f.rule == "V108" for f in ei.value.findings)
    # bypass executes fine (the callback itself is harmless at runtime)
    assert count(q, gdb, plan=bad, verify=False) == count(q, gdb, plan=plan)


def test_explain_analyze_surfaces_instead_of_raising(gdb):
    jnp = pytest.importorskip("jax.numpy")
    q = get_query("3-clique")
    plan = plan_query(q, GraphStats.of(gdb), engine="vlftj")
    pinned = jnp.arange(3)

    def cb(level, frontier, mult):
        assert pinned is not None
        return None

    res = explain_analyze(q, gdb, plan=plan.with_level_callback(cb))
    assert any(f.rule == "V108" and f.severity == "error"
               for f in res.verification)
    assert "V108" in res.render()
    assert res.count == count(q, gdb, plan=plan)


def test_renumbering_caveat_warns_same_db_errors_cross_db():
    """V106: the HybridGraphDB renumbering caveat.  4-cycle's a<b<c<d
    chain slices the id space (not an automorphism quotient), so on a
    renumbered db it is a warning — and an *error* when the plan's
    stats fingerprint shows it was costed against a different graph."""
    csr = powerlaw_cluster(n=120, m_per_node=3, seed=3)
    hdb = HybridGraphDB.build(csr, {"v1": np.arange(0, 120, 7)})
    stats = GraphStats.of(hdb)
    plan = plan_query(get_query("4-cycle"), stats, engine="vlftj")
    same = verify_plan(plan, stats, hdb)
    assert "V106" not in errors_of(same)
    assert any(f.rule == "V106" and f.severity == "warning" for f in same)
    stale = dataclasses.replace(plan, stats_fingerprint="f" * 16)
    assert "V106" in errors_of(verify_plan(stale, stats, hdb))
    # identity numbering: no caveat at all
    flat = HybridGraphDB.build(csr, {"v1": np.arange(0, 120, 7)},
                               renumber=False)
    assert not any(f.rule == "V106"
                   for f in verify_plan(plan, GraphStats.of(flat), flat))


def test_filters_quotient_automorphism_classification():
    assert filters_quotient_automorphism(get_query("3-clique"))
    assert filters_quotient_automorphism(get_query("4-clique"))
    assert filters_quotient_automorphism(get_query("2-lollipop"))
    assert filters_quotient_automorphism(get_query("3-path"))  # no filters
    assert not filters_quotient_automorphism(get_query("4-cycle"))


# ---------------------------------------------------------------------------
# recompile auditor: arithmetic + the runtime cross-check
# ---------------------------------------------------------------------------

def test_chunk_shape_count_arithmetic():
    assert chunk_shape_count(8) == 1
    assert chunk_shape_count(8192) == 11        # 8,16,...,8192
    assert chunk_shape_count(8192 + 1) == 12    # non-pow2 cap adds itself


def test_host_engines_audit_zero_keys():
    for engine in ("lftj_ref", "minesweeper_ref", "binary"):
        plan = plan_query(get_query("3-clique"), ARRAY_STATS,
                          engine=engine)
        audit = audit_recompilation(plan, ARRAY_STATS)
        assert audit.total == 0 and audit.within_budget


def test_spmd_multiplies_keys(good_plan):
    one = audit_recompilation(good_plan, HYBRID_STATS, n_devices=1)
    four = audit_recompilation(good_plan, HYBRID_STATS, n_devices=4)
    assert four.total == one.total * 4
    assert four.spmd == one.total * 3


def test_check_runtime_flags_model_drift(good_plan):
    audit = audit_recompilation(good_plan, HYBRID_STATS)
    fake = types.SimpleNamespace(jit={"compiles": audit.total + 1})
    drift = check_runtime(audit, fake)
    assert drift is not None and drift.rule == "V107"
    ok = types.SimpleNamespace(jit={"compiles": audit.total})
    assert check_runtime(audit, ok) is None


@pytest.mark.parametrize("engine,shape", [("vlftj", "3-clique"),
                                          ("vlftj", "4-cycle"),
                                          ("hybrid", "2-lollipop"),
                                          ("yannakakis", "3-path")])
def test_static_bound_covers_observed_compiles(gdb, engine, shape):
    """Acceptance: the auditor's static key count upper-bounds the
    DeviceProfile compile count on a real run."""
    q = get_query(shape)
    stats = GraphStats.of(gdb)
    plan = plan_query(q, stats, engine=engine)
    audit = audit_recompilation(plan, stats)
    prof = DeviceProfile(shape, engine)
    with prof.activate():
        execute_stats(plan, gdb)
    assert prof.jit["compiles"] <= audit.total, \
        (engine, shape, prof.jit, audit)
    assert check_runtime(audit, prof) is None


# ---------------------------------------------------------------------------
# snapshot conformance (V110)
# ---------------------------------------------------------------------------

def _snap(**kw):
    base = dict(frontier=np.zeros((3, 2), np.int32),
                mult=np.ones(3, np.int64), level=1)
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_snapshot_conformance():
    assert verify_snapshot(_snap()) == []
    assert any(f.rule == "V110" for f in verify_snapshot(_snap(mult=None)))
    assert any("dtype=object" in f.message for f in verify_snapshot(
        _snap(frontier=np.array([object()], dtype=object))))
    assert any(f.rule == "V110" for f in verify_snapshot(_snap(level=-1)))


def test_snapshot_rejects_device_arrays():
    jnp = pytest.importorskip("jax.numpy")
    found = verify_snapshot(_snap(frontier=jnp.zeros((3, 2), np.int32)))
    assert any(f.rule == "V110" and "device array" in f.message
               for f in found)


# ---------------------------------------------------------------------------
# findings plumbing
# ---------------------------------------------------------------------------

def test_report_json_and_gate():
    rep = FindingReport([
        Finding("V101", "error", "p", 1, "boom"),
        Finding("V103", "warning", "p", 2, "meh", hint="h")])
    assert not rep.gate_passes
    doc = json.loads(rep.to_json(job="t"))
    assert doc["n_findings"] == 2 and doc["n_errors"] == 1
    assert doc["gate"] == "fail" and doc["job"] == "t"
    assert doc["findings"][0]["rule"] == "V101"
    assert FindingReport([rep.findings[1]]).gate_passes


def test_noqa_suppression_filters_by_line():
    src = "x = 1\ny = 2  # repro: noqa-V101\n"
    fs = [Finding("V101", "error", "f.py", 2, "m"),
          Finding("V101", "error", "f.py", 1, "m")]
    kept = filter_suppressed(fs, {"f.py": src})
    assert [f.line for f in kept] == [1]


# ---------------------------------------------------------------------------
# lint rules (tools/lint_repro.py)
# ---------------------------------------------------------------------------

lint = load_lint_module()


@pytest.mark.parametrize("rule", lint.RULES, ids=lambda r: r.id)
def test_lint_rule_fixtures(rule):
    """Every rule fires on its bad fixture, stays quiet on its good."""
    if isinstance(rule, lint.UnusedPublicSymbols):
        bad = rule.check_repo(
            {rule.fixture_path: (ast.parse(rule.bad), rule.bad)},
            {rule.fixture_path: rule.bad})
        good = rule.check_repo(
            {rule.fixture_path: (ast.parse(rule.good), rule.good)},
            {rule.fixture_path: rule.good,
             "tests/test_x.py": "used_helper()\n"})
    else:
        assert rule.applies(rule.fixture_path), rule.id
        bad = rule.check(ast.parse(rule.bad), rule.fixture_path, rule.bad)
        good = rule.check(ast.parse(rule.good), rule.fixture_path,
                          rule.good)
    assert bad, f"{rule.id}: bad fixture did not fire"
    assert all(f.rule == rule.id for f in bad)
    assert not good, f"{rule.id}: good fixture fired: {good}"


def test_lint_noqa_suppresses():
    rule = lint.SnapshotNoPickle()
    src = ("import numpy as np\n\n"
           "def to_bytes(arr, buf):\n"
           "    np.save(buf, arr)  # repro: noqa-snapshot-no-pickle\n")
    raw = rule.check(ast.parse(src), rule.fixture_path, src)
    assert raw
    assert filter_suppressed(raw, {rule.fixture_path: src}) == []


def test_lint_self_test_gate_fires():
    assert lint.self_test() == 0


def test_repo_lints_clean_of_errors():
    """The repo's own invariants hold (satellite 1 fixed every true
    positive; satellite 2 deleted the dead symbols)."""
    report, _ = lint.run_lint()
    assert report.errors() == [], [f.format() for f in report.errors()]
    assert report.gate_passes
    # the dead-code pass stays quiet too: public symbols are referenced
    assert [f for f in report.findings
            if f.rule == "unused-public-symbol"] == []
