"""Device profiling (repro.obs.profile) + bench history/regression gate.

The contract under test, in tiers:

* **Zero-cost when off** — with no active profile the vlftj dispatch
  meters (chunks / ll_calls / candidates / kernel_dispatches) are
  identical to a run that never heard of profiling; same discipline as
  the PR 8 tracer guard.
* **Faithful when on** — an active profile sees every kernel dispatch
  (calls match the engine's own meters), buckets wall into the known
  kernel families, samples live-buffer memory at level boundaries, and
  publishes into the trace/metrics surfaces.
* **Attribution** — scheduler quanta label AOT compiles
  (``sched-<job>/q<k>``), the pool records per-worker spans, the server
  stamps one ``trace_id`` through the request log, trace, and profile.
* **Isolation** — two concurrently scheduled traced queries keep their
  per-level observations apart (contextvar activation per quantum).
* **Bench history** — ``BenchRecord`` normalizes every bench row;
  ``tools/bench_compare.py`` passes on a clean clone, fails on an
  injected wall regression or count drift, and its ``--self-test``
  proves the gate can fail.
"""
import json
import pathlib
import re
import subprocess
import sys
import time

import pytest

from repro.core import GraphStats, count, execute_stats, get_query, plan_query
from repro.obs import (KERNEL_FAMILIES, DeviceProfile, MetricsRegistry,
                       NullProfile, QueryTrace, current_profile)
from repro.graphs import powerlaw_cluster
from repro.serve import QuantumScheduler, QueryRequest, QueryServer

from conftest import make_gdb

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def gdb():
    return make_gdb(60, 3, seed=5)


# ---------------------------------------------------------------------------
# contextvar plumbing
# ---------------------------------------------------------------------------

def test_profile_inactive_by_default():
    assert current_profile() is None
    p = DeviceProfile("q", "vlftj")
    with p.activate():
        assert current_profile() is p
        with DeviceProfile().activate() as inner:
            assert current_profile() is inner
        assert current_profile() is p
    assert current_profile() is None


def test_null_profile_is_inert():
    n = NullProfile()
    n.record_jit_call()
    n.record_compile("k", 1.0)
    n.record_kernel("intersect", 1.0)
    n.sample_memory()
    with n.activate():
        assert current_profile() is None       # never installed
    assert n.to_dict() == {}


# ---------------------------------------------------------------------------
# zero-dispatch guard (the whole point)
# ---------------------------------------------------------------------------

def test_disabled_profile_adds_zero_device_dispatches(gdb):
    """Profiling on vs off: identical vlftj dispatch meters and count —
    the hooks are host clock reads around dispatches that happen
    anyway, never new device work."""
    q = get_query("4-cycle")
    plan = plan_query(q, GraphStats.of(gdb), engine="vlftj")
    assert current_profile() is None
    c_off, off = execute_stats(plan, gdb)
    prof = DeviceProfile("4-cycle", "vlftj")
    with prof.activate():
        c_on, on = execute_stats(plan, gdb)
    assert c_on == c_off
    for meter in ("chunks", "ll_calls", "candidates"):
        assert on["raw"][meter] == off["raw"][meter], meter
    assert on["kernel_dispatches"] == off["kernel_dispatches"]
    assert on["jit_calls"] == off["jit_calls"]
    # static agreement: the obs harvest path (trace/schema/metrics) is
    # jax-free per the obs-device-free lint pass, so turning profiling
    # on cannot introduce device work through the harvest side either
    import ast as ast_mod
    import os
    from conftest import REPO_ROOT, load_lint_module
    lint = load_lint_module()
    rule = lint.ObsHostPurity()
    for rel in rule.scope:
        src = open(os.path.join(REPO_ROOT, rel), encoding="utf-8").read()
        assert rule.check(ast_mod.parse(src), rel, src) == [], rel


# ---------------------------------------------------------------------------
# faithful accounting when on
# ---------------------------------------------------------------------------

def test_profile_harvests_kernels_and_memory(gdb):
    plan = plan_query(get_query("3-clique"), GraphStats.of(gdb),
                      engine="vlftj")
    prof = DeviceProfile("3-clique", "vlftj")
    with prof.activate():
        c, stats = execute_stats(plan, gdb)
    assert c == count(get_query("3-clique"), gdb, engine="lftj_ref")
    # every chunk/final dispatch the engine metered is a recorded call
    assert prof.jit["calls"] == stats["raw"]["chunks"] \
        + stats["raw"]["ll_calls"]
    assert set(prof.kernels) <= set(KERNEL_FAMILIES)
    assert "intersect" in prof.kernels
    assert prof.kernels["intersect"]["calls"] >= 1
    assert prof.kernel_wall_s() > 0.0
    assert prof.kernel_wall_s("intersect") > 0.0
    assert prof.kernel_wall_s("nope") == 0.0
    # memory watermark sampled at level boundaries, metadata only
    assert prof.memory["samples"] >= 1
    assert prof.memory["peak_live_bytes"] > 0
    assert prof.memory["peak_live_buffers"] >= 1
    # export is JSON-safe
    d = json.loads(json.dumps(prof.to_dict()))
    assert d["meta"]["query"] == "3-clique"
    assert d["jit"]["calls"] == prof.jit["calls"]


def test_profile_segment_outer_on_rows_path():
    """Row enumeration goes through the cursor's segment_expand — the
    third kernel family shows up only on the rows path."""
    csr = powerlaw_cluster(n=200, m_per_node=3, seed=1)
    server = QueryServer(csr)
    prof = DeviceProfile("3-path", "vlftj")
    with prof.activate():
        res = server.execute(QueryRequest("3-path", engine="vlftj",
                                          limit=200))
    assert res.count > 0
    assert "segment_outer" in prof.kernels
    assert prof.kernels["segment_outer"]["calls"] >= 1


def test_profile_publish_into_trace_and_registry(gdb):
    plan = plan_query(get_query("3-clique"), GraphStats.of(gdb),
                      engine="vlftj")
    prof = DeviceProfile("3-clique", "vlftj")
    tr = QueryTrace("3-clique", plan.gao, "vlftj")
    with tr.activate(), prof.activate():
        execute_stats(plan, gdb)
    reg = MetricsRegistry()
    prof.publish(trace=tr, registry=reg)
    names = [s["name"] for s in tr.spans]
    assert "profile/jit" in names
    assert any(n.startswith("profile/kernel/") for n in names)
    assert tr.summary["peak_live_bytes"] == prof.memory["peak_live_bytes"]
    snap = reg.snapshot()
    assert snap["profile_jit_calls"] == prof.jit["calls"]
    assert snap["profile_peak_live_bytes"] == prof.memory["peak_live_bytes"]
    assert snap["profile_kernel_seconds_count{family=intersect}"] == 1


# ---------------------------------------------------------------------------
# attribution: scheduler quanta, pool workers, server trace ids
# ---------------------------------------------------------------------------

def test_scheduler_attributes_compiles_to_quanta():
    csr = powerlaw_cluster(n=300, m_per_node=4, seed=0)
    server = QueryServer(csr, page_rows=256)
    sched = QuantumScheduler(server, quantum_rows=64)
    sched.submit(QueryRequest("3-path", engine="vlftj", profile=True))
    (res,) = sched.run()
    prof = res.profile
    assert prof is not None
    assert res.count == count(
        get_query("3-path"),
        server._gdb_for(server.default_selectivity, 0), engine="vlftj")
    assert prof.jit["compiles"] >= 1
    assert prof.jit["compile_wall_s"] > 0.0
    assert len(prof.compile_events) == prof.jit["compiles"]
    for ev in prof.compile_events:
        assert re.fullmatch(r"sched-\d+/q\d+", ev["attribution"])
        assert ev["wall_s"] > 0.0
    # unprofiled request: no profile object, same count
    s2 = QuantumScheduler(QueryServer(csr, page_rows=256), quantum_rows=64)
    s2.submit(QueryRequest("3-path", engine="vlftj"))
    (r2,) = s2.run()
    assert r2.profile is None and r2.count == res.count


def test_pool_records_worker_spans():
    from repro.dist.pool import WorkerPool
    prof = DeviceProfile()
    pool = WorkerPool({0: [0, 2], 1: [1]}, backend="thread")
    with prof.activate():
        results, _, _, backend = pool.run(lambda x: x * 2, [1, 2, 3])
    assert backend == "thread"
    assert results == {0: 2, 1: 4, 2: 6}
    assert sorted(s["worker"] for s in prof.worker_spans) == [0, 1]
    assert all(s["backend"] == "thread" for s in prof.worker_spans)
    # off path records nothing
    pool.run(lambda x: x, [1, 2, 3])
    assert len(prof.worker_spans) == 2


def test_server_profile_flag_roundtrip():
    csr = powerlaw_cluster(n=200, m_per_node=3, seed=1)
    server = QueryServer(csr)
    res = server.execute(QueryRequest("3-clique", engine="vlftj",
                                      profile=True, trace=True))
    assert res.profile is not None
    assert res.profile.meta["trace_id"] == res.trace.meta["trace_id"]
    assert res.profile.jit["calls"] >= 1
    off = server.execute(QueryRequest("3-clique", engine="vlftj"))
    assert off.profile is None and off.count == res.count


def test_request_log_correlates_trace_ids(tmp_path):
    log = tmp_path / "requests.jsonl"
    csr = powerlaw_cluster(n=200, m_per_node=3, seed=1)
    reg = MetricsRegistry()
    server = QueryServer(csr, metrics=reg, request_log=str(log))
    ok = server.execute(QueryRequest("3-clique", engine="vlftj"))
    prof_res = server.execute(QueryRequest("3-clique", engine="vlftj",
                                           profile=True))
    with pytest.raises(Exception):
        server.execute(QueryRequest("no-such-query", engine="vlftj"))
    lines = [json.loads(ln) for ln in log.read_text().splitlines()]
    assert len(lines) == 3
    assert [ln["status"] for ln in lines] == ["ok", "ok", "error"]
    assert len({ln["trace_id"] for ln in lines}) == 3
    assert lines[0]["count"] == ok.count
    assert lines[0]["latency_s"] >= 0
    # the profiled request's log line carries the jit/memory digest and
    # the same trace_id stamped into the returned profile
    assert lines[1]["profile"]["jit_calls"] == prof_res.profile.jit["calls"]
    assert lines[1]["trace_id"] == prof_res.profile.meta["trace_id"]
    assert "error" in lines[2] and "count" not in lines[2]
    snap = reg.snapshot()
    assert snap["server_requests{status=ok}"] == 2
    assert snap["server_requests{status=error}"] == 1


# ---------------------------------------------------------------------------
# satellite 3: concurrent traced queries stay isolated
# ---------------------------------------------------------------------------

def test_concurrent_traced_queries_do_not_interleave():
    """Two simultaneously traced queries through the preemptive
    scheduler: each trace must match its solo-run per-level
    observations exactly — no span/level bleed through the contextvar."""
    csr = powerlaw_cluster(n=300, m_per_node=4, seed=0)

    def run(reqs):
        server = QueryServer(csr, page_rows=256)
        return server.execute_concurrent(reqs, quantum_rows=64)

    (solo_a,) = run([QueryRequest("3-path", engine="vlftj", trace=True)])
    (solo_b,) = run([QueryRequest("3-clique", engine="vlftj", trace=True)])
    both = run([QueryRequest("3-path", engine="vlftj", trace=True),
                QueryRequest("3-clique", engine="vlftj", trace=True)])
    pair = {r.request.query_name: r for r in both}
    assert set(pair) == {"3-path", "3-clique"}
    for solo, res in ((solo_a, pair["3-path"]), (solo_b, pair["3-clique"])):
        assert res.count == solo.count
        assert res.trace is not solo.trace
        assert res.trace.summary["count"] == solo.trace.summary["count"]
        assert set(res.trace.levels) == set(solo.trace.levels)
        for lv, rec in solo.trace.levels.items():
            assert res.trace.levels[lv]["obs_rows"] == rec["obs_rows"], lv
            assert res.trace.levels[lv]["var"] == rec["var"]


# ---------------------------------------------------------------------------
# satellite 1: histogram +Inf bucket + cumulative invariant
# ---------------------------------------------------------------------------

def test_histogram_snapshot_inf_bucket_and_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.005, 0.05, 0.5, 50.0):
        h.observe(v)
    s = h.snapshot()
    les = list(s["buckets"])
    assert all(isinstance(le, str) for le in les)
    assert les[-1] == "+Inf"
    counts = list(s["buckets"].values())
    assert counts == sorted(counts)            # cumulative, non-decreasing
    assert counts[-1] == s["count"] == 5       # +Inf bucket == total
    assert s["buckets"] == {"0.01": 2, "0.1": 3, "1": 4, "+Inf": 5}
    json.dumps(s)                              # JSON-safe keys throughout
    flat = reg.snapshot()
    assert flat["lat_bucket{le=+Inf}"] == 5


# ---------------------------------------------------------------------------
# bench history schema + regression gate
# ---------------------------------------------------------------------------

def _bench_common():
    from benchmarks.common import BenchRecord, append_history, write_baseline
    return BenchRecord, append_history, write_baseline


def test_bench_record_normalizes_counts():
    BenchRecord, _, _ = _bench_common()
    r = BenchRecord("t6/q/ds", 123.4, "count=42;edges=9", bench="cyclic")
    assert r.count == 42
    assert r.to_json() == {"bench": "cyclic", "name": "t6/q/ds",
                           "us_per_call": 123.4, "count": 42,
                           "derived": "count=42;edges=9"}
    # explicit count wins; no count= token -> None; inf wall -> null
    assert BenchRecord("x", 1.0, "count=9", bench="b", count=3).count == 3
    assert BenchRecord("x", 1.0, "speedup=2", bench="b").count is None
    blown = BenchRecord("x", float("inf"), "count=1", bench="b")
    assert blown.to_json()["us_per_call"] is None
    # `of` stamps the bench key on plain rows and keeps existing keys
    from benchmarks.common import Row
    rec = BenchRecord.of("gao", Row("t4/x", 5.0, "count=7"))
    assert (rec.bench, rec.count) == ("gao", 7)
    assert BenchRecord.of("other", rec).bench == "gao"


def test_bench_history_and_baseline_roundtrip(tmp_path):
    BenchRecord, append_history, write_baseline = _bench_common()
    recs = [BenchRecord("x/a", 1000.0, "count=5", bench="x"),
            BenchRecord("x/b", float("inf"), "count=3", bench="x")]
    hist = tmp_path / "h.jsonl"
    hdr = append_history(str(hist), recs)
    lines = [json.loads(ln) for ln in hist.read_text().splitlines()]
    assert len(lines) == 2
    assert all(ln["run_id"] == hdr["run_id"] for ln in lines)
    assert lines[0]["schema"] == 1 and lines[0]["quick"] is True
    assert lines[1]["us_per_call"] is None
    base = tmp_path / "b.json"
    doc = write_baseline(str(base), recs)
    assert doc == json.loads(base.read_text())
    assert [r["name"] for r in doc["records"]] == ["x/a", "x/b"]


def _compare(baseline, history, *extra):
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "bench_compare.py"),
         "--baseline", str(baseline), "--history", str(history), *extra],
        capture_output=True, text=True, timeout=60)


def test_bench_compare_gate(tmp_path):
    BenchRecord, append_history, write_baseline = _bench_common()
    base_recs = [BenchRecord("x/slow", 1000.0, "count=5", bench="x"),
                 BenchRecord("x/tiny", 50.0, "count=2", bench="x"),
                 BenchRecord("x/blown", float("inf"), "", bench="x")]
    baseline = tmp_path / "BENCH_baseline.json"
    history = tmp_path / "BENCH_history.jsonl"
    write_baseline(str(baseline), base_recs)
    append_history(str(history), base_recs)
    ok = _compare(baseline, history)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    assert "OK" in ok.stdout

    # a 2x wall regression on the slow record fails the gate; the tiny
    # record is under the noise floor and may drift freely
    time.sleep(0.005)          # distinct ts for the newer run
    bad_recs = [BenchRecord("x/slow", 2000.0, "count=5", bench="x"),
                BenchRecord("x/tiny", 500.0, "count=2", bench="x"),
                BenchRecord("x/blown", float("inf"), "", bench="x")]
    append_history(str(history), bad_recs)
    bad = _compare(baseline, history, "--min-us", "600")
    assert bad.returncode == 1
    assert "WALL x/x/slow" in bad.stdout
    assert "x/tiny" not in bad.stdout          # below --min-us: ignored

    # count drift is a parity failure regardless of wall
    time.sleep(0.005)
    drift = [BenchRecord("x/slow", 1000.0, "count=6", bench="x"),
             BenchRecord("x/tiny", 50.0, "count=2", bench="x"),
             BenchRecord("x/blown", float("inf"), "", bench="x")]
    append_history(str(history), drift)
    par = _compare(baseline, history)
    assert par.returncode == 1
    assert "PARITY x/x/slow" in par.stdout


def test_bench_compare_calibrate(tmp_path):
    """--calibrate divides out fleet-wide drift (cold-vs-warm, other
    machines) but still catches the one record that regressed against
    the fleet; count parity is never calibrated."""
    BenchRecord, append_history, write_baseline = _bench_common()
    base_recs = [BenchRecord(f"x/r{i}", 1000.0 + i, f"count={i}",
                             bench="x") for i in range(10)]
    baseline = tmp_path / "BENCH_baseline.json"
    history = tmp_path / "BENCH_history.jsonl"
    write_baseline(str(baseline), base_recs)
    # every record 1.5x slower (uniform drift), one of them 3x
    drifted = [BenchRecord(r.name, r.us_per_call * (3.0 if i == 4
                                                    else 1.5),
                           r.derived, bench="x")
               for i, r in enumerate(base_recs)]
    append_history(str(history), drifted)
    uncal = _compare(baseline, history)
    assert uncal.returncode == 1
    assert uncal.stdout.count("WALL") == 10   # raw gate: everything fails
    cal = _compare(baseline, history, "--calibrate")
    assert cal.returncode == 1
    assert cal.stdout.count("WALL") == 1      # drift divided out
    assert "WALL x/x/r4" in cal.stdout
    assert "median drift 1.50x" in cal.stdout
    # drift alone (no outlier) passes calibrated
    time.sleep(0.005)
    append_history(str(history),
                   [BenchRecord(r.name, r.us_per_call * 1.5, r.derived,
                                bench="x") for r in base_recs])
    clean = _compare(baseline, history, "--calibrate")
    assert clean.returncode == 0, clean.stdout


def test_bench_compare_self_test(tmp_path):
    """Acceptance: the gate demonstrably fails on an injected 2x
    slowdown (and passes a clean clone) via --self-test."""
    BenchRecord, _, write_baseline = _bench_common()
    baseline = tmp_path / "BENCH_baseline.json"
    write_baseline(str(baseline),
                   [BenchRecord("x/a", 1000.0, "count=5", bench="x")])
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "bench_compare.py"),
         "--self-test", "--baseline", str(baseline)],
        capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "self-test OK" in out.stdout
