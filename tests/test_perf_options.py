"""§Perf engine options must preserve exact counts (rotate, tile, 2-level)
+ serving router + partitioned-join stats."""
import numpy as np
import pytest

from repro.core import GraphDB, VLFTJ, count, get_query, lftj_count
from repro.graphs import powerlaw_cluster, node_sample
from repro.serve import QueryRequest, QueryServer

QUERIES = ["3-clique", "4-clique", "4-cycle", "3-path", "2-comb",
           "2-lollipop"]


@pytest.fixture(scope="module")
def gdb():
    g = powerlaw_cluster(400, 4, seed=7)
    unary = {f"v{i}": node_sample(g.n_nodes, 6, seed=i)
             for i in range(1, 5)}
    return GraphDB(g, unary)


@pytest.fixture(scope="module")
def refs(gdb):
    return {q: count(get_query(q), gdb, engine="vlftj") for q in QUERIES}


@pytest.mark.parametrize("kw", [
    dict(rotate_checks=True),
    dict(check_mode="auto", tile_width=64),
    dict(check_mode="tile", tile_width=512),   # width covers max degree
    dict(check_mode="bsearch2", rotate_checks=True),
    dict(check_mode="bsearch2", summary_stride=32),
])
def test_perf_modes_preserve_counts(gdb, refs, kw):
    if kw.get("check_mode") == "tile":
        if gdb.max_degree > kw["tile_width"]:
            pytest.skip("tile-only mode requires width >= max degree")
    for qname in QUERIES:
        c = VLFTJ(get_query(qname), gdb, **kw).count()
        assert c == refs[qname], (qname, kw)


def test_partitioned_join_stats_and_counts(gdb, refs):
    sharded_join = pytest.importorskip(
        "repro.dist.sharded_join", reason="repro.dist not implemented")
    PartitionedJoin = sharded_join.PartitionedJoin
    for qname in ["3-clique", "3-path"]:
        pj = PartitionedJoin(get_query(qname), gdb, n_workers=4,
                             granularity=3)
        assert pj.count() == refs[qname]
        assert pj.stats["parts"] == 12
        assert pj.stats["makespan"] <= pj.stats["total_time"] + 1e-9
        assert len(pj.stats["worker_time"]) == 4


def test_query_server_routes_and_counts():
    g = powerlaw_cluster(300, 4, seed=3)
    srv = QueryServer(g)
    res = srv.execute_batch([
        QueryRequest("3-clique", selectivity=8, seed=0),
        QueryRequest("3-path", selectivity=8, seed=0),
        QueryRequest("2-lollipop", selectivity=8, seed=0),
    ])
    assert [r.engine for r in res] == ["vlftj", "yannakakis", "hybrid"]
    # counts agree with the scalar oracle on the same GraphDB
    gdb = srv._gdb_for(8, 0)
    for r in res:
        ref = lftj_count(get_query(r.request.query_name),
                         gdb.to_database())
        assert r.count == ref


def test_overlapped_reduce_apply_single_axis():
    pytest.importorskip("repro.dist", reason="repro.dist not implemented")
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist.overlap import overlapped_reduce_apply
    mesh = jax.make_mesh((1,), ("data",))
    g = np.arange(16, dtype=np.float32)
    p = np.ones(16, dtype=np.float32)
    f = jax.shard_map(
        lambda gg, pp: overlapped_reduce_apply(
            gg, pp, "data", lambda pc, gc: pc - 0.1 * gc, n_chunks=4),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_vma=False)
    out = np.asarray(f(g, p))
    np.testing.assert_allclose(out, p - 0.1 * g, rtol=1e-6, atol=1e-6)
