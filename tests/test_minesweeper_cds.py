"""CDS internals: interval lists, constraints, truncation (Ideas 1-5)."""
from _hypothesis_compat import given, settings, strategies as st

from repro.core.minesweeper_ref import (CDS, Constraint, IntervalList,
                                        STAR, _chain_bottom, _generalizes)


def test_interval_merge_open_semantics():
    il = IntervalList()
    il.insert(1, 10)
    il.insert(10, 20)     # touching open intervals: 10 stays free
    assert il.next_free(5) == 10
    assert il.next_free(10) == 10
    assert il.next_free(11) == 20
    il.insert(9, 11)      # now 10 is covered -> all merge
    assert il.ivs == [(1, 20)]
    assert il.next_free(5) == 20


def test_interval_empty_inserts_ignored():
    il = IntervalList()
    il.insert(5, 6)   # open (5,6) contains no integer
    assert il.ivs == []


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 60), st.integers(0, 60)),
                min_size=1, max_size=25),
       st.integers(0, 70))
def test_interval_list_matches_naive(pairs, probe):
    il = IntervalList()
    covered = set()
    for a, b in pairs:
        l, r = min(a, b), max(a, b)
        il.insert(l, r)
        covered |= set(range(l + 1, r))
    # invariant: sorted, disjoint
    for (a1, b1), (a2, b2) in zip(il.ivs, il.ivs[1:]):
        assert b1 <= a2
    expect = probe
    while expect in covered:
        expect += 1
    assert il.next_free(probe) == expect


def test_constraint_matching():
    c = Constraint((STAR, 7), 2, 3, 9)
    assert c.matches((0, 7, 5))
    assert not c.matches((0, 7, 3))   # open endpoint
    assert not c.matches((0, 8, 5))   # pattern mismatch
    assert c.pattern_matches((0, 7, 99))


def test_cds_insert_prunes_children():
    cds = CDS(3)
    cds.insert(Constraint((5,), 1, 2, 9))        # creates node (5)
    cds.insert(Constraint((5, 4), 2, 0, 3))      # child 4 inside (2,9)!
    node5 = cds.root.children[5]
    cds.insert(Constraint((5,), 1, 3, 8))        # prunes child 4
    assert 4 not in node5.children


def test_chain_bottom_detection():
    cds = CDS(3)
    cds.insert(Constraint((STAR,), 1, 0, 5))
    cds.insert(Constraint((7,), 1, 2, 9))
    g = cds.generalizing((7,))
    bottom = _chain_bottom(g)
    assert bottom is not None  # (7,) specializes (*,)
    cds2 = CDS(3)
    cds2.insert(Constraint((7, STAR), 2, 0, 5))
    cds2.insert(Constraint((STAR, 3), 2, 2, 9))
    g2 = cds2.generalizing((7, 3))
    assert len(g2) == 2
    assert _chain_bottom(g2) is None  # incomparable: no sound cache spot


def test_generalizes():
    assert _generalizes((STAR, STAR), (1, 2))
    assert _generalizes((1, STAR), (1, 2))
    assert not _generalizes((1, 3), (1, 2))
