"""Property-testing shim: real hypothesis when installed, else a small
deterministic fallback.

The test suite's property tests use a narrow slice of the hypothesis API
(``given``/``settings`` and the ``integers``/``sampled_from``/``lists``/
``tuples`` strategies).  When hypothesis is unavailable (the CPU container
does not ship it), the fallback below replays each property as
``max_examples`` deterministically-seeded random examples — weaker than
real shrinking-and-database hypothesis, but the same assertions run on
every CI pass instead of erroring at collection.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools

    import numpy as np

    class _Strategy:
        def sample(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=1 << 30):
            self.lo, self.hi = min_value, max_value

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def sample(self, rng):
            return self.elements[int(rng.integers(len(self.elements)))]

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=10):
            self.elements = elements
            self.min_size, self.max_size = min_size, max_size

        def sample(self, rng):
            size = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elements.sample(rng) for _ in range(size)]

    class _Tuples(_Strategy):
        def __init__(self, *elements):
            self.elements = elements

        def sample(self, rng):
            return tuple(e.sample(rng) for e in self.elements)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        integers = staticmethod(_Integers)
        sampled_from = staticmethod(_SampledFrom)
        lists = staticmethod(_Lists)
        tuples = staticmethod(_Tuples)

    _DEFAULT_EXAMPLES = 12

    def given(*st_args, **st_kwargs):
        def deco(fn):
            import inspect
            params = list(inspect.signature(fn).parameters.values())
            # positional strategies fill the RIGHTMOST parameters
            # (hypothesis semantics); bind them by name so fixtures —
            # which pytest supplies as keywords — can coexist
            pos_names = [p.name for p in params[len(params) - len(st_args):]]

            @functools.wraps(fn)
            def wrapper(*fixture_args, **fixture_kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = np.random.default_rng(0xC0FFEE)
                for _ in range(n):
                    kwargs = {name: s.sample(rng)
                              for name, s in zip(pos_names, st_args)}
                    kwargs.update({k: s.sample(rng)
                                   for k, s in st_kwargs.items()})
                    fn(*fixture_args, **fixture_kwargs, **kwargs)

            # hide the strategy-filled parameters from pytest's fixture
            # resolution
            remaining = params[:len(params) - len(st_args)]
            remaining = [p for p in remaining if p.name not in st_kwargs]
            wrapper.__signature__ = inspect.Signature(remaining)
            return wrapper
        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
