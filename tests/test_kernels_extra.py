"""Sweeps for the §Perf-era kernels: segment-outer and two-level search."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels.ref import (searchsorted_segments_2level_ref,
                               searchsorted_segments_ref)
from repro.kernels.segment_outer import (block_tile_starts,
                                         segment_outer_pallas,
                                         segment_outer_ref)

RNG = np.random.default_rng(1)


@pytest.mark.parametrize("dist", ["uniform", "powerlaw", "one_block",
                                  "empty"])
@pytest.mark.parametrize("c,m", [(32, 16), (64, 8)])
def test_segment_outer_sweep(dist, c, m):
    n, bn, te = 64, 8, 128
    e_real = {"uniform": 900, "powerlaw": 900, "one_block": 900,
              "empty": 0}[dist]
    if dist == "uniform":
        dst = np.sort(RNG.integers(0, n, e_real))
    elif dist == "powerlaw":
        dst = np.sort((n * RNG.random(e_real) ** 3).astype(np.int64))
    elif dist == "one_block":
        dst = np.sort(RNG.integers(0, bn, e_real))
    else:
        dst = np.zeros(0, np.int64)
    e = max(te, -(-max(e_real, 1) // te) * te)
    msg = RNG.standard_normal((e, c)).astype(np.float32)
    basis = RNG.standard_normal((e, m)).astype(np.float32)
    dstp = np.full(e, n, np.int32)
    dstp[:e_real] = dst
    msg[e_real:] = 0
    basis[e_real:] = 0
    bt, n_tiles = block_tile_starts(dstp, n, bn, te)
    out = segment_outer_pallas(jnp.asarray(msg), jnp.asarray(basis),
                               jnp.asarray(dstp), bt, n_nodes=n,
                               n_tiles=n_tiles, bn=bn, te=te)
    ref = segment_outer_ref(jnp.asarray(msg), jnp.asarray(basis),
                            jnp.asarray(dstp), n)
    assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10 ** 6), m=st.integers(10, 2000),
       stride=st.sampled_from([32, 128]))
def test_two_level_search_matches_flat(seed, m, stride):
    rng = np.random.default_rng(seed)
    vals = np.sort(rng.integers(0, 4 * m, m)).astype(np.int32)
    summary = vals[::stride]
    r, w = 8, 128
    lo = rng.integers(0, m, (r, 1)).astype(np.int32)
    hi = np.minimum(lo + rng.integers(0, m, (r, 1)), m).astype(np.int32)
    q = rng.integers(-5, 4 * m + 5, (r, w)).astype(np.int32)
    import math
    n_flat = int(math.ceil(math.log2(max(2, m)))) + 1
    n1 = int(math.ceil(math.log2(max(2, m // stride + 2)))) + 1
    n2 = int(math.ceil(math.log2(2 * stride + 2))) + 1
    p1, f1 = searchsorted_segments_ref(
        jnp.asarray(vals), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(q), n_iter=n_flat)
    p2, f2 = searchsorted_segments_2level_ref(
        jnp.asarray(vals), jnp.asarray(summary), jnp.asarray(lo),
        jnp.asarray(hi), jnp.asarray(q), stride=stride, n1=n1, n2=n2)
    assert_allclose(np.asarray(f1), np.asarray(f2))
    # positions agree wherever found (not-found insertion points may
    # differ inside equal-value runs; membership is the engine contract)
    found = np.asarray(f1)
    assert_allclose(np.asarray(p1)[found], np.asarray(p2)[found])
