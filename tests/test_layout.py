"""Degree-adaptive hybrid layout: renumbering, bitset packing, and
engine parity on a Zipf graph.

Contracts under test:

* ``degree_sort_permutation`` is a stable degree-descending bijection and
  ``renumber_csr``/``map_rows_back`` round-trip both the graph and query
  results;
* ``HybridLayout`` packs exactly the hub prefix, its bitset rows decode
  back to the CSR neighbor lists, and the budget/threshold knobs bound it;
* every engine returns the same counts and rows on a
  :class:`~repro.core.HybridGraphDB` as the scalar LFTJ oracle *on the
  same db*, with the vectorized engine's bitset check path actually
  exercised (``stats["bitset_rows"] > 0``);
* the planner stamps ``level_layouts`` and the array-forced plan agrees.
"""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (GraphDB, GraphStats, HybridGraphDB, count, get_query)
from repro.core import engine as engine_mod
from repro.core.planner import choose_level_layouts, plan_query
from repro.core.vlftj import VLFTJ
from repro.graphs import (CSRGraph, HybridLayout, degree_sort_permutation,
                          map_rows_back, node_sample, renumber_csr,
                          zipf_graph)

PARITY_QUERIES = ["3-clique", "4-cycle", "4-clique", "3-path", "2-lollipop"]
PARITY_ENGINES = ["minesweeper_ref", "binary", "vlftj", "hybrid", "auto"]


@pytest.fixture(scope="module")
def zgraph():
    return zipf_graph(300, 2400, alpha=2.0, seed=0)


@pytest.fixture(scope="module")
def hdb(zgraph):
    unary = {f"v{i}": node_sample(zgraph.n_nodes, 6.0, seed=17 * i + 1)
             for i in range(1, 5)}
    db = HybridGraphDB.build(zgraph, unary)
    assert db.n_hubs > 0
    return db


# ---------------------------------------------------------------------------
# renumbering
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(5, 120))
def test_degree_sort_permutation_properties(seed, n):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(0, 4 * n))
    g = CSRGraph.from_edges(rng.integers(0, n, m), rng.integers(0, n, m),
                            n_nodes=n)
    order, inv = degree_sort_permutation(g)
    assert np.array_equal(np.sort(order), np.arange(n))
    assert np.array_equal(order[inv], np.arange(n))          # inverse
    d = g.degrees[order]
    assert (d[:-1] >= d[1:]).all()                           # descending
    ties = d[:-1] == d[1:]
    assert (order[:-1][ties] < order[1:][ties]).all()        # stable


def test_renumber_round_trip(zgraph):
    order, inv = degree_sort_permutation(zgraph)
    rg = renumber_csr(zgraph, inv)
    assert rg.n_nodes == zgraph.n_nodes
    assert rg.n_edges == zgraph.n_edges
    # hubs occupy the id prefix in degree order
    assert np.array_equal(rg.degrees, zgraph.degrees[order])
    # edge sets identical up to relabeling
    ea = zgraph.edge_array()
    want = {(int(inv[a]), int(inv[b])) for a, b in ea}
    assert want == set(map(tuple, rg.edge_array().tolist()))
    # neighbor lists come back sorted in the new id space
    for v in range(0, rg.n_nodes, 37):
        nb = rg.neighbors(v)
        assert (np.diff(nb) > 0).all() if len(nb) > 1 else True
    # result rows map back through `order`
    rows = np.array([[0, 1], [2, 0]])
    back = map_rows_back(rows, order)
    assert np.array_equal(back, np.asarray(order)[rows])


# ---------------------------------------------------------------------------
# bitset packing
# ---------------------------------------------------------------------------

def test_hybrid_layout_packs_hub_prefix(zgraph):
    order, inv = degree_sort_permutation(zgraph)
    rg = renumber_csr(zgraph, inv)
    lay = HybridLayout.build(rg, min_degree=4, density=0.0)
    deg = rg.degrees
    assert lay.n_hubs == int((deg >= lay.min_degree).sum())
    assert lay.words.shape == (lay.n_hubs, lay.n_words)
    for h in range(lay.n_hubs):
        np.testing.assert_array_equal(lay.neighbors_from_bits(h),
                                      rg.neighbors(h))
    tags = lay.rep_tags()
    assert np.array_equal(tags[:lay.n_hubs], np.arange(lay.n_hubs))
    assert (tags[lay.n_hubs:] == -1).all()


def test_hybrid_layout_budget_and_caps(zgraph):
    order, inv = degree_sort_permutation(zgraph)
    rg = renumber_csr(zgraph, inv)
    full = HybridLayout.build(rg, min_degree=1, density=0.0)
    capped = HybridLayout.build(rg, min_degree=1, density=0.0,
                                word_budget=3 * full.n_words)
    assert capped.n_hubs == 3            # budget caps the hub count
    few = HybridLayout.build(rg, min_degree=1, density=0.0, max_hubs=5)
    assert few.n_hubs == 5
    none = HybridLayout.build(rg, min_degree=10 ** 9)
    assert none.n_hubs == 0 and none.rep_tags().min() == -1


def test_unsorted_graph_degrades_to_prefix(zgraph):
    # without renumbering only the qualifying *prefix* is packed — never
    # a mis-tagged vertex
    lay = HybridLayout.build(zgraph, min_degree=4, density=0.0)
    deg = zgraph.degrees
    assert lay.n_hubs <= zgraph.n_nodes
    assert (deg[:lay.n_hubs] >= lay.min_degree).all()


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_graph_stats_sees_layout(hdb):
    stats = GraphStats.of(hdb)
    assert stats.n_hubs == hdb.n_hubs > 0
    assert 0.0 < stats.hub_edge_fraction <= 1.0
    assert stats.bitset_words == hdb.layout.n_words
    plain = GraphStats.of(GraphDB(hdb.csr, hdb.unary))
    assert plain.n_hubs == 0
    assert plain.fingerprint() != stats.fingerprint()


def test_plan_stamps_level_layouts(hdb):
    stats = GraphStats.of(hdb)
    q = get_query("3-clique")
    plan = plan_query(q, stats, engine="vlftj")
    assert len(plan.level_layouts) == len(plan.gao)
    assert plan.level_layouts[-1] in ("bitset", "mixed")
    assert hash(plan) == hash(dataclasses.replace(plan))  # stays hashable
    assert choose_level_layouts(q, plan.gao, stats) == plan.level_layouts
    # no layout info -> all-array
    plain = dataclasses.replace(
        stats, n_hubs=0, hub_edge_fraction=0.0, bitset_words=0)
    assert set(choose_level_layouts(q, plan.gao, plain)) == {"array"}


def test_bitset_path_exercised_and_array_forced_agrees(hdb):
    q = get_query("3-clique")
    stats = GraphStats.of(hdb)
    plan = plan_query(q, stats, engine="vlftj")
    eng = VLFTJ(q, hdb, plan=plan)
    got = eng.count()
    assert eng.stats["bitset_rows"] > 0
    arr_plan = dataclasses.replace(
        plan, level_layouts=("array",) * len(plan.level_layouts))
    assert VLFTJ(q, hdb, plan=arr_plan).count() == got


@pytest.mark.parametrize("qname", PARITY_QUERIES)
def test_engine_count_parity_on_hybrid_db(hdb, qname):
    q = get_query(qname)
    ref = count(q, hdb, engine="lftj_ref")
    for eng in PARITY_ENGINES:
        assert count(q, hdb, engine=eng) == ref, eng


@pytest.mark.parametrize("qname", ["3-clique", "4-cycle", "3-path"])
def test_engine_enumerate_parity_on_hybrid_db(hdb, qname):
    q = get_query(qname)
    ref = engine_mod.enumerate(q, hdb, engine="lftj_ref", mode="flat")
    for eng in ["vlftj", "binary", "hybrid"]:
        res = engine_mod.enumerate(q, hdb, engine=eng)
        np.testing.assert_array_equal(res.expand(), ref.rows)


def test_counts_renumbering_invariant_without_order_filters(zgraph, hdb):
    # cliques' LessThan chains quotient the automorphism exactly; the
    # plain-db count must match the renumbered-db count
    plain = GraphDB(zgraph, {})
    bare = HybridGraphDB.build(zgraph)
    for qname in ["3-clique", "4-clique"]:
        q = get_query(qname)
        assert (count(q, bare, engine="vlftj")
                == count(q, plain, engine="lftj_ref"))


def test_rows_map_back_to_original_edges(zgraph, hdb):
    q = get_query("3-clique")
    res = engine_mod.enumerate(q, hdb, engine="vlftj", mode="flat")
    rows = hdb.rows_to_original(np.asarray(res.rows))
    es = set(map(tuple, zgraph.edge_array().tolist()))
    for a, b, c in rows[:200].tolist():
        assert (a, b) in es and (a, c) in es and (b, c) in es


def test_dev_keys_with_and_without_hubs(hdb, zgraph):
    w = np.asarray(hdb.dev("bitset_words"))
    assert w.shape == (max(1, hdb.n_hubs), hdb.layout.n_words)
    tags = np.asarray(hdb.dev("rep_tag"))
    assert tags.shape == (hdb.n_nodes,)
    empty = HybridGraphDB.build(zgraph, min_degree=10 ** 9)
    assert np.asarray(empty.dev("bitset_words")).shape[0] == 1  # gatherable
    assert (np.asarray(empty.dev("rep_tag")) == -1).all()
    with pytest.raises(KeyError):
        GraphDB(zgraph, {}).dev("bitset_words")
