"""Cross-engine agreement: every engine must produce identical counts.

The scalar LFTJ (validated against networkx oracles in test_graphs) is the
reference; Minesweeper, binary join, vectorized LFTJ, counting Yannakakis
and the hybrid must all agree on every paper query, including under
hypothesis-generated random graphs and samples.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (GraphDB, Minesweeper, PAPER_QUERIES, count,
                        get_query, pick_engine)
from repro.graphs import CSRGraph

from conftest import make_gdb

ALL_QUERIES = list(PAPER_QUERIES)


@pytest.fixture(scope="module")
def gdb():
    return make_gdb(50, 3, seed=3)


@pytest.mark.parametrize("qname", ALL_QUERIES)
def test_all_engines_agree(gdb, qname):
    q = get_query(qname)
    ref = count(q, gdb, engine="lftj_ref")
    assert count(q, gdb, engine="vlftj") == ref
    assert count(q, gdb, engine="binary") == ref
    assert count(q, gdb, engine="minesweeper_ref") == ref
    auto = pick_engine(q)
    assert count(q, gdb, engine=auto) == ref


def test_enumerate_agreement(gdb):
    from repro.core import LFTJ, VLFTJ
    for qname in ["3-clique", "3-path", "2-comb"]:
        q = get_query(qname)
        ref_engine = LFTJ(q, gdb.to_database())
        vec = VLFTJ(q, gdb, gao=ref_engine.gao)
        a = ref_engine.enumerate()
        b = vec.enumerate()
        a_sorted = a[np.lexsort(a.T[::-1])] if a.size else a
        b_sorted = b[np.lexsort(b.T[::-1])] if b.size else b
        np.testing.assert_array_equal(a_sorted, b_sorted)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.integers(8, 28),
       density=st.integers(1, 4))
def test_property_vectorized_matches_scalar(seed, n, density):
    rng = np.random.default_rng(seed)
    m = n * density
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        return
    g = CSRGraph.from_edges(src[keep], dst[keep], n_nodes=n)
    unary = {f"v{i}": rng.choice(n, max(1, n // 3), replace=False)
             for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    for qname in ["3-clique", "4-cycle", "3-path", "2-comb",
                  "2-lollipop"]:
        q = get_query(qname)
        ref = count(q, gdb, engine="lftj_ref")
        assert count(q, gdb, engine="vlftj") == ref, qname
        assert count(q, gdb, engine="auto") == ref, qname


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_property_minesweeper_matches_scalar(seed):
    rng = np.random.default_rng(seed)
    n = 16
    m = 40
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        return
    g = CSRGraph.from_edges(src[keep], dst[keep], n_nodes=n)
    unary = {f"v{i}": rng.choice(n, 5, replace=False) for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    for qname in ["3-clique", "3-path", "1-tree", "2-comb"]:
        q = get_query(qname)
        ref = count(q, gdb, engine="lftj_ref")
        assert count(q, gdb, engine="minesweeper_ref") == ref, qname


def test_minesweeper_idea_flags_preserve_counts(gdb):
    for qname in ["3-clique", "4-cycle", "3-path"]:
        q = get_query(qname)
        db = gdb.to_database()
        base = Minesweeper(q, db).count()
        assert Minesweeper(q, db, skip_probes=False).count() == base
        assert Minesweeper(q, db, use_skeleton=False).count() == base


def test_minesweeper_probe_skip_saves_probes(gdb):
    q = get_query("3-path")
    db = gdb.to_database()
    on = Minesweeper(q, db, skip_probes=True)
    on.count()
    off = Minesweeper(q, db, skip_probes=False)
    off.count()
    assert on.stats["probe_skips"] > 0
    assert on.stats["probes"] < off.stats["probes"]


def test_agm_bound_respected(gdb):
    from repro.core import agm_bound
    sizes = gdb.to_database().sizes()
    for qname in ALL_QUERIES:
        q = get_query(qname)
        c = count(q, gdb, engine="vlftj")
        assert c <= agm_bound(q, sizes) * 1.0000001, qname


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10 ** 6), path_len=st.integers(1, 3),
       clique_k=st.integers(3, 4))
def test_property_hybrid_generalized_lollipops(seed, path_len, clique_k):
    """§4.12 generalized: random tadpole queries (path of length 1-3 into
    a {3,4}-clique) — hybrid must agree with the scalar oracle."""
    from repro.core import Atom, LessThan, Query, HybridJoin

    path_vars = [f"p{i}" for i in range(path_len + 1)]
    clique_vars = [path_vars[-1]] + [f"c{i}" for i in range(clique_k - 1)]
    atoms = [Atom("v1", (path_vars[0],))]
    atoms += [Atom("edge", (path_vars[i], path_vars[i + 1]))
              for i in range(path_len)]
    atoms += [Atom("edge", (clique_vars[i], clique_vars[j]))
              for i in range(clique_k) for j in range(i + 1, clique_k)]
    filters = [LessThan(clique_vars[i], clique_vars[i + 1])
               for i in range(1, clique_k - 1)]
    q = Query(tuple(atoms), tuple(filters), f"tadpole-{path_len}-{clique_k}")

    rng = np.random.default_rng(seed)
    n, m = 24, 72
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    if not keep.any():
        return
    g = CSRGraph.from_edges(src[keep], dst[keep], n_nodes=n)
    gdb = GraphDB(g, {"v1": rng.choice(n, 8, replace=False)})
    ref = count(q, gdb, engine="lftj_ref")
    hj = HybridJoin(q, gdb)
    assert hj.count() == ref
    # the decomposition should actually engage for these shapes
    assert hj.decomp.applicable, (path_len, clique_k)
