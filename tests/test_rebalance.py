"""Mid-join frontier re-balancing: Zipf-skew makespan, callback safety,
and the snake re-deal invariants (single-device host-side; the CI
multidevice job re-runs this file under 8 forced host devices)."""
import dataclasses

import numpy as np
import pytest

from repro.core import GraphDB, GraphStats, count, get_query
from repro.core.planner import estimate_extension_degree, plan_query
from repro.core.vlftj import VLFTJ
from repro.dist.rebalance import (AdaptiveJoin, FrontierRebalancer,
                                  cost_skew, rebalance_rows,
                                  row_extension_costs)
from repro.graphs import node_sample, zipf_graph


@pytest.fixture(scope="module")
def zipf_gdb():
    g = zipf_graph(2000, 12000, alpha=1.3, seed=0)
    unary = {f"v{i}": node_sample(g.n_nodes, 6, seed=i)
             for i in range(1, 5)}
    return GraphDB(g, unary)


def test_rebalance_rows_snake_deal_balances_powerlaw_costs():
    rng = np.random.default_rng(0)
    costs = rng.pareto(1.2, size=203) + 1.0
    deal = rebalance_rows(costs, 8)
    assert sorted(int(i) for idx in deal for i in idx) == list(range(203))
    loads = np.array([costs[idx].sum() for idx in deal])
    assert loads.max() - loads.min() <= costs.max()
    assert cost_skew(loads) < cost_skew(
        [c.sum() for c in np.array_split(costs, 8)])


def test_cost_skew_edges():
    assert cost_skew([]) == 1.0
    assert cost_skew([0.0, 0.0]) == 1.0
    assert cost_skew([1.0, 1.0, 1.0]) == 1.0
    assert cost_skew([3.0, 1.0]) == 1.5


def test_row_extension_costs_prefers_min_degree_probe(zipf_gdb):
    ex = VLFTJ(get_query("3-clique"), zipf_gdb)
    lp = ex.plan[2]            # probes both bound columns
    fr = np.array([[0, 1], [5, 1900]], dtype=np.int32)
    deg = zipf_gdb.csr.degrees
    costs = row_extension_costs(fr, lp, deg)
    assert costs[0] == 1.0 + min(deg[0], deg[1])
    assert costs[1] == 1.0 + min(deg[5], deg[1900])
    # stats fallback: uniform at the model's expected fanout
    stats = GraphStats.of(zipf_gdb)
    est = row_extension_costs(fr, lp, None, stats)
    assert est.shape == (2,)
    assert np.allclose(est, estimate_extension_degree(lp, stats))


@pytest.mark.parametrize("qname", ["3-clique", "4-cycle", "3-path"])
def test_adaptive_join_counts_match_engine(zipf_gdb, qname):
    ref = count(get_query(qname), zipf_gdb, engine="vlftj")
    for rebalance in (False, True):
        aj = AdaptiveJoin(get_query(qname), zipf_gdb, n_shards=8,
                          rebalance=rebalance)
        assert aj.count() == ref


def test_rebalanced_makespan_beats_static_on_zipf(zipf_gdb):
    """The acceptance property: on a Zipf frontier the mid-join re-deal's
    makespan is no worse than the static first-level deal's (compared in
    the deterministic cost-model units so CI timer noise cannot flake
    it; the wall-clock version is recorded by bench_dist --skew)."""
    q = get_query("3-path")
    stat = AdaptiveJoin(q, zipf_gdb, n_shards=8, rebalance=False)
    ada = AdaptiveJoin(q, zipf_gdb, n_shards=8, threshold=1.2,
                       rebalance=True)
    assert stat.count() == ada.count()
    assert ada.stats["rebalances"], "skew never triggered a re-deal"
    assert (ada.stats["cost_makespan"]
            <= stat.stats["cost_makespan"] + 1e-9)
    ev = ada.stats["rebalances"][0]
    assert ev["skew_after"] <= ev["skew_before"]
    # re-deal can't help the single-worker total, only the spread
    assert ada.stats["cost_total"] == pytest.approx(
        stat.stats["cost_total"], rel=0.2)


def test_adaptive_join_more_shards_than_seeds():
    """Regression: an emptied shard's frontier must be re-widened each
    level, or later-level cost pricing indexes columns the empty array
    doesn't have (numpy deprecation today, IndexError tomorrow)."""
    import warnings

    from repro.graphs import zipf_graph as zg

    g = zg(300, 1200, alpha=1.3, seed=5)
    unary = {f"v{i}": node_sample(g.n_nodes, 8, seed=i)
             for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    ref = count(get_query("3-path"), gdb, engine="vlftj")
    with warnings.catch_warnings():
        warnings.filterwarnings("error", message=".*[Oo]ut of bound.*")
        for rebalance in (False, True):
            aj = AdaptiveJoin(get_query("3-path"), gdb, n_shards=64,
                              rebalance=rebalance)
            assert sum(p.shape[0] == 0 for p in aj.parts) > 0
            assert aj.count() == ref


def test_adaptive_stats_invariants(zipf_gdb):
    aj = AdaptiveJoin(get_query("3-path"), zipf_gdb, n_shards=4)
    aj.count()
    st = aj.stats
    assert st["makespan"] <= st["total_time"] + 1e-9
    assert abs(sum(st["shard_time"]) - st["total_time"]) < 1e-9
    assert st["cost_makespan"] <= st["cost_total"] + 1e-9
    assert len(st["shard_time"]) == 4


def test_frontier_rebalancer_is_a_pure_permutation(zipf_gdb):
    """Attached as JoinPlan.level_callback the re-balancer must not
    change results — only row order — under both counting and
    enumeration."""
    q = get_query("3-path")
    plan = plan_query(q, GraphStats.of(zipf_gdb), engine="vlftj")
    reb = FrontierRebalancer(plan, n_shards=8,
                             degrees=zipf_gdb.csr.degrees, threshold=1.2)
    cb_plan = dataclasses.replace(plan, level_callback=reb)
    assert hash(cb_plan) == hash(plan)      # excluded from identity
    ref = VLFTJ(q, zipf_gdb, plan=plan).count()
    assert VLFTJ(q, zipf_gdb, plan=cb_plan).count() == ref
    assert reb.events, "zipf frontier should trip the threshold"
    ev = reb.events[0]
    assert ev["skew_after"] <= ev["skew_before"]
    rows_ref = VLFTJ(q, zipf_gdb, plan=plan).enumerate(limit=500)
    rows_cb = VLFTJ(q, zipf_gdb, plan=cb_plan).enumerate(limit=500)
    assert np.array_equal(rows_ref, rows_cb)


def test_spmd_join_step_applies_rebalancer_callback(zipf_gdb):
    """spmd_join_step(plan=) must price the level it is about to
    dispatch (levels[width]) — the regression was passing the frontier
    width as the callback level, one past VLFTJ._run's convention, so
    the re-deal never fired."""
    import jax

    from repro.core.plan import executor_geometry
    from repro.dist.sharded_join import spmd_join_step

    q = get_query("3-clique")
    plan = plan_query(q, GraphStats.of(zipf_gdb), engine="vlftj")
    gdb = zipf_gdb
    ex = VLFTJ(q, gdb, plan=plan)
    # penultimate frontier of the clique (the level-2 dispatch input)
    fr = np.asarray(ex._run(count_only=False, max_levels=2),
                    dtype=np.int32)
    lp = ex.plan[2]
    width, _ = executor_geometry(gdb.max_degree)
    kw = dict(probe_cols=lp.edge_sources, n_unary=0, lower_cols=lp.lower,
              upper_cols=lp.upper, width=width, n_iter=gdb.bsearch_iters,
              needs_degree=lp.needs_degree)
    mesh = jax.make_mesh((jax.device_count(),),
                         ("data",))
    mult = np.ones(fr.shape[0], np.int64)
    plain = int(spmd_join_step(mesh, kw)(
        gdb.dev("indptr"), gdb.dev("indices"), fr, mult))
    reb = FrontierRebalancer(plan, n_shards=8,
                             degrees=gdb.csr.degrees, threshold=1.01)
    cb_plan = dataclasses.replace(plan, level_callback=reb)
    step = spmd_join_step(mesh, kw, plan=cb_plan)
    got = int(step(gdb.dev("indptr"), gdb.dev("indices"), fr, mult))
    assert got == plain                    # permutation never changes counts
    assert reb.events, "callback should fire on a zipf frontier"
    assert reb.events[0]["rows"] == fr.shape[0]


def test_frontier_rebalancer_balances_blocks():
    rng = np.random.default_rng(1)
    # synthetic skew: all heavy rows at the front of one block
    deg = np.concatenate([np.full(50, 400), rng.integers(1, 5, 1950)])
    g = zipf_graph(2000, 4000, seed=3)
    q = get_query("3-clique")
    plan = plan_query(q, GraphStats.of(GraphDB(g, {})), engine="vlftj")
    reb = FrontierRebalancer(plan, n_shards=4, degrees=deg, threshold=1.5)
    frontier = np.stack([np.arange(2000, dtype=np.int32),
                         np.arange(2000, dtype=np.int32)], axis=1)
    mult = np.ones(2000, dtype=np.int64)
    out = reb(1, frontier, mult)
    assert out is not None
    fr2, mult2 = out
    assert np.array_equal(np.sort(fr2[:, 0]), frontier[:, 0])
    costs = row_extension_costs(fr2, plan.levels[2], deg)
    blocks = np.array([b.sum() for b in np.array_split(costs, 4)])
    assert cost_skew(blocks) < reb.events[0]["skew_before"]
