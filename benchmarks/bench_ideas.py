"""Tables 1-3 — implementation-idea ablations on faithful Minesweeper.

Idea 4 (avoid repeated seekGap): ``skip_probes`` on/off — Tables 1/2.
Idea 7 (gap skipping via β-acyclic skeleton): ``use_skeleton`` on/off on
cyclic queries — Table 3 (the paper reports up to 10^4× there; the effect
here is CDS-size-bound, visible as speedup > 1).
Idea 6 analogue (caching): the vectorized Minesweeper analogue's
memoization = counting message passing vs recomputing sub-paths with
vectorized LFTJ, reported as a ratio on low-selectivity paths.
"""
from __future__ import annotations

from functools import partial

from repro.core import Minesweeper, count, get_query

from .common import BenchRecord, bench_gdb, timed

Rec = partial(BenchRecord, bench="ideas")


def run(quick: bool = True) -> list[BenchRecord]:
    scale = 0.03 if quick else 0.1   # faithful MS is host Python
    rows: list[BenchRecord] = []
    gdb = bench_gdb("ca-GrQc", scale, selectivity=8)
    db = gdb.to_database()
    for qname in ["2-comb", "3-path", "4-path"]:
        q = get_query(qname)
        c1, us_on = timed(lambda: Minesweeper(q, db,
                                              skip_probes=True).count())
        c2, us_off = timed(lambda: Minesweeper(q, db,
                                               skip_probes=False).count())
        assert c1 == c2
        rows.append(Rec(f"t1/idea4/{qname}", us_on,
                        f"speedup={us_off / max(us_on, 1):.2f}x"))
    for qname in ["3-clique", "4-cycle"]:
        q = get_query(qname)
        c1, us_on = timed(lambda: Minesweeper(q, db,
                                              use_skeleton=True).count())
        c2, us_off = timed(lambda: Minesweeper(q, db,
                                               use_skeleton=False).count())
        assert c1 == c2
        rows.append(Rec(f"t3/idea7/{qname}", us_on,
                        f"speedup={us_off / max(us_on, 1):.2f}x"))
    # Idea 6 analogue: caching (message passing) vs re-searching (vlftj)
    gdb2 = bench_gdb("wiki-Vote", 0.25 if quick else 1.0, selectivity=8)
    for qname in ["3-path", "4-path"]:
        q = get_query(qname)
        ref, us_ms = timed(lambda: count(q, gdb2, engine="yannakakis"))
        c2, us_vl = timed(lambda: count(q, gdb2, engine="vlftj"),
                          timeout_s=120)
        assert ref == c2
        rows.append(Rec(f"t2/idea6-analogue/{qname}", us_ms,
                        f"caching_speedup={us_vl / max(us_ms, 1):.1f}x"))
    return rows
