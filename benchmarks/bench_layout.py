"""Adjacency-layout crossover benchmark: degree-adaptive bitset vs array.

Measures the hybrid layout stack (``graphs/layout.py`` +
``core.device_graph.HybridGraphDB`` + the vectorized engine's bitset
check path) against the array-only baseline on the same degree-sorted
graph, so the timing gap isolates the *representation* choice:

* ``triangle/zipf<alpha>/{array,hybrid}`` — triangle closure on Zipf
  graphs (skew 1.5 / 2.0 / 2.5).  The final GAO level checks candidates
  against two bound sources; on hub-hub frontier rows the hybrid plan
  replaces ``log2(maxdeg)+1`` binary-search gather rounds with one
  bitset word gather + bit test.  The derived field carries the
  speedup — the acceptance bar is >= 2x on the hub-heavy shapes
  (alpha <= 2.0; at 2.5 the quick graph's triangle count is tiny and
  the measurement is dispatch-overhead noise).
* ``path3/zipf<alpha>/{array,hybrid}`` — the 3-path control: no GAO
  level has two bound edge sources, so the planner keeps every level
  ``array`` and the two runs must time the same (ratio ~1 = the hybrid
  machinery costs nothing when it cannot help).
* ``triangle/uniform/{array,hybrid}`` — Erdos-Renyi control: no skew,
  but every vertex clears the degree floor so membership checks all go
  through the bitset table; the bar is ratio <= 1 (unregressed).
* ``build/zipf<alpha>`` — one-time layout build cost (degree-sort
  renumbering + bitset packing), to show it amortizes.

Counts are verified equal between the array and hybrid runs (both run
on the *same* renumbered HybridGraphDB; only ``level_layouts`` differs).
"""
from __future__ import annotations

import dataclasses
import json
import os
from functools import partial

from repro.core import HybridGraphDB, GraphStats, get_query
from repro.core.planner import plan_query
from repro.core.vlftj import VLFTJ
from repro.graphs import erdos_renyi, node_sample, zipf_graph

from .common import BenchRecord, timed

Rec = partial(BenchRecord, bench="layout")

ALPHAS = (1.5, 2.0, 2.5)


def _graph(alpha: float | None, quick: bool, seed: int = 0):
    n, m = (2000, 20000) if quick else (8000, 120000)
    if alpha is None:
        return erdos_renyi(n, m, seed=seed)
    return zipf_graph(n, m, alpha=alpha, seed=seed)


def _hdb(g, qname: str) -> HybridGraphDB:
    unary = None
    if qname == "3-path":  # path endpoints carry sample predicates
        unary = {f"v{i}": node_sample(g.n_nodes, 8.0, seed=17 * i + 1)
                 for i in (1, 2)}
    return HybridGraphDB.build(g, unary)


def _pair_rows(tag: str, qname: str, g, repeats: int = 3) -> list[BenchRecord]:
    """Time the same plan with layouts forced to array vs as chosen."""
    q = get_query(qname)
    hdb = _hdb(g, qname)
    plan = plan_query(q, GraphStats.of(hdb), engine="vlftj")
    plan_arr = dataclasses.replace(
        plan, level_layouts=("array",) * len(plan.level_layouts))
    VLFTJ(q, hdb, plan=plan_arr).count()   # warm: compile cache
    VLFTJ(q, hdb, plan=plan).count()
    c_arr, us_arr = timed(lambda: VLFTJ(q, hdb, plan=plan_arr).count(),
                          repeats=repeats)
    eng = VLFTJ(q, hdb, plan=plan)
    c_hyb, us_hyb = timed(eng.count, repeats=repeats)
    assert c_arr == c_hyb, (tag, c_arr, c_hyb)
    eng.stats["bitset_rows"] = 0
    eng.count()  # one instrumented pass for the bitset row count
    speed = us_arr / max(us_hyb, 1e-9)
    return [
        Rec(f"{tag}/array", us_arr, f"count={c_arr}"),
        Rec(f"{tag}/hybrid", us_hyb,
            f"count={c_hyb};hubs={hdb.n_hubs};"
            f"bitset_rows={eng.stats['bitset_rows']};"
            f"layouts={'-'.join(plan.level_layouts)};"
            f"speedup={speed:.2f}"),
    ]


def _build_rows(quick: bool) -> list[BenchRecord]:
    rows = []
    for alpha in ALPHAS:
        g = _graph(alpha, quick)
        HybridGraphDB.build(g)
        lay, us = timed(lambda: HybridGraphDB.build(g).layout, repeats=3)
        rows.append(Rec(f"build/zipf{alpha}", us,
                        f"hubs={lay.n_hubs};words={lay.n_words};"
                        f"min_degree={lay.min_degree}"))
    return rows


def run(quick: bool = True) -> list[BenchRecord]:
    rows: list[BenchRecord] = []
    for alpha in ALPHAS:
        rows += _pair_rows(f"triangle/zipf{alpha}", "3-clique",
                           _graph(alpha, quick))
    for alpha in ALPHAS:
        rows += _pair_rows(f"path3/zipf{alpha}", "3-path",
                           _graph(alpha, quick))
    rows += _pair_rows("triangle/uniform", "3-clique", _graph(None, quick))
    rows += _build_rows(quick)
    return rows


def record_baseline(path: str | None = None, quick: bool = True) -> dict:
    """Write BENCH_layout.json: the array-vs-hybrid crossover table."""
    rows = run(quick=quick)
    payload = {
        "bench": "layout",
        "quick": quick,
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 2),
                  "derived": r.derived} for r in rows],
    }
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_layout.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="degree-adaptive layout crossover benchmark")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH json here instead of CSV rows")
    a = ap.parse_args()
    if a.out:
        payload = record_baseline(path=a.out, quick=a.quick)
        print(f"wrote {a.out} ({len(payload['rows'])} rows)")
    else:
        for row in run(quick=a.quick):
            print(row.csv())
