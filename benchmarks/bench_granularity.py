"""Table 5 — output-space partition granularity factor f (§4.10).

``PartitionedJoin`` splits the first GAO variable's domain into
``workers × f`` parts and round-robins them (static work stealing).
Reported: runtime normalized to f=1, plus the worker-load imbalance
(max/mean frontier rows) that over-partitioning smooths.
"""
from __future__ import annotations


from functools import partial

from repro.core import get_query
from repro.dist.sharded_join import PartitionedJoin

from .common import BenchRecord, bench_gdb, timed

Rec = partial(BenchRecord, bench="granularity")

FACTORS = [1, 2, 3, 4, 8, 12, 14]


def run(quick: bool = True) -> list[BenchRecord]:
    rows: list[BenchRecord] = []
    gdb = bench_gdb("wiki-Vote", 0.25 if quick else 1.0, selectivity=8)
    for qname in ["3-clique", "4-cycle", "3-path"]:
        q = get_query(qname)
        base_mk = None
        ref = None
        for f in FACTORS:
            pj = PartitionedJoin(q, gdb, n_workers=8, granularity=f)
            c, us = timed(pj.count, timeout_s=120)
            if base_mk is None:
                base_mk, ref = pj.stats["makespan"], c
            assert c == ref
            # the Table-5 metric: estimated parallel makespan (slowest
            # worker) normalized to f=1 — over-partitioning smooths the
            # power-law part-size skew; ``us`` is the sequential 1-host
            # wall time (pure overhead view).
            mk = pj.stats["makespan"]
            tt = pj.stats["total_time"]
            rows.append(Rec(
                f"t5/{qname}/f{f}", us,
                f"makespan_norm={mk / max(base_mk, 1e-9):.2f};"
                f"imbalance={mk * 8 / max(tt, 1e-9):.2f}"))
    return rows
