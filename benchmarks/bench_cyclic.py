"""Table 6 — cyclic queries ({3,4}-clique, 4-cycle) across engines.

Reproduces the paper's headline: worst-case-optimal joins stay flat where
the Selinger-style pairwise baseline blows up its intermediates (the "-"
timeouts in Table 6 are our ``JoinBlowup``/timeout entries).
"""
from __future__ import annotations

from functools import partial

from repro.core import GraphStats, JoinBlowup, count, get_query, plan_query

from .common import BenchRecord, bench_gdb, timed

Rec = partial(BenchRecord, bench="cyclic")

DATASETS = ["ca-GrQc", "wiki-Vote", "ego-Facebook", "p2p-Gnutella04"]
QUERIES = ["3-clique", "4-clique", "4-cycle"]


def run(quick: bool = True) -> list[BenchRecord]:
    scale = 0.25 if quick else 1.0
    timeout = 60 if quick else 600
    rows: list[BenchRecord] = []
    for ds in DATASETS:
        gdb = bench_gdb(ds, scale)
        m = gdb.csr.n_edges // 2
        stats = GraphStats.of(gdb)
        for qname in QUERIES:
            q = get_query(qname)
            # plan once outside the timer: the tables measure engine
            # execution, not per-call planning
            pv = plan_query(q, stats, engine="vlftj")
            pb = plan_query(q, stats, engine="binary")
            ph = plan_query(q, stats, engine="hybrid")
            ref, us = timed(lambda: count(q, gdb, plan=pv),
                            timeout_s=timeout)
            rows.append(Rec(f"t6/{qname}/{ds}/vlftj", us,
                            f"count={ref};edges={m}"))
            try:
                c2, us2 = timed(
                    lambda: count(q, gdb, plan=pb,
                                  cap=20_000_000), timeout_s=timeout)
                assert c2 == ref, (qname, ds, c2, ref)
                rows.append(Rec(f"t6/{qname}/{ds}/binary", us2,
                                f"count={c2};slowdown="
                                f"{us2 / max(us, 1):.1f}x"))
            except JoinBlowup as e:
                rows.append(Rec(f"t6/{qname}/{ds}/binary", float("inf"),
                                f"blowup_rows={e.rows}"))
            # Minesweeper analogue on cyclic = hybrid (Idea 7 skeleton)
            c3, us3 = timed(lambda: count(q, gdb, plan=ph),
                            timeout_s=timeout)
            assert c3 == ref
            rows.append(Rec(f"t6/{qname}/{ds}/hybrid", us3,
                            f"count={c3}"))
    return rows
