"""Planner benchmark: plan-cache latency + cost-model fidelity.

Two measurements per query shape:

  * ``plan/<q>/cold`` vs ``plan/<q>/cached`` — the latency of
    ``PlanCache.get_or_plan`` on a miss (full candidate enumeration +
    costing + AGM LP) vs a hit (one dict lookup).  The gap is what the
    serving layer saves on every repeated pattern shape.
  * ``costmodel/<q>/gao_rank_corr`` — Spearman rank correlation between
    the model's estimated cost and the measured vectorized-LFTJ runtime
    over a sample of candidate GAOs; ``costmodel/engines/rank_corr``
    does the same across engine candidates.  Positive correlation means
    cost-based selection is picking better plans than a blind heuristic.
  * ``qerror/<q>/L<level>`` — per-GAO-level Q-error of the planner's
    frontier-cardinality estimates against the cardinalities a traced
    run actually observed (``repro.obs``): ``max(est/obs, obs/est)``,
    1.0 = perfect.  The per-level breakdown shows *where* the
    independence assumption loses contact with a skewed graph — the
    feedback signal the adaptive-re-planning roadmap item consumes.

``python -m benchmarks.run --only planner`` or import ``run()``;
``record_baseline()`` writes ``BENCH_planner.json``.
"""
from __future__ import annotations

import json
import os
import time
from functools import partial

import numpy as np

from repro.core import (GraphStats, PlanCache, count, execute, get_query,
                        plan_query)
from repro.core.planner import candidate_gaos, candidate_plans

from .common import BenchRecord, bench_gdb, timed

Rec = partial(BenchRecord, bench="planner")

SHAPES = ["3-clique", "4-clique", "4-cycle", "3-path", "4-path",
          "1-tree", "2-comb", "2-lollipop", "3-lollipop"]
CORR_SHAPES = ["3-clique", "4-cycle", "3-path"]


def _spearman(a, b) -> float:
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    if ra.std() == 0 or rb.std() == 0:
        return 0.0
    return float(np.corrcoef(ra, rb)[0, 1])


def run(quick: bool = True) -> list[BenchRecord]:
    rows: list[BenchRecord] = []
    gdb = bench_gdb("ca-GrQc", 0.12 if quick else 1.0, selectivity=8)
    stats = GraphStats.of(gdb)

    # -- plan-cache latency: cold (miss) vs cached (hit) ---------------------
    for qname in SHAPES:
        q = get_query(qname)
        cache = PlanCache()
        t0 = time.time()
        plan = cache.get_or_plan(q, stats)
        cold_us = (time.time() - t0) * 1e6
        _, hit_us = timed(lambda: cache.get_or_plan(q, stats),
                          repeats=200, timeout_s=10)
        rows.append(Rec(f"plan/{qname}/cold", cold_us,
                        f"engine={plan.engine};gao={''.join(plan.gao)}"))
        rows.append(Rec(f"plan/{qname}/cached", hit_us,
                        f"hits={cache.hits}"))

    # -- cost model vs actual: GAO ranking -----------------------------------
    for qname in CORR_SHAPES:
        q = get_query(qname)
        gaos = candidate_gaos(q)
        if len(gaos) > 8:   # sample evenly across the candidate spectrum
            idx = np.linspace(0, len(gaos) - 1, 8).astype(int)
            gaos = [gaos[i] for i in idx]
        est, actual = [], []
        for gao in gaos:
            plan = plan_query(q, stats, engine="vlftj", gao=gao)
            execute(plan, gdb)          # warm the jit caches
            _, us = timed(lambda: execute(plan, gdb), repeats=3,
                          timeout_s=60)
            est.append(plan.est_cost)   # the pinned-gao estimate
            actual.append(us)
        rho = _spearman(np.asarray(est), np.asarray(actual))
        rows.append(Rec(f"costmodel/{qname}/gao_rank_corr", 0.0,
                        f"rho={rho:.3f};n={len(gaos)}"))

    # -- cost model vs actual: engine ranking --------------------------------
    est, actual = [], []
    for qname in SHAPES:
        q = get_query(qname)
        for plan in candidate_plans(q, stats):
            execute(plan, gdb)
            _, us = timed(lambda: execute(plan, gdb), repeats=3,
                          timeout_s=60)
            est.append(plan.est_cost)
            actual.append(us)
    rho = _spearman(np.asarray(est), np.asarray(actual))
    rows.append(Rec("costmodel/engines/rank_corr", 0.0,
                    f"rho={rho:.3f};n={len(est)}"))

    # -- estimate fidelity: per-level Q-error from traced runs ---------------
    from repro.obs import QueryTrace
    from repro.core import execute_stats
    for qname in CORR_SHAPES:
        q = get_query(qname)
        plan = plan_query(q, stats, engine="vlftj")
        tr = QueryTrace(qname, plan.gao, plan.engine)
        with tr.activate():
            execute_stats(plan, gdb)
        for rec in (tr.levels[lv] for lv in sorted(tr.levels)):
            qe = rec.get("q_error")
            if qe is None:
                continue
            rows.append(Rec(
                f"qerror/{qname}/L{rec['level']}", 0.0,
                f"var={rec.get('var')};est={rec.get('est_rows'):.4g};"
                f"obs={rec.get('obs_rows')};q={qe:.4g}"))
        mq = tr.max_q_error
        rows.append(Rec(f"qerror/{qname}/max", 0.0, f"q={mq:.4g}"))

    # -- end-to-end: served count latency with plan cache --------------------
    cache = PlanCache()
    for qname in ["3-clique", "3-path"]:
        q = get_query(qname)
        count(q, gdb, cache=cache)      # cold: plan + compile + execute
        _, us = timed(lambda: count(q, gdb, cache=cache), repeats=3,
                      timeout_s=60)
        rows.append(Rec(f"serve/{qname}/warm_count", us,
                        f"cache_hits={cache.hits}"))
    return rows


def record_baseline(path: str | None = None, quick: bool = True) -> dict:
    """Write BENCH_planner.json so future PRs have a perf trajectory."""
    rows = run(quick=quick)
    payload = {
        "bench": "planner",
        "quick": quick,
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 2),
                  "derived": r.derived} for r in rows],
    }
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_planner.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="planner latency + cost-model "
                                             "fidelity benchmark")
    ap.add_argument("--quick", action="store_true",
                    help="small graph scale (the CI smoke profile)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH json here instead of CSV rows")
    args = ap.parse_args()
    if args.out:
        payload = record_baseline(path=args.out, quick=args.quick)
        print(f"wrote {args.out} ({len(payload['rows'])} rows)")
    else:
        for row in run(quick=args.quick):
            print(row.csv())
