"""Enumeration throughput/memory benchmark: flat vs chunked vs factorized.

Three emission strategies over the same vectorized-LFTJ plans, written
to ``BENCH_enumerate.json`` by ``record_baseline``:

* ``<q>/flat`` — ``VLFTJ.enumerate()``: materialize + lex-sort the full
  output.  Derived: rows/s and the materialized bytes (the peak).
* ``<q>/chunked`` — ``ResultCursor`` pages (``core.engine.stream``):
  the final GAO level re-entered per frontier chunk, pages concatenated
  but never co-resident.  Derived: rows/s, page count, and
  ``peak_rows`` — the cursor's tail-buffer high-water mark, the number
  the bounded-memory contract is about (compare it against
  ``rows`` for the flat strategy).
* ``<q>/factorized`` — ``results.factorize_vlftj``: the trie build that
  never materializes the flat cross-product.  Derived: rows/s
  (expanded-row equivalents), trie bytes, and the compression ratio
  versus flat.

Queries: ``3-clique`` (dense core, fanout ~1) and ``3-path`` (the
high-fanout shape where factorization and chunking pay off).
"""
import json
import os
from functools import partial

from repro.core import GraphDB, GraphStats, VLFTJ, get_query
from repro.core import engine as engine_mod
from repro.core.planner import plan_query
from repro.graphs import node_sample, powerlaw_cluster
from repro.results import factorize_vlftj

from .common import BenchRecord, timed

Rec = partial(BenchRecord, bench="enumerate")

QUERIES = ("3-clique", "3-path")
PAGE_ROWS = 4096


def _gdb(quick: bool) -> GraphDB:
    g = powerlaw_cluster(1000 if quick else 3000, 5, seed=0)
    unary = {f"v{i}": node_sample(g.n_nodes, 8, seed=i)
             for i in range(1, 5)}
    return GraphDB(g, unary)


def run(quick: bool = True) -> list[BenchRecord]:
    rows: list[BenchRecord] = []
    gdb = _gdb(quick)
    stats = GraphStats.of(gdb)
    for qname in QUERIES:
        q = get_query(qname)
        plan = plan_query(q, stats, engine="vlftj", output="rows")

        def flat():
            return VLFTJ(q, gdb, plan=plan).enumerate()

        out, us = timed(flat, repeats=3)
        n = out.shape[0]
        rps = n / max(us, 1e-9) * 1e6
        rows.append(Rec(f"{qname}/flat", us,
                        f"rows={n};rows_per_s={rps:.0f};"
                        f"bytes={out.nbytes};peak_rows={n}"))

        def chunked():
            cur = engine_mod.stream(q, gdb, plan=plan,
                                    page_rows=PAGE_ROWS)
            total = 0
            for page in cur:
                total += page.shape[0]
            return cur, total

        (cur, total), us = timed(chunked, repeats=3)
        assert total == n, (total, n)
        rows.append(Rec(
            f"{qname}/chunked", us,
            f"rows={n};rows_per_s={n / max(us, 1e-9) * 1e6:.0f};"
            f"pages={cur.stats['pages']};"
            f"peak_rows={cur.stats['peak_buffer_rows']}"))

        def fact():
            return factorize_vlftj(VLFTJ(q, gdb, plan=plan))

        fr, us = timed(fact, repeats=3)
        assert fr.count() == n, (fr.count(), n)
        ratio = out.nbytes / max(1, fr.nbytes)
        rows.append(Rec(
            f"{qname}/factorized", us,
            f"rows={n};rows_per_s={n / max(us, 1e-9) * 1e6:.0f};"
            f"bytes={fr.nbytes};flat_over_fact={ratio:.2f}"))
    return rows


def record_baseline(path: str | None = None, quick: bool = True) -> dict:
    """Write BENCH_enumerate.json: flat vs chunked vs factorized."""
    rows = run(quick=quick)
    payload = {
        "bench": "enumerate",
        "quick": quick,
        "page_rows": PAGE_ROWS,
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 2),
                  "derived": r.derived} for r in rows],
    }
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_enumerate.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="enumeration flat/chunked/factorized benchmark")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH json here instead of CSV rows")
    a = ap.parse_args()
    if a.out:
        payload = record_baseline(path=a.out, quick=a.quick)
        print(f"wrote {a.out} ({len(payload['rows'])} rows)")
    else:
        for row in run(quick=a.quick):
            print(row.csv())
