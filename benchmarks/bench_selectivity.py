"""Figures 3-5 — 3-path runtime vs sample size (selectivity sweep).

The paper sweeps the node-sample size N on LiveJournal/Pokec/Orkut and
shows Minesweeper's caching advantage *growing* as samples get larger
(more redundant sub-path work for LFTJ to repeat, all of it computed once
by the message passing).  Same sweep here: selectivity 128 → 4 (sample
fraction 0.8% → 25%) at fixed graph.
"""
from __future__ import annotations

from functools import partial

from repro.core import (GraphDB, GraphStats, VLFTJ, get_query, plan_query,
                        yannakakis_count)
from repro.graphs import node_sample, powerlaw_cluster

from .common import BenchRecord, timed

Rec = partial(BenchRecord, bench="selectivity")

SELECTIVITIES = [128, 64, 32, 16, 8, 4]


def run(quick: bool = True) -> list[BenchRecord]:
    n = 4000 if quick else 50_000
    g = powerlaw_cluster(n, 6, seed=2)
    q = get_query("3-path")
    rows: list[BenchRecord] = []
    for sel in SELECTIVITIES:
        unary = {"v1": node_sample(g.n_nodes, sel, seed=11),
                 "v2": node_sample(g.n_nodes, sel, seed=13)}
        gdb = GraphDB(g, unary)
        pv = plan_query(q, GraphStats.of(gdb), engine="vlftj")
        ref, us_ms = timed(lambda: yannakakis_count(q, gdb),
                           timeout_s=120)
        c2, us_vl = timed(lambda: VLFTJ(q, gdb, rotate_checks=True,
                                        plan=pv).count(),
                          timeout_s=120)
        assert c2 == ref
        rows.append(Rec(f"f345/3-path/sel{sel}/ms-analogue", us_ms,
                        f"sample={unary['v1'].size};count={ref}"))
        rows.append(Rec(f"f345/3-path/sel{sel}/vlftj", us_vl,
                        f"ms_advantage={us_vl / max(us_ms, 1):.1f}x"))
    return rows
