"""Preemptive serving benchmark: quantum scheduler vs FIFO under mixed load.

The workload the scheduler exists for: one heavy full-graph 3-path
enumeration racing N small sparse-sample 3-path counts on one server.
Both policies run the *identical* workload through
:class:`repro.serve.scheduler.QuantumScheduler`; only the policy differs
(``fifo`` = run-to-completion in submission order, the pre-scheduler
server behaviour).  Written to ``BENCH_serve.json`` by
``record_baseline``:

* ``serve/<policy>/small`` — small-query completion latency: p50/p99 on
  the deterministic rows-expanded virtual clock (``vclock_done -
  vclock_submit``) and p99 wall micros.
* ``serve/<policy>/heavy`` — the heavy job: rows expanded, quanta,
  preemptions.
* ``serve/<policy>/total`` — work conservation + throughput: total rows
  expanded across all jobs and wall rows/s.
* ``serve/fairness`` — the headline: p99 improvement (fifo/quantum, on
  the vclock) and the throughput ratio (quantum/fifo) — preemption must
  buy fairness without giving up total throughput.

Latency on the virtual clock is exact and reproducible across runs
(``tests/test_scheduler.py::test_quantum_meter_deterministic``); wall
numbers ride along for operators.  A warm-up pass runs the workload
once untimed so jit compilation (identical kernel shapes for both
policies — windows and pages do not depend on the policy) is excluded.
"""
import json
import os
import time
from functools import partial

import numpy as np

from repro.graphs import powerlaw_cluster
from repro.serve import (QuantumScheduler, QueryRequest, QueryServer,
                         TenantQuota)

from .common import BenchRecord

Rec = partial(BenchRecord, bench="serve")

QUANTUM_ROWS = 4096
N_SMALL = 16
PAGE_ROWS = 2048


def _graph(quick: bool, smoke: bool):
    if smoke:
        return powerlaw_cluster(400, 5, seed=0)
    return powerlaw_cluster(800 if quick else 2500, 5, seed=0)


def _workload(n_small: int) -> list[QueryRequest]:
    heavy = QueryRequest("3-path", engine="vlftj", limit=10**9,
                         selectivity=1.0)
    smalls = [QueryRequest("3-path", engine="vlftj", seed=i % 4)
              for i in range(n_small)]
    return [heavy] + smalls


def _run_policy(csr, policy: str, n_small: int) -> dict:
    server = QueryServer(csr, page_rows=PAGE_ROWS)
    sched = QuantumScheduler(server, quantum_rows=QUANTUM_ROWS,
                             policy=policy,
                             default_quota=TenantQuota(
                                 max_in_flight=N_SMALL + 1))
    t0 = time.time()
    for req in _workload(n_small):
        # the heavy enumeration streams-and-discards: fairness under
        # load, not result buffering, is what this benchmark measures
        sched.submit(req, collect_rows=req.limit is None)
    results = sched.run()
    wall_s = time.time() - t0
    heavy, smalls = results[0], results[1:]
    vlat = np.array([r.stats["vclock_done"] - r.stats["vclock_submit"]
                     for r in smalls], dtype=np.int64)
    wlat = np.array([r.latency_s for r in smalls])
    total = sum(r.stats["rows_expanded"] for r in results)
    return {
        "policy": policy,
        "small_p50_vclock": int(np.percentile(vlat, 50)),
        "small_p99_vclock": int(np.percentile(vlat, 99)),
        "small_p99_wall_us": float(np.percentile(wlat, 99) * 1e6),
        "heavy_rows_expanded": heavy.stats["rows_expanded"],
        "heavy_quanta": heavy.stats["quanta"],
        "heavy_preemptions": heavy.stats["preemptions"],
        "total_rows_expanded": total,
        "wall_s": wall_s,
        "rows_per_s": total / max(wall_s, 1e-9),
    }


def run(quick: bool = True, smoke: bool = False) -> list[BenchRecord]:
    csr = _graph(quick, smoke)
    n_small = N_SMALL // 2 if smoke else N_SMALL
    _run_policy(csr, "fifo", n_small)       # warm-up: jit compiles
    out = {p: _run_policy(csr, p, n_small) for p in ("fifo", "quantum")}
    rows: list[BenchRecord] = []
    for p, m in out.items():
        rows.append(Rec(
            f"serve/{p}/small", m["small_p99_wall_us"],
            f"p50_vclock={m['small_p50_vclock']};"
            f"p99_vclock={m['small_p99_vclock']};n={n_small}"))
        rows.append(Rec(
            f"serve/{p}/heavy", 0.0,
            f"rows_expanded={m['heavy_rows_expanded']};"
            f"quanta={m['heavy_quanta']};"
            f"preemptions={m['heavy_preemptions']}"))
        rows.append(Rec(
            f"serve/{p}/total", m["wall_s"] * 1e6,
            f"rows_expanded={m['total_rows_expanded']};"
            f"rows_per_s={m['rows_per_s']:.0f}"))
    imp = out["fifo"]["small_p99_vclock"] \
        / max(out["quantum"]["small_p99_vclock"], 1)
    tput = out["quantum"]["rows_per_s"] / max(out["fifo"]["rows_per_s"],
                                              1e-9)
    rows.append(Rec(
        "serve/fairness", 0.0,
        f"p99_improvement={imp:.1f}x;throughput_ratio={tput:.3f};"
        f"equal_work="
        f"{out['quantum']['total_rows_expanded'] == out['fifo']['total_rows_expanded']}"))
    run._last = out     # record_baseline reuses the measurements
    return rows


def record_baseline(path: str | None = None, quick: bool = True,
                    smoke: bool = False) -> dict:
    """Write BENCH_serve.json: FIFO vs quantum fairness/throughput."""
    rows = run(quick=quick, smoke=smoke)
    out = run._last
    imp = out["fifo"]["small_p99_vclock"] \
        / max(out["quantum"]["small_p99_vclock"], 1)
    payload = {
        "bench": "serve",
        "quick": quick,
        "smoke": smoke,
        "quantum_rows": QUANTUM_ROWS,
        "n_small": N_SMALL // 2 if smoke else N_SMALL,
        "policies": out,
        "fairness": {
            "small_p99_improvement": round(imp, 2),
            "throughput_ratio": round(
                out["quantum"]["rows_per_s"]
                / max(out["fifo"]["rows_per_s"], 1e-9), 3),
            "equal_work": (out["quantum"]["total_rows_expanded"]
                           == out["fifo"]["total_rows_expanded"]),
        },
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 2),
                  "derived": r.derived} for r in rows],
    }
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="preemptive serving fairness benchmark")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: smallest graph, fewest smalls")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH json here instead of CSV rows")
    a = ap.parse_args()
    if a.out:
        payload = record_baseline(path=a.out, quick=True, smoke=a.smoke)
        fair = payload["fairness"]
        print(f"wrote {a.out} "
              f"(p99_improvement={fair['small_p99_improvement']}x, "
              f"throughput_ratio={fair['throughput_ratio']})")
    else:
        for row in run(quick=a.quick or a.smoke, smoke=a.smoke):
            print(row.csv())
