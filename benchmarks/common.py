"""Shared benchmark scaffolding.

Each bench module exposes ``run(quick=True) -> list[Row]``; ``run.py``
drives them all and prints ``name,us_per_call,derived`` CSV (one line per
measurement), mirroring one paper table/figure per module.

Graphs are SNAP-like synthetics (see repro.graphs.generators).  ``quick``
scales sizes for the CPU container; pass ``--full`` for larger runs.
"""
from __future__ import annotations

import math
import re
import time
from dataclasses import dataclass


from repro.core import GraphDB
from repro.graphs import node_sample
from repro.graphs.generators import make_snap_like

#: bump when the normalized record layout changes — ``BENCH_history.jsonl``
#: lines carry it so ``tools/bench_compare.py`` can refuse mixed schemas.
BENCH_SCHEMA_VERSION = 1


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


@dataclass
class BenchRecord(Row):
    """Normalized benchmark measurement: a :class:`Row` plus the owning
    bench module key and the result cardinality (the parity signal the
    regression gate checks alongside wall time).

    ``count`` is parsed from a ``count=<n>`` token in ``derived`` when
    not given explicitly, so legacy rows normalize without touching
    every call site's derived-string convention.
    """
    bench: str = ""
    count: int | None = None

    def __post_init__(self) -> None:
        if self.count is None:
            m = re.search(r"(?:^|[;,])count=(\d+)", ";" + self.derived)
            if m:
                self.count = int(m.group(1))

    def to_json(self) -> dict:
        """JSON-safe dict: ``inf`` wall (timeout/blowup rows) maps to
        null so the history file stays parseable everywhere."""
        us = self.us_per_call
        return {"bench": self.bench, "name": self.name,
                "us_per_call": round(us, 3) if math.isfinite(us) else None,
                "count": self.count, "derived": self.derived}

    @classmethod
    def of(cls, bench: str, row: "Row") -> "BenchRecord":
        """Coerce any ``Row`` (or stamp an unlabelled ``BenchRecord``)
        onto the normalized schema under bench key ``bench``."""
        if isinstance(row, BenchRecord):
            if not row.bench:
                row.bench = bench
            return row
        return cls(row.name, row.us_per_call, row.derived, bench=bench)


def git_rev() -> str | None:
    """Short commit hash of the working tree, or None outside a repo."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True)
        return out.stdout.strip() or None
    except Exception:
        return None


def run_header(quick: bool) -> dict:
    """Shared run-level fields for history lines and baseline files."""
    import uuid
    return {"schema": BENCH_SCHEMA_VERSION,
            "run_id": uuid.uuid4().hex[:12],
            "ts": round(time.time(), 3),
            "git": git_rev(),
            "quick": bool(quick)}


def append_history(path: str, records: list["BenchRecord"],
                   quick: bool = True) -> dict:
    """Append one JSONL line per record to the bench history file.

    Every line repeats the run header (``run_id`` groups one driver
    invocation) so the file stays a flat, greppable, append-only log —
    no state beyond "open for append".  Returns the header used.
    """
    import json
    hdr = run_header(quick)
    with open(path, "a") as fh:
        for rec in records:
            fh.write(json.dumps({**hdr, **rec.to_json()}) + "\n")
    return hdr


def write_baseline(path: str, records: list["BenchRecord"],
                   quick: bool = True) -> dict:
    """Write the committed regression baseline: run header plus the
    full normalized record list, one stable-sorted JSON document."""
    import json
    payload = dict(run_header(quick))
    payload["records"] = sorted(
        (r.to_json() for r in records),
        key=lambda d: (d["bench"], d["name"]))
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)
        fh.write("\n")
    return payload


def timed(fn, repeats: int = 1, timeout_s: float = 120.0):
    """(result, us_per_call); returns (None, inf) past the timeout."""
    t0 = time.time()
    result = None
    n = 0
    for _ in range(repeats):
        result = fn()
        n += 1
        if time.time() - t0 > timeout_s:
            break
    dt = (time.time() - t0) / max(1, n)
    return result, dt * 1e6


def bench_gdb(dataset: str, scale: float, seed: int = 0,
              selectivity: float = 8.0) -> GraphDB:
    g = make_snap_like(dataset, seed=seed, scale=scale)
    unary = {f"v{i}": node_sample(g.n_nodes, selectivity, seed=17 * i + 1)
             for i in range(1, 5)}
    return GraphDB(g, unary)
