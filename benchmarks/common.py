"""Shared benchmark scaffolding.

Each bench module exposes ``run(quick=True) -> list[Row]``; ``run.py``
drives them all and prints ``name,us_per_call,derived`` CSV (one line per
measurement), mirroring one paper table/figure per module.

Graphs are SNAP-like synthetics (see repro.graphs.generators).  ``quick``
scales sizes for the CPU container; pass ``--full`` for larger runs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass


from repro.core import GraphDB
from repro.graphs import node_sample
from repro.graphs.generators import make_snap_like


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn, repeats: int = 1, timeout_s: float = 120.0):
    """(result, us_per_call); returns (None, inf) past the timeout."""
    t0 = time.time()
    result = None
    n = 0
    for _ in range(repeats):
        result = fn()
        n += 1
        if time.time() - t0 > timeout_s:
            break
    dt = (time.time() - t0) / max(1, n)
    return result, dt * 1e6


def bench_gdb(dataset: str, scale: float, seed: int = 0,
              selectivity: float = 8.0) -> GraphDB:
    g = make_snap_like(dataset, seed=seed, scale=scale)
    unary = {f"v{i}": node_sample(g.n_nodes, selectivity, seed=17 * i + 1)
             for i in range(1, 5)}
    return GraphDB(g, unary)
