"""Distributed scaling benchmark: sharded join, skew, CSR sharding,
compression.

Four measurements, written to ``BENCH_dist.json`` by ``record_baseline``:

* ``join/<n>shard`` — one vectorized-LFTJ triangle expansion level over
  the full edge frontier via ``dist.spmd_join_step``, frontier
  row-sharded over 1 vs every forced host device (CI runs with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on real
  accelerators the same code path shards over the physical mesh).  The
  derived field carries rows/s and the verified triangle count.
* ``skew/{static,rebalanced}`` — the adaptive-execution headline: a
  3-path join over a Zipf graph run level-synchronously on 8 shards
  (``dist.rebalance.AdaptiveJoin``) with the static first-level deal
  frozen vs mid-join frontier re-deals.  The derived fields carry wall
  and cost-model makespans plus the rebalanced/static ratio — the
  acceptance bar is ratio <= 0.7.
* ``sharded_csr/<query>`` — ``dist.sharded_csr.sharded_count`` over a
  row-partitioned CSR (8 shards) on every tier-1 query shape, each
  verified equal to the replicated-CSR count (``match=1``), with the
  exchanged adjacency volume.
* ``train/{uncompressed,compressed}_step`` + ``loss_curves`` — the tiny
  transformer's *sharded* data-parallel train step with an f32-pmean
  wire (``make_dp_train_step``) vs the int8 error-feedback compressed
  wire (``make_compressed_train_step``) — same mesh and batch split, so
  the timing gap isolates compression; both loss trajectories are kept
  so compression quality regressions show up as curve divergence, not
  just speed.

Run standalone (``python -m benchmarks.bench_dist``) this module forces
8 host devices before jax initializes; under ``benchmarks.run`` it
measures whatever device count the process already has.  ``--skew``
runs only the skew section (fast inner loop for re-balancer work).
"""
import os
import sys
from functools import partial

if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import json
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import GraphDB, VLFTJ, get_query
from repro.core import engine as engine_mod
from repro.core.plan import executor_geometry
from repro.dist.compressed_step import (init_compressed_state,
                                        make_compressed_train_step,
                                        make_dp_train_step)
from repro.dist.rebalance import AdaptiveJoin
from repro.dist.sharded_csr import ShardedGraphDB, sharded_count
from repro.dist.sharded_join import spmd_join_step
from repro.graphs import node_sample, powerlaw_cluster, zipf_graph
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.train.optimizer import OptimizerConfig, init_opt_state

from .common import BenchRecord, timed

Rec = partial(BenchRecord, bench="dist")


def _mesh(n_shards: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:n_shards]), ("data",))


def _triangle_frontier(g, pad_to: int):
    ea = g.edge_array()
    fr = ea[ea[:, 0] < ea[:, 1]].astype(np.int32)
    pad = (-len(fr)) % pad_to
    fr = np.pad(fr, ((0, pad), (0, 0)))
    mult = np.ones(len(fr), np.int64)
    if pad:
        mult[len(fr) - pad:] = 0
    return fr, mult


def _join_rows(quick: bool) -> list[BenchRecord]:
    rows: list[BenchRecord] = []
    g = powerlaw_cluster(1200 if quick else 4000, 6, seed=0)
    gdb = GraphDB(g, {})
    n_dev = jax.device_count()
    fr, mult = _triangle_frontier(g, pad_to=n_dev)
    width, _ = executor_geometry(gdb.max_degree)
    kw = dict(probe_cols=(0, 1), n_unary=0, lower_cols=(1,), upper_cols=(),
              width=width, n_iter=gdb.bsearch_iters, needs_degree=False)
    ref = VLFTJ(get_query("3-clique"), gdb).count()
    args = (gdb.dev("indptr"), gdb.dev("indices"),
            jnp.asarray(fr), jnp.asarray(mult))
    for shards in sorted({1, n_dev}):
        step = spmd_join_step(_mesh(shards), kw)
        total = int(step(*args))                      # warm + verify
        assert total == ref, (total, ref)
        _, us = timed(lambda: int(step(*args)), repeats=5, timeout_s=120)
        rps = len(fr) / (us / 1e6)
        rows.append(Rec(f"join/{shards}shard", us,
                        f"rows={len(fr)};rows_per_s={rps:.0f};"
                        f"triangles={total}"))
    return rows


SKEW_SHARDS = 16
CSR_SHARDS = 8
SHARDED_CSR_QUERIES = ("3-clique", "4-clique", "4-cycle", "3-path",
                       "2-lollipop", "3-lollipop")


def _skew_rows(quick: bool) -> list[BenchRecord]:
    """Static vs mid-join-rebalanced makespan on a Zipf 3-path.

    The workload is the regime where mid-join skew is real: *selective*
    seeds (an RDBMS-style ``v1`` predicate leaves ~80 seeds, so the
    law-of-large-numbers self-balancing of big frontiers never kicks
    in) over an assortative Zipf graph (hubs neighbor hubs — a seed's
    subtree mass is badly predicted by its own degree, which is all the
    static first-level deal can see).  Makespans are min-of-3 per
    variant; the derived fields also carry the deterministic cost-model
    ratio the tests assert on.  ``quick`` deliberately does NOT scale
    this section down: below this graph size per-shard level work drops
    under the per-dispatch fixed cost and wall makespan stops tracking
    the skew at all (the whole section is ~1-2 min).
    """
    n, m = (8000, 200000)
    g = zipf_graph(n, m, alpha=1.4, seed=0)
    unary = {f"v{i}": node_sample(g.n_nodes, 150, seed=i)
             for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    q = get_query("3-path")
    reps = 3
    runs = {}
    for label, rebalance in (("static", False), ("rebalanced", True)):
        aj = AdaptiveJoin(q, gdb, n_shards=SKEW_SHARDS, threshold=1.2,
                          rebalance=rebalance)
        aj.count()          # warm the level kernels
        best, count = None, None
        for _ in range(reps):
            aj2 = AdaptiveJoin(q, gdb, n_shards=SKEW_SHARDS,
                               threshold=1.2, rebalance=rebalance)
            count = aj2.count()
            if best is None or aj2.stats["makespan"] < best["makespan"]:
                best = aj2.stats
        runs[label] = (best, count)
    ratio = (runs["rebalanced"][0]["makespan"]
             / max(runs["static"][0]["makespan"], 1e-12))
    cost_ratio = (runs["rebalanced"][0]["cost_makespan"]
                  / max(runs["static"][0]["cost_makespan"], 1e-12))
    assert runs["static"][1] == runs["rebalanced"][1]
    rows = []
    for label in ("static", "rebalanced"):
        st, cnt = runs[label]
        rows.append(Rec(
            f"skew/{label}", st["makespan"] * 1e6,
            f"count={cnt};shards={SKEW_SHARDS};"
            f"cost_makespan={st['cost_makespan']:.0f};"
            f"rebalances={len(st.get('rebalances', []))};"
            + (f"makespan_ratio={ratio:.3f};"
               f"cost_ratio={cost_ratio:.3f}"
               if label == "rebalanced" else
               f"total_time_us={st['total_time'] * 1e6:.0f}")))
    return rows


def _sharded_csr_rows(quick: bool) -> list[BenchRecord]:
    """Row-partitioned-CSR count parity on every tier-1 query shape."""
    g = powerlaw_cluster(300 if quick else 1000, 4, seed=11)
    unary = {f"v{i}": node_sample(g.n_nodes, 6, seed=i)
             for i in range(1, 5)}
    gdb = GraphDB(g, unary)
    rows: list[BenchRecord] = []
    for qname in SHARDED_CSR_QUERIES:
        sg = ShardedGraphDB(g, CSR_SHARDS, unary)
        ref = engine_mod.count(get_query(qname), gdb, engine="vlftj")
        got, us = timed(lambda: sharded_count(get_query(qname), sg),
                        repeats=1, timeout_s=300)
        assert got == ref, (qname, got, ref)
        rows.append(Rec(
            f"sharded_csr/{qname}", us,
            f"count={got};match={int(got == ref)};"
            f"shards={CSR_SHARDS};"
            f"exchanged_values={sg.exchange['values']}"))
    return rows


def _train_rows(quick: bool) -> tuple[list[BenchRecord], dict]:
    rows: list[BenchRecord] = []
    cfg = TransformerConfig(name="bench", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=256,
                            dtype=jnp.float32, remat=False)
    n_dev = jax.device_count()
    mesh = _mesh(n_dev)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    n_steps = 12 if quick else 30
    p0 = init_params(jax.random.PRNGKey(0), cfg)

    def lf(p, b):
        return loss_fn(p, b, cfg)

    def batch_at(s):
        rng = np.random.default_rng(s)
        toks = rng.integers(0, 64, (16, 32), dtype=np.int32)
        return {"tokens": toks, "labels": (toks * 3 + 7) % 256}

    curves: dict = {"n_devices": n_dev, "steps": n_steps}
    for compressed in (False, True):
        p = jax.tree.map(jnp.copy, p0)
        opt = init_opt_state(p)
        err = init_compressed_state(p, mesh)
        step_c = make_compressed_train_step(lf, oc, mesh)
        # fair baseline: the same sharded DP step over an f32 wire, so
        # the timing gap isolates compression, not data parallelism
        step_u = make_dp_train_step(lf, oc, mesh)
        losses, times = [], []
        for s in range(n_steps):
            batch = batch_at(s)
            t0 = time.time()
            if compressed:
                p, opt, err, m = step_c(p, opt, err, batch)
            else:
                p, opt, m = step_u(p, opt, batch)
            jax.block_until_ready(m["loss"])
            times.append(time.time() - t0)
            losses.append(round(float(m["loss"]), 5))
        name = "compressed" if compressed else "uncompressed"
        curves[name] = losses
        us = float(np.median(times[1:])) * 1e6       # skip the compile step
        rows.append(Rec(f"train/{name}_step", us,
                        f"loss0={losses[0]:.3f};lossN={losses[-1]:.3f}"))
    return rows, curves


def run(quick: bool = True, skew_only: bool = False) -> list[BenchRecord]:
    if skew_only:
        return _skew_rows(quick)
    rows = _join_rows(quick) + _skew_rows(quick) + _sharded_csr_rows(quick)
    train_rows, _ = _train_rows(quick)
    return rows + train_rows


def record_baseline(path: str | None = None, quick: bool = True) -> dict:
    """Write BENCH_dist.json: shard scaling, skew re-balancing,
    sharded-CSR parity, and compression loss curves."""
    rows = _join_rows(quick) + _skew_rows(quick) + _sharded_csr_rows(quick)
    train_rows, curves = _train_rows(quick)
    payload = {
        "bench": "dist",
        "quick": quick,
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 2),
                  "derived": r.derived} for r in rows + train_rows],
        "loss_curves": curves,
    }
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_dist.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="distributed join/compression "
                                             "scaling benchmark")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skew", action="store_true",
                    help="run only the static-vs-rebalanced skew section")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH json here instead of CSV rows")
    a = ap.parse_args()
    if a.out and a.skew:
        rows = _skew_rows(quick=a.quick)
        payload = {"bench": "dist-skew", "quick": a.quick,
                   "rows": [{"name": r.name,
                             "us_per_call": round(r.us_per_call, 2),
                             "derived": r.derived} for r in rows]}
        with open(a.out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {a.out} ({len(payload['rows'])} rows)")
    elif a.out:
        payload = record_baseline(path=a.out, quick=a.quick)
        print(f"wrote {a.out} ({len(payload['rows'])} rows)")
    else:
        for row in run(quick=a.quick, skew_only=a.skew):
            print(row.csv())
