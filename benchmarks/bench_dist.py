"""Distributed scaling benchmark: sharded join throughput + compression.

Two measurements, written to ``BENCH_dist.json`` by ``record_baseline``:

* ``join/<n>shard`` — one vectorized-LFTJ triangle expansion level over
  the full edge frontier via ``dist.spmd_join_step``, frontier
  row-sharded over 1 vs every forced host device (CI runs with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on real
  accelerators the same code path shards over the physical mesh).  The
  derived field carries rows/s and the verified triangle count.
* ``train/{uncompressed,compressed}_step`` + ``loss_curves`` — the tiny
  transformer's *sharded* data-parallel train step with an f32-pmean
  wire (``make_dp_train_step``) vs the int8 error-feedback compressed
  wire (``make_compressed_train_step``) — same mesh and batch split, so
  the timing gap isolates compression; both loss trajectories are kept
  so compression quality regressions show up as curve divergence, not
  just speed.

Run standalone (``python -m benchmarks.bench_dist``) this module forces
8 host devices before jax initializes; under ``benchmarks.run`` it
measures whatever device count the process already has.
"""
import os
import sys

if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import json
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import GraphDB, VLFTJ, get_query
from repro.core.plan import executor_geometry
from repro.dist.compressed_step import (init_compressed_state,
                                        make_compressed_train_step,
                                        make_dp_train_step)
from repro.dist.sharded_join import spmd_join_step
from repro.graphs import powerlaw_cluster
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.train.optimizer import OptimizerConfig, init_opt_state

from .common import Row, timed


def _mesh(n_shards: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:n_shards]), ("data",))


def _triangle_frontier(g, pad_to: int):
    ea = g.edge_array()
    fr = ea[ea[:, 0] < ea[:, 1]].astype(np.int32)
    pad = (-len(fr)) % pad_to
    fr = np.pad(fr, ((0, pad), (0, 0)))
    mult = np.ones(len(fr), np.int64)
    if pad:
        mult[len(fr) - pad:] = 0
    return fr, mult


def _join_rows(quick: bool) -> list[Row]:
    rows: list[Row] = []
    g = powerlaw_cluster(1200 if quick else 4000, 6, seed=0)
    gdb = GraphDB(g, {})
    n_dev = jax.device_count()
    fr, mult = _triangle_frontier(g, pad_to=n_dev)
    width, _ = executor_geometry(gdb.max_degree)
    kw = dict(probe_cols=(0, 1), n_unary=0, lower_cols=(1,), upper_cols=(),
              width=width, n_iter=gdb.bsearch_iters, needs_degree=False)
    ref = VLFTJ(get_query("3-clique"), gdb).count()
    args = (gdb.dev("indptr"), gdb.dev("indices"),
            jnp.asarray(fr), jnp.asarray(mult))
    for shards in sorted({1, n_dev}):
        step = spmd_join_step(_mesh(shards), kw)
        total = int(step(*args))                      # warm + verify
        assert total == ref, (total, ref)
        _, us = timed(lambda: int(step(*args)), repeats=5, timeout_s=120)
        rps = len(fr) / (us / 1e6)
        rows.append(Row(f"join/{shards}shard", us,
                        f"rows={len(fr)};rows_per_s={rps:.0f};"
                        f"triangles={total}"))
    return rows


def _train_rows(quick: bool) -> tuple[list[Row], dict]:
    rows: list[Row] = []
    cfg = TransformerConfig(name="bench", n_layers=2, d_model=64, n_heads=4,
                            n_kv_heads=2, d_ff=128, vocab_size=256,
                            dtype=jnp.float32, remat=False)
    n_dev = jax.device_count()
    mesh = _mesh(n_dev)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    n_steps = 12 if quick else 30
    p0 = init_params(jax.random.PRNGKey(0), cfg)

    def lf(p, b):
        return loss_fn(p, b, cfg)

    def batch_at(s):
        rng = np.random.default_rng(s)
        toks = rng.integers(0, 64, (16, 32), dtype=np.int32)
        return {"tokens": toks, "labels": (toks * 3 + 7) % 256}

    curves: dict = {"n_devices": n_dev, "steps": n_steps}
    for compressed in (False, True):
        p = jax.tree.map(jnp.copy, p0)
        opt = init_opt_state(p)
        err = init_compressed_state(p, mesh)
        step_c = make_compressed_train_step(lf, oc, mesh)
        # fair baseline: the same sharded DP step over an f32 wire, so
        # the timing gap isolates compression, not data parallelism
        step_u = make_dp_train_step(lf, oc, mesh)
        losses, times = [], []
        for s in range(n_steps):
            batch = batch_at(s)
            t0 = time.time()
            if compressed:
                p, opt, err, m = step_c(p, opt, err, batch)
            else:
                p, opt, m = step_u(p, opt, batch)
            jax.block_until_ready(m["loss"])
            times.append(time.time() - t0)
            losses.append(round(float(m["loss"]), 5))
        name = "compressed" if compressed else "uncompressed"
        curves[name] = losses
        us = float(np.median(times[1:])) * 1e6       # skip the compile step
        rows.append(Row(f"train/{name}_step", us,
                        f"loss0={losses[0]:.3f};lossN={losses[-1]:.3f}"))
    return rows, curves


def run(quick: bool = True) -> list[Row]:
    rows = _join_rows(quick)
    train_rows, _ = _train_rows(quick)
    return rows + train_rows


def record_baseline(path: str | None = None, quick: bool = True) -> dict:
    """Write BENCH_dist.json: shard scaling + compression loss curves."""
    rows = _join_rows(quick)
    train_rows, curves = _train_rows(quick)
    payload = {
        "bench": "dist",
        "quick": quick,
        "rows": [{"name": r.name, "us_per_call": round(r.us_per_call, 2),
                  "derived": r.derived} for r in rows + train_rows],
        "loss_curves": curves,
    }
    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_dist.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="distributed join/compression "
                                             "scaling benchmark")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH json here instead of CSV rows")
    a = ap.parse_args()
    if a.out:
        payload = record_baseline(path=a.out, quick=a.quick)
        print(f"wrote {a.out} ({len(payload['rows'])} rows)")
    else:
        for row in run(quick=a.quick):
            print(row.csv())
