"""Table 4 — GAO selection: NEO vs non-NEO orders on the 4-path.

The paper's 7 representative orderings of (a,b,c,d,e); ABCDE/BACDE/BCADE/
CBADE/CBDAE are NEOs, ABDCE/BADCE are not.  Run on both the faithful
Minesweeper (small scale: the CDS chain property breaks for non-NEO,
costing spec-branch blowup) and the vectorized engine (level order changes
probe fan-out).
"""
from __future__ import annotations

from functools import partial

from repro.core import Minesweeper, VLFTJ, get_query, is_neo, Hypergraph

from .common import BenchRecord, bench_gdb, timed

Rec = partial(BenchRecord, bench="gao")

ORDERS = ["abcde", "bacde", "bcade", "cbade", "cbdae", "abdce", "badce"]


def run(quick: bool = True) -> list[BenchRecord]:
    q = get_query("4-path")
    hg = Hypergraph.of(q)
    rows: list[BenchRecord] = []
    gdb_small = bench_gdb("ca-GrQc", 0.012 if quick else 0.05,
                          selectivity=8)
    db = gdb_small.to_database()
    gdb = bench_gdb("ca-GrQc", 0.12 if quick else 1.0, selectivity=8)
    ref = None
    for order in ORDERS:
        gao = tuple(order)
        neo = is_neo(hg, gao)
        c1, us_ms = timed(lambda: Minesweeper(q, db, gao=gao).count(),
                          timeout_s=90)
        c2, us_vl = timed(lambda: VLFTJ(q, gdb, gao=gao).count(),
                          timeout_s=90)
        if ref is None:
            ref = (c1, c2)
        assert (c1, c2) == ref, (order, c1, c2, ref)
        rows.append(Rec(f"t4/gao-{order}/ms", us_ms,
                        f"neo={neo};count={c1}"))
        rows.append(Rec(f"t4/gao-{order}/vlftj", us_vl,
                        f"neo={neo};count={c2}"))
    return rows
