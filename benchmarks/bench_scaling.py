"""Figures 6-7 — grow the instance until the pairwise engine dies.

The paper grows LiveJournal subsets; the asymptotic driver is the wedge
(2-path) intermediate a pairwise plan must materialize for clique
queries: Ω(Σ_v deg(v)²) rows, versus the WCOJ bound Õ(N + output).  On
this CPU container the cleanest way to walk that curve is a *density*
sweep at fixed node count — wedges grow ~m² per step while the WCOJ
frontier grows ~m — until the baseline crosses its 20M-row cap
(the analogue of the paper's "-" timeouts) and the worst-case-optimal
engine keeps cruising.

The vectorized engine runs with rotated checks (§Perf A2, the adopted
default for production).
"""
from __future__ import annotations

from functools import partial

import numpy as np

from repro.core import GraphDB, JoinBlowup, VLFTJ, binary_join_count, \
    get_query
from repro.graphs import powerlaw_cluster

from .common import BenchRecord, timed

Rec = partial(BenchRecord, bench="scaling")

CAP = 20_000_000


def run(quick: bool = True) -> list[BenchRecord]:
    n = 6000 if quick else 20000
    densities = [4, 8, 16, 32] if quick else [4, 8, 16, 32, 48]
    rows: list[BenchRecord] = []
    for qname in ["3-clique", "4-clique"]:
        q = get_query(qname)
        for m in densities:
            g = powerlaw_cluster(n, m, seed=1)
            gdb = GraphDB(g, {})
            deg = g.degrees.astype(np.int64)
            wedges = int((deg * (deg - 1) // 2).sum())
            eng = VLFTJ(q, gdb, rotate_checks=True)
            ref, us = timed(eng.count, timeout_s=300)
            rows.append(Rec(f"f67/{qname}/m{m}/vlftj", us,
                            f"edges={g.n_edges // 2};wedges={wedges};"
                            f"count={ref}"))
            try:
                c2, us2 = timed(lambda: binary_join_count(
                    q, gdb.to_database(), cap=CAP), timeout_s=300)
                assert c2 == ref
                rows.append(Rec(f"f67/{qname}/m{m}/binary", us2,
                                f"wedges={wedges}"))
            except JoinBlowup as e:
                rows.append(Rec(f"f67/{qname}/m{m}/binary", float("inf"),
                                f"BLOWUP rows={e.rows}>{CAP} "
                                f"(paper: '-')"))
    return rows
