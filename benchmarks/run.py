"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs paper-scale
sizes (slow on CPU); default is the quick profile.

Every run also appends normalized :class:`benchmarks.common.BenchRecord`
lines to ``BENCH_history.jsonl`` (``--history`` to relocate,
``--no-history`` to skip) — the append-only log that
``tools/bench_compare.py`` gates CI perf regressions against.
``--baseline-out`` additionally writes the single-document baseline
snapshot that gets committed.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: cyclic,acyclic,ideas,gao,"
                         "granularity,scaling,agm,planner,dist,"
                         "enumerate,layout,serve")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="append-only JSONL bench log (default "
                         "BENCH_history.jsonl)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip writing the history file")
    ap.add_argument("--baseline-out", default=None,
                    help="also write a BENCH_baseline.json snapshot here")
    args = ap.parse_args()
    quick = not args.full

    modules = {
        "cyclic": "bench_cyclic",          # Table 6
        "acyclic": "bench_acyclic",        # Table 7
        "ideas": "bench_ideas",            # Tables 1-3
        "gao": "bench_gao",                # Table 4
        "granularity": "bench_granularity",    # Table 5
        "scaling": "bench_scaling",        # Figures 6-7
        "selectivity": "bench_selectivity",    # Figures 3-5
        "agm": "bench_agm",                # Appendix A
        "planner": "bench_planner",        # plan cache + cost model
        "dist": "bench_dist",              # sharded join + compression
        "enumerate": "bench_enumerate",    # flat/chunked/factorized rows
        "layout": "bench_layout",          # bitset/array crossover
        "serve": "bench_serve",            # preemptive scheduler fairness
    }
    chosen = (args.only.split(",") if args.only else list(modules))
    unknown = [k for k in chosen if k not in modules]
    if unknown:
        ap.error(f"unknown --only keys {unknown}; "
                 f"options: {','.join(modules)}")
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    records = []
    import importlib

    from .common import BenchRecord, append_history, write_baseline
    for key in chosen:
        mod_name = modules[key]
        # import lazily: one module's missing dependency (e.g. the
        # unimplemented repro.dist) must not take down the others
        try:
            mod = importlib.import_module(f".{mod_name}", __package__)
            for row in mod.run(quick=quick):
                # modules emit BenchRecord already; `of` stamps the
                # bench key on any plain Row that slips through
                rec = BenchRecord.of(key, row)
                records.append(rec)
                print(rec.csv(), flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{key}/ERROR,inf,{type(e).__name__}: {e}", flush=True)
    if records and not args.no_history:
        hdr = append_history(args.history, records, quick=quick)
        print(f"# history: {len(records)} records -> {args.history} "
              f"(run_id={hdr['run_id']})", file=sys.stderr)
    if records and args.baseline_out:
        write_baseline(args.baseline_out, records, quick=quick)
        print(f"# baseline -> {args.baseline_out}", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s, module_failures={failures}",
          file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
