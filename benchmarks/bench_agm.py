"""Appendix A — AGM bounds: the worst-case-optimality certificates.

For every benchmark query: the optimal fractional edge cover (scipy LP),
the AGM output bound for the instance, and the realized output count —
verifying ``count <= AGM(Q)`` and showing the gap the worst-case-optimal
runtime guarantee is measured against.
"""
from __future__ import annotations

from functools import partial

from repro.core import count, fractional_edge_cover, get_query

from .common import BenchRecord, bench_gdb, timed

Rec = partial(BenchRecord, bench="agm")


def run(quick: bool = True) -> list[BenchRecord]:
    rows: list[BenchRecord] = []
    gdb = bench_gdb("ca-GrQc", 0.25 if quick else 1.0, selectivity=8)
    sizes = gdb.to_database().sizes()
    for qname in ["3-clique", "4-clique", "4-cycle", "3-path", "4-path",
                  "2-comb", "1-tree", "2-lollipop"]:
        q = get_query(qname)
        (x, log2b), us = timed(lambda: fractional_edge_cover(q, sizes))
        bound = 2.0 ** log2b
        c = count(q, gdb, engine="auto")
        assert c <= bound * 1.0000001, (qname, c, bound)
        rows.append(Rec(f"agm/{qname}", us,
                        f"bound={bound:.3g};count={c};"
                        f"cover={','.join(f'{v:.2f}' for v in x)}"))
    return rows
