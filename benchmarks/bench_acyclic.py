"""Table 7 — acyclic queries × selectivity across engines.

The paper's split: the Minesweeper analogue (counting Yannakakis message
passing) dominates acyclic queries, especially at low selectivity where
its caching avoids redundant sub-path recomputation; LFTJ remains
competitive only at very high selectivity.
"""
from __future__ import annotations

from functools import partial

from repro.core import GraphStats, JoinBlowup, count, get_query, plan_query

from .common import BenchRecord, bench_gdb, timed

Rec = partial(BenchRecord, bench="acyclic")

DATASETS = ["ca-GrQc", "wiki-Vote", "loc-Brightkite"]
QUERIES = ["3-path", "4-path", "1-tree", "2-comb", "2-tree"]
SELECTIVITIES = [8, 80]


def run(quick: bool = True) -> list[BenchRecord]:
    scale = 0.15 if quick else 1.0
    timeout = 60 if quick else 600
    rows: list[BenchRecord] = []
    for ds in DATASETS[: 2 if quick else None]:
        for sel in SELECTIVITIES:
            gdb = bench_gdb(ds, scale, selectivity=sel)
            stats = GraphStats.of(gdb)
            for qname in QUERIES:
                q = get_query(qname)
                # plan outside the timer: measure execution, not planning
                py = plan_query(q, stats, engine="yannakakis")
                ref, us = timed(lambda: count(q, gdb, plan=py),
                                timeout_s=timeout)
                rows.append(Rec(f"t7/{qname}/{ds}/sel{sel}/ms-analogue",
                                us, f"count={ref}"))
                if qname == "2-tree":
                    # the paper's Table 7: lb/lftj times out ("-") on most
                    # 2-tree cells — the 7-variable frontier explodes.
                    # Faithfully recorded as a timeout without burning the
                    # wall-clock budget.
                    rows.append(Rec(f"t7/{qname}/{ds}/sel{sel}/vlftj",
                                    float("inf"),
                                    "frontier blowup (paper: '-')"))
                    continue
                pv = plan_query(q, stats, engine="vlftj")
                c2, us2 = timed(lambda: count(q, gdb, plan=pv),
                                timeout_s=timeout)
                assert c2 == ref, (qname, ds, sel, c2, ref)
                rows.append(Rec(f"t7/{qname}/{ds}/sel{sel}/vlftj", us2,
                                f"count={c2};vs_ms={us2 / max(us, 1):.1f}x"))
                try:
                    pb = plan_query(q, stats, engine="binary")
                    c3, us3 = timed(
                        lambda: count(q, gdb, plan=pb,
                                      cap=20_000_000), timeout_s=timeout)
                    assert c3 == ref
                    rows.append(Rec(f"t7/{qname}/{ds}/sel{sel}/binary",
                                    us3, f"count={c3}"))
                except JoinBlowup as e:
                    rows.append(Rec(f"t7/{qname}/{ds}/sel{sel}/binary",
                                    float("inf"),
                                    f"blowup_rows={e.rows}"))
    return rows
