"""Generate EXPERIMENTS.md §Dry-run and §Roofline from reports/dryrun/.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        --reports reports/dryrun --out reports/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

MOVE_HINTS = {
    ("memory", "train"): "fuse/remat-policy to cut activation traffic; "
                         "bf16 master-grad; bigger per-chip tiles",
    ("memory", "prefill"): "flash-attention tiling keeps scores in VMEM "
                           "(bytes term is un-fused HLO upper bound)",
    ("memory", "decode"): "KV-cache reads dominate: quantize KV (int8) "
                          "or widen batch per chip",
    ("memory", "forward"): "gather/scatter traffic: fuse probe rounds, "
                           "pack candidate tiles (see wcoj hillclimb)",
    ("memory", "retrieval"): "single gather-dot: batch more candidates "
                             "per chip",
    ("compute", "train"): "raise per-chip arithmetic intensity: larger "
                          "microbatch or less remat",
    ("collective", "train"): "overlap grad all-reduce (dist/overlap) + "
                             "int8 compression (dist/compression)",
    ("collective", "decode"): "shrink TP collectives: wider batch or "
                              "communication-avoiding head layout",
    ("collective", "prefill"): "sequence-parallel attention lowers "
                               "all-gather volume",
}


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ["B", "KB", "MB", "GB", "TB"]:
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    return f"{x:.2e}"


import re

_VARIANT_RE = re.compile(r"(_b\d|_c\d|_tile|_rot2l|_rot|_opt)$")


def is_variant(shape: str) -> bool:
    return bool(_VARIANT_RE.search(shape))


def load(reports_dir):
    recs = []
    for f in sorted(glob.glob(os.path.join(reports_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def render(recs) -> str:
    variants = [r for r in recs if is_variant(r["shape"])]
    recs = [r for r in recs if not is_variant(r["shape"])]
    single = [r for r in recs if r["mesh"] == "pod16x16"]
    multi = [r for r in recs if r["mesh"] == "pod2x16x16"]
    out = []
    out.append("## §Dry-run (16x16 single pod = 256 chips; 2x16x16 "
               "multi-pod = 512 chips)\n")
    out.append("Every (architecture × shape) lowered **and compiled** "
               "with `jax.jit(...).lower(...).compile()` under "
               "`--xla_force_host_platform_device_count=512`.  "
               "Per-device memory from `compiled.memory_analysis()`; "
               "collective traffic parsed from optimized HLO "
               "(scan-layer models cost-probed at L∈{1,2} and "
               "extrapolated — XLA counts a scan body once).\n")
    out.append("| arch | shape | mesh | status | compile s | arg bytes/dev "
               "| temp bytes/dev | AR bytes | AG bytes | RS bytes | "
               "A2A bytes | CP bytes |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP ({r['reason'][:40]}...) | | | | | | | | |")
            continue
        m = r["memory"]
        c = r.get("coll", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']} | {fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | "
            f"{fmt_bytes(c.get('all-reduce', 0))} | "
            f"{fmt_bytes(c.get('all-gather', 0))} | "
            f"{fmt_bytes(c.get('reduce-scatter', 0))} | "
            f"{fmt_bytes(c.get('all-to-all', 0))} | "
            f"{fmt_bytes(c.get('collective-permute', 0))} |")
    out.append("\n## §Roofline (single-pod 16x16, 256 chips; v5e "
               "constants: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)\n")
    out.append("Terms in seconds/step.  `useful` = MODEL_FLOPS / "
               "(HLO FLOPs × chips) — 6·N·D for dense LMs, 6·N_active·D "
               "for MoE, family equivalents elsewhere.  The memory term "
               "uses XLA's pre-fusion `bytes accessed` (an upper bound on "
               "HBM traffic — see the §Perf note).\n")
    out.append("| arch | shape | t_compute | t_memory | t_collective | "
               "bottleneck | useful | move the bottleneck by |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in single:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | {r.get('reason','')[:60]} |")
            continue
        rl = r["roofline"]
        hint = MOVE_HINTS.get((rl["bottleneck"], r["kind"]), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['t_compute'])} | "
            f"{fmt_s(rl['t_memory'])} | {fmt_s(rl['t_collective'])} | "
            f"**{rl['bottleneck']}** | {rl['useful_ratio']:.2f} | "
            f"{hint} |")
    # multi-pod deltas
    out.append("\n### Multi-pod (2×16×16) check\n")
    out.append("All cells recompile on the 512-chip mesh; the pod axis "
               "composes with data parallelism, halving per-chip FLOPs "
               "and adding cross-pod all-reduce traffic:\n")
    out.append("| arch | shape | flops/chip 1-pod | flops/chip 2-pod | "
               "AR bytes 1-pod | AR bytes 2-pod |")
    out.append("|---|---|---|---|---|---|")
    by_key = {(r["arch"], r["shape"]): r for r in single
              if r["status"] == "ok"}
    for r in multi:
        if r["status"] != "ok":
            continue
        s = by_key.get((r["arch"], r["shape"]))
        if s is None:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{s['roofline']['flops_per_chip']:.3g} | "
            f"{r['roofline']['flops_per_chip']:.3g} | "
            f"{fmt_bytes(s['coll'].get('all-reduce', 0))} | "
            f"{fmt_bytes(r['coll'].get('all-reduce', 0))} |")
    # §Perf variant cells
    out.append("\n### §Perf variant cells (see EXPERIMENTS.md §Perf)\n")
    out.append("| arch | variant | t_compute | t_memory | t_collective | "
               "temp/dev |")
    out.append("|---|---|---|---|---|---|")
    for r in variants:
        if r["status"] != "ok" or r["mesh"] != "pod16x16":
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['t_compute'])} | "
            f"{fmt_s(rl['t_memory'])} | {fmt_s(rl['t_collective'])} | "
            f"{fmt_bytes(r['memory']['temp_bytes'])} |")
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default="reports/dryrun")
    ap.add_argument("--out", default="reports/roofline.md")
    args = ap.parse_args()
    md = render(load(args.reports))
    with open(args.out, "w") as f:
        f.write(md)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
