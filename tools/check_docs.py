"""Docs gate: code blocks must import, relative links must resolve.

Two failure modes docs rot into, both cheap to gate in CI:

* a ``python`` fenced block references an API that was renamed or
  removed — every block is compiled (syntax) and its ``import`` /
  ``from`` statements are executed (so ``from repro.serve import
  QuantumScheduler`` fails the build the day the symbol disappears);
  block bodies are NOT run (doc examples may be long-running);
* a relative markdown link points at a file that moved — every
  ``[text](target)`` with a non-URL target must resolve on disk,
  relative to the file containing it (``#anchors`` and absolute URLs
  are skipped).

Usage: ``PYTHONPATH=src python tools/check_docs.py [files...]``
(default: README.md and docs/*.md).  Exit 1 with a per-finding report
on any failure.
"""
from __future__ import annotations

import ast
import glob
import re
import sys

FENCE = re.compile(r"```python[^\n]*\n(.*?)```", re.S)
# [text](target) — but not ![image](...) captures we care to treat
# differently, and not reference-style links
LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_code_blocks(path: str, text: str) -> list[str]:
    errors: list[str] = []
    for i, block in enumerate(FENCE.findall(text), 1):
        where = f"{path}: python block #{i}"
        try:
            tree = ast.parse(block)
        except SyntaxError as e:
            errors.append(f"{where}: syntax error: {e}")
            continue
        imports = [node for node in tree.body
                   if isinstance(node, (ast.Import, ast.ImportFrom))]
        if not imports:
            continue
        src = "\n".join(ast.unparse(node) for node in imports)
        try:
            exec(compile(src, where, "exec"), {})
        except Exception as e:  # noqa: BLE001 — report any import failure
            errors.append(f"{where}: import check failed: "
                          f"{type(e).__name__}: {e}")
    return errors


def check_links(path: str, text: str) -> list[str]:
    import os
    errors: list[str] = []
    base = os.path.dirname(os.path.abspath(path))
    for target in LINK.findall(text):
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            errors.append(f"{path}: dead relative link: {target}")
    return errors


def main(argv: list[str]) -> int:
    files = argv or sorted({"README.md", *glob.glob("docs/*.md")})
    errors: list[str] = []
    for path in files:
        with open(path) as f:
            text = f.read()
        errors += check_code_blocks(path, text)
        errors += check_links(path, text)
    for e in errors:
        print(f"ERROR: {e}")
    n_files = len(files)
    print(f"checked {n_files} file(s): "
          f"{'FAIL' if errors else 'OK'} ({len(errors)} error(s))")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
