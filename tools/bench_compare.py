"""CI perf-regression gate: latest bench history run vs committed baseline.

Reads the append-only ``BENCH_history.jsonl`` written by
``benchmarks/run.py``, takes the **latest run** (max ``ts`` among
``run_id`` groups), and diffs it against the committed
``BENCH_baseline.json`` snapshot.  Two failure classes:

* **wall regression** — a record's ``us_per_call`` exceeds the baseline
  by more than ``--threshold`` (default 1.3x).  Sub-``--min-us``
  measurements (default 200us) are skipped: at that scale the container
  scheduler jitter swamps any real signal.  A record that was finite in
  the baseline but timed out (null wall) in the run always fails.
* **parity drift** — a record's ``count`` differs from the baseline's.
  Counts are exact join cardinalities on seeded graphs; any drift is a
  correctness bug wearing a perf costume, so there is no tolerance.

``--calibrate`` divides every wall ratio by the **median** ratio across
all compared records before applying the threshold.  Raw wall clocks
shift fleet-wide between machines and process contexts (a subset run
pays cold XLA compiles the full baseline run amortized; CI runners are
not the baseline box) — the median captures that shared drift, and a
genuine regression still sticks out because it moves one record, not
the fleet.  Calibration needs ``>= 8`` comparable records to trust the
median; below that it is a no-op.  Count parity is never calibrated.

Records present on only one side are reported but do not fail the gate
(benches get added and retired; the baseline refresh is a deliberate
commit).  Mixed ``schema`` versions refuse to compare.

``--self-test`` proves the gate can fail: it clones the baseline into a
synthetic history run with one record slowed 2x, runs the comparison
in-process, and exits 0 iff that regression is caught.

Usage::

    python tools/bench_compare.py --baseline BENCH_baseline.json \
        --history BENCH_history.jsonl [--threshold 1.3] [--min-us 200]
    python tools/bench_compare.py --self-test --baseline BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys


def load_baseline(path: str) -> dict:
    with open(path) as fh:
        doc = json.load(fh)
    if "records" not in doc:
        raise SystemExit(f"{path}: not a baseline file (no 'records')")
    return doc


def latest_run(history_path: str) -> tuple[dict, list[dict]]:
    """(header-ish fields, records) of the most recent run in the log."""
    runs: dict[str, list[dict]] = {}
    with open(history_path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise SystemExit(f"{history_path}:{ln}: bad JSON: {e}")
            runs.setdefault(rec.get("run_id", "?"), []).append(rec)
    if not runs:
        raise SystemExit(f"{history_path}: empty history")
    run_id = max(runs, key=lambda r: max(x.get("ts", 0) for x in runs[r]))
    recs = runs[run_id]
    hdr = {k: recs[0].get(k) for k in ("schema", "run_id", "ts", "git",
                                       "quick")}
    return hdr, recs


def key(rec: dict) -> tuple[str, str]:
    return (rec.get("bench", ""), rec.get("name", ""))


def compare(baseline: dict, run_hdr: dict, run_recs: list[dict],
            threshold: float = 1.3, min_us: float = 200.0,
            calibrate: bool = False) -> tuple[list[str], list[str]]:
    """(failures, notes) — the gate fails iff ``failures`` is non-empty."""
    failures: list[str] = []
    notes: list[str] = []
    if baseline.get("schema") != run_hdr.get("schema"):
        failures.append(
            f"schema mismatch: baseline={baseline.get('schema')} "
            f"run={run_hdr.get('schema')} — refresh the baseline")
        return failures, notes
    if baseline.get("quick") != run_hdr.get("quick"):
        notes.append(
            f"profile mismatch (baseline quick={baseline.get('quick')}, "
            f"run quick={run_hdr.get('quick')}): wall ratios unreliable")
    base = {key(r): r for r in baseline["records"]}
    run = {key(r): r for r in run_recs}
    for k in sorted(base.keys() - run.keys()):
        notes.append(f"missing from run: {k[0]}/{k[1]}")
    for k in sorted(run.keys() - base.keys()):
        notes.append(f"new (not in baseline): {k[0]}/{k[1]}")
    # fleet-wide drift: median wall ratio over the comparable pairs
    drift = 1.0
    if calibrate:
        ratios = []
        for k in base.keys() & run.keys():
            bw = base[k].get("us_per_call")
            rw = run[k].get("us_per_call")
            if bw is not None and rw is not None and bw > 0 \
                    and max(bw, rw) >= min_us:
                ratios.append(rw / bw)
        if len(ratios) >= 8:    # too few pairs: the median IS the signal
            drift = statistics.median(ratios)
            notes.append(f"calibrated: median drift {drift:.2f}x "
                         f"over {len(ratios)} records divided out")
        else:
            notes.append(f"calibration skipped: only {len(ratios)} "
                         f"comparable records (< 8)")
    for k in sorted(base.keys() & run.keys()):
        b, r = base[k], run[k]
        label = f"{k[0]}/{k[1]}"
        # parity: exact counts on seeded graphs — zero tolerance
        if b.get("count") is not None and r.get("count") is not None \
                and b["count"] != r["count"]:
            failures.append(
                f"PARITY {label}: count {b['count']} -> {r['count']}")
        bw, rw = b.get("us_per_call"), r.get("us_per_call")
        if bw is None and rw is None:
            continue            # both timed out / blowup rows: stable
        if bw is not None and rw is None:
            failures.append(
                f"WALL {label}: {bw:.0f}us -> timeout/inf")
            continue
        if bw is None and rw is not None:
            notes.append(f"recovered {label}: inf -> {rw:.0f}us")
            continue
        if max(bw, rw) < min_us:
            continue            # below the noise floor: skip
        ratio = (rw / bw) / drift
        if ratio > threshold:
            failures.append(
                f"WALL {label}: {bw:.0f}us -> {rw:.0f}us "
                f"({ratio:.2f}x > {threshold:.2f}x"
                + (f" after {drift:.2f}x drift" if drift != 1.0 else "")
                + ")")
        elif 1.0 / ratio > threshold:
            notes.append(
                f"improved {label}: {bw:.0f}us -> {rw:.0f}us "
                f"({1.0 / ratio:.2f}x faster)")
    return failures, notes


def self_test(baseline: dict, threshold: float, min_us: float) -> int:
    """Inject a synthetic 2x slowdown and require the gate to fail."""
    timed = [r for r in baseline["records"]
             if r.get("us_per_call") is not None
             and r["us_per_call"] >= min_us]
    if not timed:
        print("self-test: no baseline record above the noise floor",
              file=sys.stderr)
        return 1
    victim = key(timed[0])
    hdr = {"schema": baseline.get("schema"), "run_id": "selftest",
           "ts": baseline.get("ts", 0), "quick": baseline.get("quick")}
    fake = []
    for r in baseline["records"]:
        r = dict(r)
        if key(r) == victim:
            r["us_per_call"] = r["us_per_call"] * 2.0
        fake.append(r)
    failures, _ = compare(baseline, hdr, fake, threshold, min_us)
    want = f"WALL {victim[0]}/{victim[1]}"
    caught = any(f.startswith(want) for f in failures)
    # the clean clone must also PASS — a gate that always fails is
    # as useless as one that never does
    clean, _ = compare(baseline, hdr, [dict(r) for r in baseline["records"]],
                       threshold, min_us)
    if caught and not clean:
        print(f"self-test OK: injected 2x slowdown on "
              f"{victim[0]}/{victim[1]} caught; clean clone passes")
        return 0
    print(f"self-test FAILED: caught={caught} "
          f"clean_failures={clean}", file=sys.stderr)
    return 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="max allowed wall ratio run/baseline "
                         "(default 1.3)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="ignore wall deltas when both sides are below "
                         "this (scheduler-jitter noise floor)")
    ap.add_argument("--calibrate", action="store_true",
                    help="divide wall ratios by the fleet-median drift "
                         "before thresholding (cross-machine / "
                         "cold-vs-warm robustness)")
    ap.add_argument("--self-test", action="store_true",
                    help="inject a synthetic 2x slowdown and verify "
                         "the gate fails on it")
    args = ap.parse_args()
    baseline = load_baseline(args.baseline)
    if args.self_test:
        return self_test(baseline, args.threshold, args.min_us)
    hdr, recs = latest_run(args.history)
    failures, notes = compare(baseline, hdr, recs,
                              args.threshold, args.min_us,
                              calibrate=args.calibrate)
    print(f"bench_compare: run {hdr['run_id']} "
          f"({len(recs)} records) vs baseline "
          f"{baseline.get('run_id')} ({len(baseline['records'])} records)")
    for n in notes:
        print(f"  note: {n}")
    for f in failures:
        print(f"  FAIL: {f}")
    if failures:
        print(f"bench_compare: {len(failures)} regression(s)",
              file=sys.stderr)
        return 1
    print("bench_compare: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
