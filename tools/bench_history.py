"""Inspect the append-only bench history log (``BENCH_history.jsonl``).

Three read-only views over the lines ``benchmarks/run.py`` appends:

* ``runs`` (default) — one line per run: run_id, timestamp, git rev,
  quick/full profile, record count, bench modules covered.
* ``tail`` — the records of the latest run (or ``--run <id>``), as
  ``bench,name,us_per_call,count`` CSV.
* ``trend --name <record-name>`` — that record's wall time across every
  run that measured it, oldest first, with the ratio to the previous
  run; the quickest way to see when a regression landed.

Usage::

    python tools/bench_history.py [runs|tail|trend] \
        [--history BENCH_history.jsonl] [--run ID] [--name agm/3-clique]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def load(path: str) -> list[dict]:
    recs = []
    try:
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    recs.append(json.loads(line))
    except FileNotFoundError:
        raise SystemExit(f"{path}: no history yet "
                         f"(run `python -m benchmarks.run` first)")
    if not recs:
        raise SystemExit(f"{path}: empty history")
    return recs


def by_run(recs: list[dict]) -> list[tuple[str, list[dict]]]:
    """Runs ordered oldest -> newest by their records' max ts."""
    runs: dict[str, list[dict]] = {}
    for r in recs:
        runs.setdefault(r.get("run_id", "?"), []).append(r)
    return sorted(runs.items(),
                  key=lambda kv: max(x.get("ts", 0) for x in kv[1]))


def _fmt_ts(ts: float) -> str:
    return time.strftime("%Y-%m-%d %H:%M:%S", time.gmtime(ts))


def cmd_runs(runs: list[tuple[str, list[dict]]]) -> None:
    print("run_id,ts_utc,git,profile,records,benches")
    for run_id, rs in runs:
        benches = sorted({r.get("bench", "?") for r in rs})
        r0 = rs[0]
        prof = "quick" if r0.get("quick") else "full"
        print(f"{run_id},{_fmt_ts(max(x.get('ts', 0) for x in rs))},"
              f"{r0.get('git') or '-'},{prof},{len(rs)},"
              f"{'+'.join(benches)}")


def cmd_tail(runs: list[tuple[str, list[dict]]], run_id: str | None) -> None:
    if run_id is None:
        run_id, rs = runs[-1]
    else:
        match = dict(runs)
        if run_id not in match:
            raise SystemExit(f"run {run_id!r} not in history "
                             f"(see `bench_history.py runs`)")
        rs = match[run_id]
    print(f"# run {run_id}")
    print("bench,name,us_per_call,count")
    for r in rs:
        us = r.get("us_per_call")
        print(f"{r.get('bench')},{r.get('name')},"
              f"{'inf' if us is None else f'{us:.1f}'},"
              f"{r.get('count') if r.get('count') is not None else ''}")


def cmd_trend(runs: list[tuple[str, list[dict]]], name: str) -> None:
    print(f"# trend for {name}")
    print("run_id,ts_utc,us_per_call,vs_prev")
    prev = None
    hits = 0
    for run_id, rs in runs:
        for r in rs:
            if r.get("name") != name:
                continue
            hits += 1
            us = r.get("us_per_call")
            if us is None:
                ratio = "inf"
            elif prev:
                ratio = f"{us / prev:.2f}x"
            else:
                ratio = "-"
            print(f"{run_id},{_fmt_ts(r.get('ts', 0))},"
                  f"{'inf' if us is None else f'{us:.1f}'},{ratio}")
            if us is not None:
                prev = us
    if not hits:
        raise SystemExit(f"no record named {name!r} in history")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("cmd", nargs="?", default="runs",
                    choices=["runs", "tail", "trend"])
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--run", default=None,
                    help="run_id for `tail` (default: latest)")
    ap.add_argument("--name", default=None,
                    help="record name for `trend`")
    args = ap.parse_args()
    runs = by_run(load(args.history))
    if args.cmd == "runs":
        cmd_runs(runs)
    elif args.cmd == "tail":
        cmd_tail(runs, args.run)
    else:
        if not args.name:
            ap.error("trend requires --name")
        cmd_trend(runs, args.name)
    return 0


if __name__ == "__main__":
    sys.exit(main())
