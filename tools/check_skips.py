#!/usr/bin/env python3
"""Skip-budget gate: fail CI when pytest skipped anything off-allowlist.

Usage: python tools/check_skips.py <junit-report.xml> [allowlist.txt]

The allowlist (default ``tests/skip_allowlist.txt``) holds one entry per
line, ``#`` comments allowed.  An entry matches a skipped test when it
equals the test id (``classname::name``) or is its parametrize prefix
(id starts with ``entry[``).  Any skipped test without a match fails the
build — so a test un-skipped by a landed feature (e.g. ``repro.dist``)
can never silently start skipping again.  Stale allowlist entries (no
longer skipping) are reported as warnings so the budget only shrinks.
"""
from __future__ import annotations

import sys
import xml.etree.ElementTree as ET


def load_allowlist(path: str) -> list[str]:
    try:
        with open(path) as f:
            lines = f.read().splitlines()
    except FileNotFoundError:
        return []
    return [ln.strip() for ln in lines
            if ln.strip() and not ln.strip().startswith("#")]


def skipped_ids(report: str) -> list[str]:
    tree = ET.parse(report)
    out = []
    for case in tree.iter("testcase"):
        if case.find("skipped") is not None:
            out.append(f"{case.get('classname')}::{case.get('name')}")
    return out


def matches(test_id: str, entry: str) -> bool:
    return test_id == entry or test_id.startswith(entry + "[")


def main(argv: list[str]) -> int:
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 2
    report = argv[0]
    try:
        ET.parse(report)
    except (OSError, ET.ParseError) as e:
        print(f"cannot read junit report {report!r}: {e}")
        return 2
    allowlist_path = argv[1] if len(argv) > 1 else "tests/skip_allowlist.txt"
    allowed = load_allowlist(allowlist_path)
    skipped = skipped_ids(report)

    unexpected = [s for s in skipped
                  if not any(matches(s, e) for e in allowed)]
    stale = [e for e in allowed
             if not any(matches(s, e) for s in skipped)]
    print(f"skip budget: {len(skipped)} skipped, "
          f"{len(allowed)} allowlisted, {len(unexpected)} unexpected")
    for e in stale:
        print(f"  warning: stale allowlist entry (no longer skips): {e}")
    if unexpected:
        print("unexpected skips (add a feature, not an allowlist entry):")
        for s in unexpected:
            print(f"  {s}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
