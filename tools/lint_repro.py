"""AST lint passes encoding this repo's cross-module invariants.

``ruff`` covers generic Python hygiene; these rules cover contracts no
generic linter knows about — the ones PRs 8/9 could only enforce with
runtime meter-comparison tests:

==========================  ==============================================
rule id                     invariant
==========================  ==============================================
``obs-device-free``         the obs host-side harvest path
                            (``obs/trace.py``, ``obs/schema.py``,
                            ``obs/metrics.py``) never imports or touches
                            ``jax`` — observability must add zero device
                            dispatches
``engine-stats-keys``       every engine's ``self.stats`` dict literal
                            sources every ``ENGINE_STATS_SOURCE_KEYS``
                            entry (``rows_expanded``, ``level_rows``) —
                            the scheduler meters on the first, Q-error
                            needs the second
``contextvar-pairing``      every ``ContextVar.set()`` is paired with a
                            ``reset()`` in an enclosing ``finally`` —
                            an unpaired activation leaks trace/profile
                            state across requests
``snapshot-no-pickle``      snapshot/serialization paths (``serve/``,
                            ``results/``) never use ``pickle`` and
                            always pass ``allow_pickle=False`` to
                            ``np.save``/``np.load``
``quantum-wallclock``       quantum-metering code (``*Budget`` classes,
                            ``charge`` methods) never reads wall clocks
                            — preemption must be deterministic and
                            replayable
``unused-public-symbol``    (note) module-level public symbols in
                            ``src/repro`` nobody references from source,
                            tests, benchmarks, tools, or docs
==========================  ==============================================

All findings report through :class:`repro.analysis.Finding` — the same
record the static plan verifier emits — and the JSON document matches
``python -m repro.analysis``'s, so CI's ``static-analysis`` job uploads
one artifact schema.  Suppress a finding by appending
``# repro: noqa-<rule-id>`` to its line.  ``--self-test`` runs every
rule against its embedded good/bad fixtures and requires the bad one to
fire and the good one to pass (mirroring ``tools/bench_compare.py``).

Usage::

    python tools/lint_repro.py [--format=json] [--out findings.json]
    python tools/lint_repro.py --self-test
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis import Finding, FindingReport, filter_suppressed  # noqa: E402
from repro.obs.schema import ENGINE_STATS_SOURCE_KEYS  # noqa: E402


def _parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    par: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            par[child] = node
    return par


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for an Attribute/Name chain, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class Rule:
    """One lint rule: an id, a path scope, a per-file AST check, and
    embedded good/bad fixtures driving ``--self-test``."""

    id: str = ""
    severity: str = "error"
    #: self-test fixtures: ``bad`` must fire, ``good`` must not.
    good: str = ""
    bad: str = ""
    #: path used when checking fixtures (rules scope by path)
    fixture_path: str = ""

    def applies(self, path: str) -> bool:
        raise NotImplementedError

    def check(self, tree: ast.Module, path: str, source: str
              ) -> list[Finding]:
        raise NotImplementedError


class ObsHostPurity(Rule):
    """obs-device-free: no jax reachable from obs host-side harvest code.

    ``obs/profile.py`` is deliberately out of scope — it *is* the device
    accounting layer (``sample_memory`` reads live-buffer metadata).
    The harvest/trace/metrics path must stay importable and runnable
    with zero device work.
    """

    id = "obs-device-free"
    scope = ("src/repro/obs/trace.py", "src/repro/obs/schema.py",
             "src/repro/obs/metrics.py")
    fixture_path = "src/repro/obs/trace.py"
    good = "import numpy as np\n\ndef harvest(stats):\n    return dict(stats)\n"
    bad = ("import jax.numpy as jnp\n\n"
           "def harvest(stats):\n    return jnp.sum(stats)\n")

    def applies(self, path: str) -> bool:
        return path.replace(os.sep, "/") in self.scope

    def check(self, tree, path, source):
        out = []
        for node in ast.walk(tree):
            offender = None
            if isinstance(node, ast.Import):
                offender = next((a.name for a in node.names
                                 if a.name.split(".")[0] in ("jax",
                                                             "jaxlib")),
                                None)
            elif isinstance(node, ast.ImportFrom):
                root = (node.module or "").split(".")[0]
                if root in ("jax", "jaxlib"):
                    offender = node.module
            elif isinstance(node, ast.Name) and node.id in ("jax", "jnp"):
                offender = node.id
            if offender:
                out.append(Finding(
                    self.id, self.severity, path, node.lineno,
                    f"obs host-side harvest code touches {offender!r} — "
                    f"observability must add zero device dispatches",
                    "keep device accounting in obs/profile.py; harvest "
                    "host dicts/numpy only"))
        return out


class EngineStatsSchema(Rule):
    """engine-stats-keys: engine ``self.stats`` literals source the
    mandatory schema keys."""

    id = "engine-stats-keys"
    fixture_path = "src/repro/core/fixture_engine.py"
    good = ("class GoodEngine:\n"
            "    def __init__(self):\n"
            "        self.stats = {'rows_expanded': 0, 'level_rows': {},\n"
            "                      'probes': 0}\n"
            "    def count(self):\n"
            "        return 0\n")
    bad = ("class BadEngine:\n"
           "    def __init__(self):\n"
           "        self.stats = {'probes': 0}\n"
           "    def count(self):\n"
           "        return 0\n")

    def applies(self, path: str) -> bool:
        p = path.replace(os.sep, "/")
        return p.startswith("src/repro/core/") and p.endswith(".py")

    def check(self, tree, path, source):
        out = []
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            has_count = any(isinstance(n, ast.FunctionDef)
                            and n.name == "count" for n in cls.body)
            if not has_count:
                continue
            for node in ast.walk(cls):
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    target, value = node.targets[0], node.value
                elif isinstance(node, ast.AnnAssign):
                    target, value = node.target, node.value
                else:
                    continue
                if _dotted(target) != "self.stats" \
                        or not isinstance(value, ast.Dict):
                    continue
                keys = {k.value for k in value.keys
                        if isinstance(k, ast.Constant)}
                missing = [k for k in ENGINE_STATS_SOURCE_KEYS
                           if k not in keys]
                if missing:
                    out.append(Finding(
                        self.id, self.severity, path, node.lineno,
                        f"{cls.name}.stats literal is missing schema "
                        f"key(s) {missing} "
                        f"(ENGINE_STATS_SOURCE_KEYS)",
                        "initialize every source key in the literal and "
                        "maintain it during execution — the scheduler "
                        "meters rows_expanded; Q-error needs "
                        "level_rows"))
        return out


class ContextvarPairing(Rule):
    """contextvar-pairing: every ContextVar ``.set()`` has a ``.reset()``
    in an enclosing ``finally`` block of the same function."""

    id = "contextvar-pairing"
    fixture_path = "src/repro/obs/fixture_ctx.py"
    good = ("from contextvars import ContextVar\n"
            "_ACTIVE = ContextVar('active', default=None)\n\n"
            "def activate(tr):\n"
            "    token = _ACTIVE.set(tr)\n"
            "    try:\n"
            "        yield tr\n"
            "    finally:\n"
            "        _ACTIVE.reset(token)\n")
    bad = ("from contextvars import ContextVar\n"
           "_ACTIVE = ContextVar('active', default=None)\n\n"
           "def activate(tr):\n"
           "    _ACTIVE.set(tr)\n"
           "    return tr\n")

    def applies(self, path: str) -> bool:
        p = path.replace(os.sep, "/")
        return p.startswith("src/repro/") and p.endswith(".py")

    def check(self, tree, path, source):
        ctxvars = {t.id for node in ast.walk(tree)
                   if isinstance(node, ast.Assign)
                   and isinstance(node.value, ast.Call)
                   and _dotted(node.value.func).split(".")[-1]
                   == "ContextVar"
                   for t in node.targets if isinstance(t, ast.Name)}
        if not ctxvars:
            return []
        par = _parents(tree)
        out = []
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "set"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in ctxvars):
                continue
            var = node.func.value.id
            if not self._reset_in_enclosing_finally(node, par, var):
                out.append(Finding(
                    self.id, self.severity, path, node.lineno,
                    f"{var}.set() without a paired {var}.reset() in an "
                    f"enclosing finally — an exception leaks the "
                    f"activation across requests",
                    "token = var.set(...); try: ... finally: "
                    "var.reset(token)"))
        return out

    @staticmethod
    def _reset_in_enclosing_finally(node, par, var) -> bool:
        cur = node
        while cur in par:
            cur = par[cur]
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # a `try/finally` later in the same function (the
                # token-then-try idiom) also pairs the activation
                for t in ast.walk(cur):
                    if isinstance(t, ast.Try) and any(
                            isinstance(c, ast.Call)
                            and isinstance(c.func, ast.Attribute)
                            and c.func.attr == "reset"
                            and _dotted(c.func.value) == var
                            for f in t.finalbody for c in ast.walk(f)):
                        return True
                return False
        return False


class SnapshotNoPickle(Rule):
    """snapshot-no-pickle: serve/results serialization paths are
    pickle-free (a pickled snapshot would happily swallow device
    arrays and arbitrary code)."""

    id = "snapshot-no-pickle"
    fixture_path = "src/repro/serve/fixture_snap.py"
    good = ("import numpy as np\n\n"
            "def to_bytes(arr, buf):\n"
            "    np.save(buf, arr, allow_pickle=False)\n")
    bad = ("import pickle\n\n"
           "def to_bytes(snapshot):\n"
           "    return pickle.dumps(snapshot)\n")

    def applies(self, path: str) -> bool:
        p = path.replace(os.sep, "/")
        return (p.startswith("src/repro/serve/")
                or p.startswith("src/repro/results/")) \
            and p.endswith(".py")

    def check(self, tree, path, source):
        out = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                mod = getattr(node, "module", None) or ""
                names = [a.name for a in node.names]
                if "pickle" in names or mod.split(".")[0] == "pickle":
                    out.append(Finding(
                        self.id, self.severity, path, node.lineno,
                        "pickle import in a snapshot/serialization path",
                        "serialize with a json header + "
                        "np.save(allow_pickle=False)"))
            elif isinstance(node, ast.Call):
                fn = _dotted(node.func)
                if fn.startswith("pickle."):
                    out.append(Finding(
                        self.id, self.severity, path, node.lineno,
                        f"{fn}() in a snapshot/serialization path",
                        "snapshots must be pickle-free"))
                elif fn in ("np.save", "np.load", "numpy.save",
                            "numpy.load"):
                    ok = any(kw.arg == "allow_pickle"
                             and isinstance(kw.value, ast.Constant)
                             and kw.value.value is False
                             for kw in node.keywords)
                    if not ok:
                        out.append(Finding(
                            self.id, self.severity, path, node.lineno,
                            f"{fn}() without allow_pickle=False",
                            "always pass allow_pickle=False in "
                            "snapshot paths"))
        return out


class QuantumNoWallclock(Rule):
    """quantum-wallclock: quantum metering is deterministic — budgets
    charge logical work (rows expanded), never wall clocks, so a
    suspend/resume schedule replays identically."""

    id = "quantum-wallclock"
    fixture_path = "src/repro/serve/fixture_budget.py"
    good = ("class RowBudget:\n"
            "    def __init__(self, quantum):\n"
            "        self.left = quantum\n"
            "    def charge(self, rows):\n"
            "        self.left -= rows\n"
            "        return self.left > 0\n")
    bad = ("import time\n\n"
           "class TimeBudget:\n"
           "    def __init__(self, quantum_s):\n"
           "        self.t0 = time.monotonic()\n"
           "        self.quantum_s = quantum_s\n"
           "    def charge(self, rows):\n"
           "        return time.monotonic() - self.t0 < self.quantum_s\n")

    _CLOCKS = ("time.time", "time.monotonic", "time.perf_counter",
               "time.process_time", "datetime.now", "datetime.utcnow",
               "datetime.datetime.now")

    def applies(self, path: str) -> bool:
        p = path.replace(os.sep, "/")
        return p.startswith("src/repro/serve/") and p.endswith(".py")

    def check(self, tree, path, source):
        out = []
        for scope in ast.walk(tree):
            in_budget_cls = (isinstance(scope, ast.ClassDef)
                             and "Budget" in scope.name)
            in_charge_fn = (isinstance(scope, ast.FunctionDef)
                            and scope.name == "charge")
            if not (in_budget_cls or in_charge_fn):
                continue
            where = scope.name
            for node in ast.walk(scope):
                if isinstance(node, ast.Call) \
                        and _dotted(node.func) in self._CLOCKS:
                    out.append(Finding(
                        self.id, self.severity, path, node.lineno,
                        f"wall-clock read {_dotted(node.func)}() inside "
                        f"quantum-metering code ({where})",
                        "meter logical work (rows expanded) — "
                        "suspend/resume must replay deterministically"))
        return out


class UnusedPublicSymbols(Rule):
    """unused-public-symbol (note): module-level public defs in
    ``src/repro`` with no reference anywhere else in the repo.  Repo-
    wide rule — driven through :meth:`check_repo`, not per-file."""

    id = "unused-public-symbol"
    severity = "note"
    fixture_path = "src/repro/core/fixture_dead.py"
    good = "def used_helper():\n    return 1\n"
    bad = "def totally_unreferenced_helper():\n    return 1\n"

    def applies(self, path: str) -> bool:
        return False            # repo-wide, see check_repo

    def check(self, tree, path, source):
        return []

    def definitions(self, tree: ast.Module, path: str
                    ) -> list[tuple[str, int]]:
        defs = []
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                if not node.name.startswith("_"):
                    defs.append((node.name, node.lineno))
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) \
                            and not t.id.startswith("_") \
                            and t.id != "__all__" and t.id.isupper():
                        defs.append((t.id, node.lineno))
        return defs

    def check_repo(self, files: dict[str, tuple[ast.Module, str]],
                   corpus: dict[str, str]) -> list[Finding]:
        out = []
        for path, (tree, source) in sorted(files.items()):
            p = path.replace(os.sep, "/")
            if not p.startswith("src/repro/") or p.endswith("__init__.py"):
                continue
            for name, lineno in self.definitions(tree, path):
                pat = re.compile(rf"\b{re.escape(name)}\b")
                referenced = False
                for other, text in corpus.items():
                    hits = len(pat.findall(text))
                    if other == path:
                        hits -= 1       # its own definition line
                    if hits > 0:
                        referenced = True
                        break
                if not referenced:
                    out.append(Finding(
                        self.id, self.severity, path, lineno,
                        f"public symbol {name!r} has no reference in "
                        f"src/tests/benchmarks/tools/docs",
                        "delete it, underscore it, or cover it with a "
                        "test/doc"))
        return out


RULES: list[Rule] = [ObsHostPurity(), EngineStatsSchema(),
                     ContextvarPairing(), SnapshotNoPickle(),
                     QuantumNoWallclock(), UnusedPublicSymbols()]

#: directories whose text counts as a "reference" for the dead-code pass
_CORPUS_DIRS = ("src", "tests", "benchmarks", "tools", "docs")
_CORPUS_FILES = ("README.md", "ROADMAP.md", "ARCHITECTURE.md")


def _iter_files(root: str, exts=(".py",)):
    for base, dirs, names in os.walk(root):
        dirs[:] = [d for d in dirs
                   if d not in ("__pycache__", ".git", ".venv")]
        for n in sorted(names):
            if n.endswith(exts):
                yield os.path.join(base, n)


def collect(repo: str = _REPO):
    """Parse every lintable source file; returns ``(files, corpus)``.

    ``files`` maps repo-relative path -> (ast, source) for
    ``src/repro``; ``corpus`` maps path -> text for everything the
    dead-code pass accepts as a reference.
    """
    files: dict[str, tuple[ast.Module, str]] = {}
    corpus: dict[str, str] = {}
    for d in _CORPUS_DIRS:
        droot = os.path.join(repo, d)
        if not os.path.isdir(droot):
            continue
        exts = (".py",) if d in ("src", "tests", "benchmarks", "tools") \
            else (".md",)
        for path in _iter_files(droot, exts):
            rel = os.path.relpath(path, repo).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
            corpus[rel] = text
            if rel.startswith("src/repro/") and rel.endswith(".py"):
                files[rel] = (ast.parse(text, filename=rel), text)
    for name in _CORPUS_FILES:
        path = os.path.join(repo, name)
        if os.path.exists(path):
            with open(path, encoding="utf-8") as fh:
                corpus[name] = fh.read()
    return files, corpus


def run_lint(repo: str = _REPO, rules: list[Rule] | None = None
             ) -> tuple[FindingReport, dict[str, str]]:
    rules = RULES if rules is None else rules
    files, corpus = collect(repo)
    findings: list[Finding] = []
    for path, (tree, source) in sorted(files.items()):
        for rule in rules:
            if rule.applies(path):
                findings.extend(rule.check(tree, path, source))
    for rule in rules:
        if hasattr(rule, "check_repo"):
            findings.extend(rule.check_repo(files, corpus))
    sources = {p: s for p, (_, s) in files.items()}
    return FindingReport(filter_suppressed(findings, sources)), sources


def self_test() -> int:
    """Each rule must fire on its bad fixture and pass its good one."""
    failures = []
    for rule in RULES:
        if isinstance(rule, UnusedPublicSymbols):
            # repo-wide rule: fixture files with an empty/self corpus
            bad_tree = ast.parse(rule.bad)
            good_tree = ast.parse(rule.good)
            bad = rule.check_repo(
                {rule.fixture_path: (bad_tree, rule.bad)},
                {rule.fixture_path: rule.bad})
            good = rule.check_repo(
                {rule.fixture_path: (good_tree, rule.good)},
                {rule.fixture_path: rule.good,
                 "tests/test_x.py": "used_helper()\n"})
        else:
            bad = rule.check(ast.parse(rule.bad), rule.fixture_path,
                             rule.bad)
            good = rule.check(ast.parse(rule.good), rule.fixture_path,
                              rule.good)
        if not bad:
            failures.append(f"{rule.id}: bad fixture did NOT fire")
        if good:
            failures.append(f"{rule.id}: good fixture fired: {good}")
        if not failures or failures[-1].split(":")[0] != rule.id:
            print(f"self-test: {rule.id} fires on bad, quiet on good")
    # suppression must actually suppress
    sup_rule = SnapshotNoPickle()
    sup_src = ("import numpy as np\n\n"
               "def to_bytes(arr, buf):\n"
               "    np.save(buf, arr)  # repro: noqa-snapshot-no-pickle\n")
    raw = sup_rule.check(ast.parse(sup_src), sup_rule.fixture_path,
                         sup_src)
    kept = filter_suppressed(raw, {sup_rule.fixture_path: sup_src})
    if not raw:
        failures.append("noqa self-test: finding did not fire pre-filter")
    if kept:
        failures.append("noqa self-test: suppression marker ignored")
    if not failures:
        print("self-test: noqa suppression honored")
    for msg in failures:
        print(f"self-test FAILED: {msg}", file=sys.stderr)
    if not failures:
        print(f"self-test OK: {len(RULES)} rules verified")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="tools/lint_repro.py")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="write the JSON findings document here")
    ap.add_argument("--self-test", action="store_true",
                    help="run every rule against its embedded good/bad "
                         "fixtures; the gate must fire")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    report, _ = run_lint()
    doc = report.to_json(job="lint-repro",
                         rules=[r.id for r in RULES])
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(doc + "\n")
    if args.format == "json":
        print(doc)
    else:
        for f in report.findings:
            print(f.format())
        print(f"lint_repro: {len(report.findings)} finding(s), "
              f"{len(report.errors())} error(s) over {len(RULES)} rules")
    return 0 if report.gate_passes else 1


if __name__ == "__main__":
    raise SystemExit(main())
