"""Train a small LM end-to-end with the production substrate.

Demonstrates the full loop on CPU: deterministic pipeline, AdamW+cosine,
grad accumulation, async fault-tolerant checkpointing, auto-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.train.loop import Trainer
from repro.train.optimizer import OptimizerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro-lm-ckpt")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = TransformerConfig(
        name="demo-lm", n_layers=args.layers, d_model=args.d_model,
        n_heads=4, n_kv_heads=2, d_ff=4 * args.d_model, vocab_size=1024,
        dtype=jnp.float32, remat=False)
    print(f"model: {cfg.n_params/1e6:.2f}M params")

    # a learnable synthetic stream: tokens follow t+1 = (3t+7) % V with
    # noise, so loss decreasing proves the pipeline end to end
    import numpy as np

    def get_batch(step):
        rng = np.random.default_rng(step)
        b, s = 16, 64
        t0 = rng.integers(0, 1024, (b, 1))
        seq = [t0]
        for _ in range(s):
            nxt = (3 * seq[-1] + 7) % 1024
            flip = rng.random((b, 1)) < 0.05
            nxt = np.where(flip, rng.integers(0, 1024, (b, 1)), nxt)
            seq.append(nxt)
        arr = np.concatenate(seq, 1).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    trainer = Trainer(
        loss_fn=lambda p, b: loss_fn(p, b, cfg),
        params=init_params(jax.random.PRNGKey(0), cfg),
        opt_cfg=OptimizerConfig(lr=3e-3, warmup_steps=20,
                                total_steps=args.steps),
        get_batch=get_batch,
        ckpt_dir=args.ckpt, ckpt_every=50, microbatches=2)
    resumed = trainer.maybe_resume()
    if resumed:
        print(f"resumed from checkpoint at step {resumed}")
    hist = trainer.run(args.steps, log_every=20, resume="none")
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.3f}  "
              f"lr {h['lr']:.2e}  |g| {h['grad_norm']:.2f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must decrease"
    print("final loss", round(hist[-1]["loss"], 3),
          "(checkpoints in", args.ckpt + ")")


if __name__ == "__main__":
    main()
