"""End-to-end driver: batched graph-pattern query serving.

The paper's workload as a service: a resident graph, clients submitting
pattern queries with per-request samples, the engine router picking the
Table-6/7 winner per query shape.

    PYTHONPATH=src python examples/serve_queries.py
"""
import time

import numpy as np

from repro.graphs import powerlaw_cluster
from repro.serve import QueryRequest, QueryServer

g = powerlaw_cluster(n=5000, m_per_node=6, seed=0)
server = QueryServer(g)
print(f"serving graph: {g.n_nodes} nodes, {g.n_edges // 2} edges\n")

requests = []
rng = np.random.default_rng(0)
for i in range(24):
    qname = rng.choice(["3-clique", "4-cycle", "3-path", "2-comb",
                        "1-tree", "2-lollipop"])
    requests.append(QueryRequest(str(qname),
                                 selectivity=float(rng.choice([8, 80])),
                                 seed=int(rng.integers(3))))

t0 = time.time()
results = server.execute_many(requests)   # plan-grouped batched execution
wall = time.time() - t0

by_engine: dict = {}
for r in results:
    by_engine.setdefault(r.engine, []).append(r.latency_s)
    print(f"  {r.request.query_name:11s} sel={r.request.selectivity:4.0f} "
          f"-> {r.count:>12,}  [{r.engine:10s} {r.latency_s*1e3:7.1f} ms]")

print(f"\n{len(results)} requests in {wall:.2f}s "
      f"({len(results)/wall:.1f} qps)  plan cache: "
      f"{server.plan_cache_info()}")
for eng, lats in sorted(by_engine.items()):
    lats = sorted(lats)
    p50 = lats[len(lats) // 2] * 1e3
    print(f"  {eng:10s}: n={len(lats)} p50={p50:.1f}ms "
          f"max={max(lats)*1e3:.1f}ms")
