"""End-to-end driver: batched + preemptive graph-pattern query serving.

The paper's workload as a service: a resident graph, clients submitting
pattern queries with per-request samples, the engine router picking the
Table-6/7 winner per query shape.  Part 2 shows the preemptive
scheduler: the same mixed light/heavy load under FIFO vs quantum
round-robin, with per-tenant admission control (the transcript in
docs/SERVING.md comes from this script).

    PYTHONPATH=src python examples/serve_queries.py
"""
import time

import numpy as np

from repro.graphs import powerlaw_cluster
from repro.serve import (AdmissionError, QuantumScheduler, QueryRequest,
                         QueryServer, TenantQuota)

g = powerlaw_cluster(n=5000, m_per_node=6, seed=0)
server = QueryServer(g)
print(f"serving graph: {g.n_nodes} nodes, {g.n_edges // 2} edges\n")

requests = []
rng = np.random.default_rng(0)
for i in range(24):
    qname = rng.choice(["3-clique", "4-cycle", "3-path", "2-comb",
                        "1-tree", "2-lollipop"])
    requests.append(QueryRequest(str(qname),
                                 selectivity=float(rng.choice([8, 80])),
                                 seed=int(rng.integers(3))))

t0 = time.time()
results = server.execute_many(requests)   # plan-grouped batched execution
wall = time.time() - t0

by_engine: dict = {}
for r in results:
    by_engine.setdefault(r.engine, []).append(r.latency_s)
    print(f"  {r.request.query_name:11s} sel={r.request.selectivity:4.0f} "
          f"-> {r.count:>12,}  [{r.engine:10s} {r.latency_s*1e3:7.1f} ms]")

print(f"\n{len(results)} requests in {wall:.2f}s "
      f"({len(results)/wall:.1f} qps)  plan cache: "
      f"{server.plan_cache_info()}")
for eng, lats in sorted(by_engine.items()):
    lats = sorted(lats)
    p50 = lats[len(lats) // 2] * 1e3
    print(f"  {eng:10s}: n={len(lats)} p50={p50:.1f}ms "
          f"max={max(lats)*1e3:.1f}ms")

# -- part 2: preemptive scheduling under mixed light/heavy load -------------
# One heavy full-graph 3-path enumeration racing six small counts.  FIFO
# (run-to-completion, the batch behaviour above) starves the smalls;
# the quantum policy round-robins slices of `quantum_rows` expanded
# rows, so every small finishes within a few quanta of submission.
print("\n--- preemptive scheduling: 1 heavy enumeration vs 6 smalls ---")


def mixed_load(policy: str):
    sched = QuantumScheduler(server, quantum_rows=8192, policy=policy)
    sched.submit(QueryRequest("3-path", engine="vlftj", limit=10**9,
                              selectivity=2.0), collect_rows=False)
    for i in range(6):
        sched.submit(QueryRequest("3-clique", engine="vlftj", seed=i % 3))
    return sched.run()


for policy in ("fifo", "quantum"):
    results = mixed_load(policy)
    heavy, smalls = results[0], results[1:]
    done = [r.stats["vclock_done"] - r.stats["vclock_submit"]
            for r in smalls]
    print(f"  {policy:7s}: heavy rows_expanded="
          f"{heavy.stats['rows_expanded']:,} "
          f"quanta={heavy.stats['quanta']} | small completion "
          f"(rows-expanded clock) p50={sorted(done)[len(done)//2]:,} "
          f"max={max(done):,}")

# -- part 3: per-tenant quotas (429-style admission control) ----------------
print("\n--- admission control: tenant 'b' capped at 2 in flight ---")
sched = QuantumScheduler(server, quantum_rows=8192,
                         quotas={"b": TenantQuota(max_in_flight=2)})
for i in range(4):
    try:
        tok = sched.submit(QueryRequest("3-clique", tenant="b", seed=i))
        print(f"  submit #{i}: admitted as {tok}")
    except AdmissionError as e:
        print(f"  submit #{i}: HTTP {e.status} — {e}")
sched.run()
