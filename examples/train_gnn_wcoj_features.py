"""The paper's technique feeding the model zoo: WCOJ structural features.

Per-node triangle counts — computed by the vectorized LFTJ engine — are
appended to node features before training a GatedGCN.  This is the
integration point described in DESIGN.md §4: the join engine and the GNNs
share the same CSR trie.

    PYTHONPATH=src python examples/train_gnn_wcoj_features.py
"""
import jax
import numpy as np

from repro.core import GraphDB, VLFTJ, get_query
from repro.graphs import powerlaw_cluster
from repro.models.gnn.data import GraphBatch
from repro.models.gnn.gatedgcn import (GatedGCNConfig, gatedgcn_loss,
                                       init_gatedgcn)
from repro.train.loop import Trainer
from repro.train.optimizer import OptimizerConfig

g = powerlaw_cluster(n=800, m_per_node=4, seed=0)
gdb = GraphDB(g, {})

# 1) enumerate triangles with the worst-case-optimal join, scatter counts
eng = VLFTJ(get_query("3-clique"), gdb)
tris = eng.enumerate()                      # (T, 3) node triples, a<b<c
tri_count = np.zeros(g.n_nodes, np.float32)
np.add.at(tri_count, tris.ravel(), 1.0)
print(f"{tris.shape[0]} triangles; max per node {int(tri_count.max())}")

# 2) labels correlated with triangle membership (structure detection task)
rng = np.random.default_rng(0)
labels = (tri_count > np.median(tri_count)).astype(np.int32)
base_feat = rng.standard_normal((g.n_nodes, 8)).astype(np.float32)


def make_batch(with_wcoj: bool) -> GraphBatch:
    feats = [base_feat]
    if with_wcoj:
        feats.append(np.log1p(tri_count)[:, None])
    feat = np.concatenate(feats, 1)
    ea = g.edge_array()
    return GraphBatch(src=ea[:, 0], dst=ea[:, 1], n_nodes=g.n_nodes,
                      node_feat=feat, labels=labels)


def train(with_wcoj: bool, steps: int = 60) -> float:
    batch = make_batch(with_wcoj)
    cfg = GatedGCNConfig(n_layers=3, d_hidden=32,
                         d_in=batch.node_feat.shape[1], n_classes=2)
    tr = Trainer(
        loss_fn=lambda p, b: gatedgcn_loss(p, batch, cfg),
        params=init_gatedgcn(jax.random.PRNGKey(0), cfg),
        opt_cfg=OptimizerConfig(lr=3e-3, warmup_steps=10,
                                total_steps=steps),
        get_batch=lambda s: {"_": np.zeros(1)})
    hist = tr.run(steps, log_every=steps)
    return hist[-1]["loss"]


plain = train(with_wcoj=False)
wcoj = train(with_wcoj=True)
print(f"final loss without WCOJ features: {plain:.4f}")
print(f"final loss with    WCOJ features: {wcoj:.4f}")
assert wcoj < plain, "structural features should help this task"
print("WCOJ structural features improve the GNN ✓")
