"""Quickstart: worst-case-optimal graph-pattern counting in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import GraphDB, agm_bound, count, get_query, pick_engine
from repro.graphs import node_sample, powerlaw_cluster

# 1) a graph (SNAP-style power-law synthetic; use graphs.load_edgelist
#    for a real SNAP file) + two node samples at selectivity 10
g = powerlaw_cluster(n=2000, m_per_node=5, seed=0)
gdb = GraphDB(g, {
    "v1": node_sample(g.n_nodes, 10, seed=1),
    "v2": node_sample(g.n_nodes, 10, seed=2),
})
print(f"graph: {g.n_nodes} nodes, {g.n_edges // 2} edges")

# 2) count patterns with the engine of your choice (auto = Table 6/7
#    winners: LFTJ for cyclic, the Minesweeper analogue for acyclic)
for qname in ["3-clique", "4-clique", "3-path", "2-comb"]:
    q = get_query(qname)
    c = count(q, gdb, engine="auto")
    bound = agm_bound(q, gdb.to_database().sizes())
    print(f"{qname:9s} -> {c:>12,} matches "
          f"(engine={pick_engine(q):10s} AGM bound={bound:.3g})")

# 3) the same counts from the Selinger-style pairwise baseline — watch
#    the intermediate blow up on the cyclic patterns
from repro.core import BinaryJoin
bj = BinaryJoin(get_query("3-clique"), gdb.to_database())
print("pairwise 3-clique:", bj.count(),
      f"(max intermediate {bj.stats['max_intermediate']:,} rows — "
      "the asymptotic gap the paper closes)")
