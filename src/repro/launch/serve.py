"""Serving launcher — the paper's workload as a long-running service.

    PYTHONPATH=src python -m repro.launch.serve --nodes 20000 --requests 50

Loads (or generates) a graph, starts the QueryServer, and drives a mixed
batch of pattern queries, printing per-engine latency percentiles — the
operational analogue of Tables 6/7.  ``--edgelist`` serves a real SNAP
file.
"""
from __future__ import annotations

import argparse

import numpy as np

import repro  # noqa: F401
from repro.graphs import load_edgelist, powerlaw_cluster
from repro.serve import QueryRequest, QueryServer

MIX = ["3-clique", "4-cycle", "3-path", "4-path", "1-tree", "2-comb",
       "2-lollipop"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edgelist", default=None)
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--m-per-node", type=int, default=6)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--selectivity", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.edgelist:
        g = load_edgelist(args.edgelist)
    else:
        g = powerlaw_cluster(args.nodes, args.m_per_node, seed=args.seed)
    print(f"graph: {g.n_nodes:,} nodes / {g.n_edges // 2:,} edges")
    server = QueryServer(g, default_selectivity=args.selectivity)

    rng = np.random.default_rng(args.seed)
    reqs = [QueryRequest(str(rng.choice(MIX)),
                         selectivity=float(rng.choice([8, 80])),
                         seed=int(rng.integers(3)))
            for _ in range(args.requests)]
    results = server.execute_batch(reqs)

    by_engine: dict[str, list[float]] = {}
    for r in results:
        by_engine.setdefault(r.engine, []).append(r.latency_s)
    total = sum(sum(v) for v in by_engine.values())
    print(f"\n{len(results)} requests, {total:.2f}s engine time")
    for eng, lats in sorted(by_engine.items()):
        lats.sort()
        p50 = lats[len(lats) // 2] * 1e3
        p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e3
        print(f"  {eng:12s} n={len(lats):3d} p50={p50:8.1f}ms "
              f"p99={p99:8.1f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
