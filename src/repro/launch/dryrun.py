import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import: jax locks the
# device count at first initialization.  This module is the ONLY place
# that forces 512 placeholder devices (the dry-run contract).

import argparse            # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402

import repro               # noqa: E402  (enables x64)
from repro.configs import ARCHS                    # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import roofline_terms    # noqa: E402


def _compile_cell(cell, mesh):
    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=getattr(cell, "donate", ()))
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    from repro.launch.roofline import collective_bytes
    return {
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                  None),
        },
        "cost": {k: v for k, v in (cost or {}).items()
                 if isinstance(v, (int, float))},
        "coll": collective_bytes(hlo),
    }


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str) -> dict:
    arch = ARCHS[arch_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "chips": chips}
    fname = os.path.join(out_dir, f"{arch_id}__{shape_name}__"
                                  f"{mesh_name}.json")
    cell = arch.cell(shape_name, mesh)
    if cell.skip:
        rec["status"] = "skipped"
        rec["reason"] = cell.skip
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    full = _compile_cell(cell, mesh)
    rec.update({"status": "ok", "kind": cell.kind, "note": cell.note,
                **{k: full[k] for k in
                   ("lower_s", "compile_s", "memory")},
                "cost_raw": full["cost"], "coll_raw": full["coll"]})
    cost, coll = full["cost"], full["coll"]
    if cell.probe_builder is not None and cell.n_scan >= 2:
        # scan bodies are costed once by XLA: extrapolate from L=1,2
        p1 = _compile_cell(cell.probe_builder(1), mesh)
        p2 = _compile_cell(cell.probe_builder(2), mesh)
        L = cell.n_scan
        # clamp at the L=1 cost: a one-off op in the L=1 program can make
        # the per-layer marginal negative for a category, which must not
        # extrapolate below zero
        cost = {k: max(0.0, p1["cost"].get(k, 0.0)
                       + (L - 1) * (p2["cost"].get(k, 0.0)
                                    - p1["cost"].get(k, 0.0)))
                for k in set(p1["cost"]) | set(p2["cost"])}
        coll = {k: max(0, p1["coll"].get(k, 0)
                       + (L - 1) * (p2["coll"].get(k, 0)
                                    - p1["coll"].get(k, 0)))
                for k in set(p1["coll"]) | set(p2["coll"])}
        rec["cost_probe"] = {"L1": p1["cost"], "L2": p2["cost"],
                             "n_scan": L}
    rec["cost"] = cost
    rec["coll"] = coll
    rl = roofline_terms(cost, "", chips, model_flops=cell.model_flops,
                        coll_override=coll)
    rec["roofline"] = rl.to_dict()
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    for arch_id in archs:
        arch = ARCHS[arch_id]
        shapes = (list(arch.shapes) if args.shape == "all"
                  else [s for s in args.shape.split(",")
                        if s in arch.shapes])
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                fname = os.path.join(
                    args.out,
                    f"{arch_id}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip existing] {fname}")
                    continue
                tag = f"{arch_id} x {shape_name} x {mesh_name}"
                try:
                    rec = run_cell(arch_id, shape_name, multi, args.out)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch_id, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    with open(fname, "w") as f:
                        json.dump(rec, f, indent=1)
                results.append(rec)
                if rec["status"] == "ok":
                    rl = rec["roofline"]
                    print(f"[ok] {tag}: compile {rec['compile_s']}s "
                          f"flops/chip {rl['flops_per_chip']:.3g} "
                          f"bottleneck {rl['bottleneck']} "
                          f"(c={rl['t_compute']:.2e}s m={rl['t_memory']:.2e}s "
                          f"x={rl['t_collective']:.2e}s) "
                          f"useful={rl['useful_ratio']:.2f}")
                elif rec["status"] == "skipped":
                    print(f"[skipped] {tag}: {rec['reason']}")
                else:
                    print(f"[ERROR] {tag}: {rec['error']}")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
