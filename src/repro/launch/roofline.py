"""Roofline-term extraction from compiled dry-run artifacts.

Terms (seconds), per (arch × shape × mesh):
    compute    = per-chip HLO FLOPs / 197 TF/s (bf16 peak, v5e)
    memory     = per-chip HLO bytes accessed / 819 GB/s HBM
    collective = Σ collective-op operand bytes / (chips × 50 GB/s link)

FLOPs/bytes come from ``compiled.cost_analysis()`` (the partitioned,
per-device module).  Collective bytes are not in cost_analysis: the
post-optimization HLO text is scanned and operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
are summed.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind summed operand bytes from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        rhs = stripped.split(" = ", 1)[1]
        kind = None
        for k in _COLLECTIVES:
            # op name appears right after the result shape
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # paired with -start; avoid double count
        # operand shapes are inside the call parens
        call = rhs.split("(", 1)[1]
        shapes = _SHAPE_RE.findall(call)
        if not shapes:
            # operands referenced by name only: fall back to result shape
            shapes = _SHAPE_RE.findall(rhs.split(" ", 1)[0])
        total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += total
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts}


@dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_total: float
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    coll_detail: dict

    def to_dict(self):
        return asdict(self)


def roofline_terms(cost: dict, hlo_text: str, chips: int,
                   model_flops: float = 0.0,
                   coll_override: dict | None = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = (coll_override if coll_override is not None
            else collective_bytes(hlo_text))
    cb = float(sum(v for k, v in coll.items() if not k.startswith("n_")))
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = cb / (chips * LINK_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops * chips
    useful = model_flops / total_flops if total_flops else 0.0
    return Roofline(flops, byts, cb, chips, t_c, t_m, t_x, bottleneck,
                    model_flops, useful, coll)
