"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-3b-a800m \
        --steps 100 --reduced --ckpt /tmp/ckpt --resume auto

Selects the architecture from the registry, builds the best mesh for the
available devices (elastic: a restarted job with fewer chips resumes from
the same logical checkpoint), wires the deterministic data pipeline, and
runs the fault-tolerant training loop.  ``--reduced`` runs the smoke-scale
config (CPU-friendly); full-scale runs are what the dry-run compiles for
the production meshes.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro  # noqa: F401
from repro.configs import ARCHS
from repro.data import lm_synthetic_batch, recsys_synthetic_batch
from repro.dist.elastic import best_mesh
from repro.models import transformer as tfm
from repro.models import xdeepfm as xdf
from repro.models.gnn import data as gnn_data
from repro.train.loop import Trainer
from repro.train.optimizer import OptimizerConfig


def build_trainer(arch_id: str, args) -> Trainer:
    arch = ARCHS[arch_id]
    key = jax.random.PRNGKey(args.seed)
    opt = OptimizerConfig(lr=args.lr, warmup_steps=min(100, args.steps),
                          total_steps=args.steps)
    if arch.family == "lm":
        cfg = arch.reduced_cfg() if args.reduced else arch.cfg
        params = tfm.init_params(key, cfg)
        batch, seq = (8, 64) if args.reduced else (256, 4096)
        return Trainer(
            loss_fn=lambda p, b: tfm.loss_fn(p, b, cfg),
            params=params, opt_cfg=opt,
            get_batch=lambda s: lm_synthetic_batch(
                s, batch, seq, cfg.vocab_size, seed=args.seed),
            ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
            microbatches=args.microbatches)
    if arch.family == "gnn":
        g = gnn_data.random_graph_batch(
            256 if args.reduced else 100_000,
            1024 if args.reduced else 1_600_000,
            16, seed=args.seed, coords=True, n_graphs=4)
        cfg = arch.make_cfg(16, 16)
        params = arch.init_fn(key, cfg)
        return Trainer(
            loss_fn=lambda p, b: arch.loss_fn(p, g, cfg),
            params=params, opt_cfg=opt,
            get_batch=lambda s: {"step": np.zeros(1)},
            ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every)
    if arch.family == "recsys":
        cfg = arch.reduced_cfg() if args.reduced else arch.cfg
        params = xdf.init_xdeepfm(key, cfg)
        batch = 256 if args.reduced else 65536
        return Trainer(
            loss_fn=lambda p, b: xdf.xdeepfm_loss(p, b, cfg),
            params=params, opt_cfg=opt,
            get_batch=lambda s: recsys_synthetic_batch(
                s, batch, cfg.n_sparse, cfg.vocab_per_field,
                seed=args.seed),
            ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every)
    raise SystemExit(f"--arch {arch_id}: family {arch.family} is not a "
                     "trainable architecture (use launch.serve for wcoj)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mesh = best_mesh()
    print(f"devices={len(jax.devices())} mesh={dict(mesh.shape)}")
    trainer = build_trainer(args.arch, args)
    hist = trainer.run(args.steps, log_every=args.log_every,
                       resume=args.resume)
    for h in hist[-5:]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"lr {h['lr']:.2e} |g| {h['grad_norm']:.2f} "
              f"{h['wall']:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
