"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization; everything else sees the real device count).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Best-effort mesh from the actually available devices (elastic path:
    tests run with 8 host devices; the container default is 1)."""
    n = len(jax.devices())
    model = min(model, n)
    data = data if data is not None else n // model
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes over which the batch is sharded (pod composes with data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
