"""xDeepFM [arXiv:1803.05170]: sparse embeddings + CIN + deep MLP.

The hot path is the embedding lookup over 39 categorical fields with
large per-field vocabularies.  JAX has no EmbeddingBag: lookups are
``jnp.take`` gathers over a row-sharded table + ``segment_sum`` for
multi-hot bags — built here as a first-class layer (see DESIGN.md).

CIN (Compressed Interaction Network): x^k_{h} = Σ_{i,j} W^{k,h}_{ij}
(x^{k-1}_i ∘ x^0_j), implemented as an outer product over field dims and a
1×1 "conv" (einsum) compression; three layers of 200 feature maps.

``retrieval_cand`` scoring: one user embedding vs 10^6 candidate item
embeddings = a single batched matmul, not a loop.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..layers.common import normal_init


@dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 100_000   # rows per field table
    cin_layers: tuple = (200, 200, 200)
    mlp_dims: tuple = (400, 400)
    n_dense: int = 0

    @property
    def total_vocab(self) -> int:
        return self.n_sparse * self.vocab_per_field


def init_xdeepfm(key, cfg: XDeepFMConfig):
    ks = iter(jax.random.split(key, 8 + len(cfg.cin_layers)
                               + len(cfg.mlp_dims)))
    f, d = cfg.n_sparse, cfg.embed_dim
    p = {
        # one logical table, fields offset into it (row-shardable)
        "embed": normal_init(next(ks), (cfg.total_vocab, d), stddev=0.01),
        "linear": normal_init(next(ks), (cfg.total_vocab, 1), stddev=0.01),
        "cin": [],
        "mlp": [],
    }
    prev = f
    for h in cfg.cin_layers:
        p["cin"].append(normal_init(next(ks), (prev * f, h)))
        prev = h
    dims = (f * d,) + tuple(cfg.mlp_dims)
    for i in range(len(cfg.mlp_dims)):
        p["mlp"].append({
            "w": normal_init(next(ks), (dims[i], dims[i + 1])),
            "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    p["out_mlp"] = normal_init(next(ks), (cfg.mlp_dims[-1], 1))
    p["out_cin"] = normal_init(next(ks), (sum(cfg.cin_layers), 1))
    return p


def embedding_bag(table: jax.Array, ids: jax.Array,
                  offsets: jax.Array | None = None) -> jax.Array:
    """EmbeddingBag: gather + (optional) segment-sum reduction.

    ids: (B, F) one-hot-per-field case -> plain gather (B, F, d);
    with ``offsets`` (B, F) counts for multi-hot bags over flat ids.
    """
    if offsets is None:
        return table[ids]
    # multi-hot: ids (T,) flat, offsets = bag boundaries (B*F+1,)
    emb = table[ids]                                   # (T, d)
    bag_id = jnp.cumsum(
        jnp.zeros(ids.shape[0], jnp.int32).at[offsets[1:-1]].add(1))
    n_bags = offsets.shape[0] - 1
    return jax.ops.segment_sum(emb, bag_id, num_segments=n_bags)


def _field_ids(ids: jax.Array, cfg: XDeepFMConfig) -> jax.Array:
    off = (jnp.arange(cfg.n_sparse, dtype=ids.dtype)
           * cfg.vocab_per_field)[None, :]
    return ids + off


def xdeepfm_forward(params, ids: jax.Array, cfg: XDeepFMConfig):
    """ids: (B, n_sparse) per-field categorical indices -> logits (B,)."""
    flat = _field_ids(ids, cfg)
    e = embedding_bag(params["embed"], flat)           # (B, F, d)
    lin = params["linear"][flat][..., 0].sum(axis=1)   # (B,)

    # CIN
    x0 = e                                             # (B, F, d)
    xk = e
    cin_outs = []
    for w in params["cin"]:
        inter = jnp.einsum("bhd,bmd->bhmd", xk, x0)    # (B, Hk, F, d)
        b, hk, f, d = inter.shape
        inter = inter.reshape(b, hk * f, d)
        xk = jnp.einsum("bpd,ph->bhd", inter, w)       # (B, H, d)
        cin_outs.append(xk.sum(axis=-1))               # (B, H)
    cin_vec = jnp.concatenate(cin_outs, axis=-1)

    # deep MLP
    h = e.reshape(e.shape[0], -1)
    for l in params["mlp"]:
        h = jax.nn.relu(h @ l["w"] + l["b"])

    logit = (lin + (h @ params["out_mlp"])[:, 0]
             + (cin_vec @ params["out_cin"])[:, 0])
    return logit


def xdeepfm_loss(params, batch, cfg: XDeepFMConfig):
    logit = xdeepfm_forward(params, batch["ids"], cfg)
    y = batch["labels"].astype(jnp.float32)
    # numerically stable BCE-with-logits
    loss = jnp.maximum(logit, 0) - logit * y + jnp.log1p(
        jnp.exp(-jnp.abs(logit)))
    return loss.mean()


def retrieval_scores(params, query_ids: jax.Array,
                     candidate_ids: jax.Array, cfg: XDeepFMConfig):
    """Score 1 query against N candidates with one batched dot.

    query_ids: (1, n_sparse); candidate_ids: (N,) item-field indices
    (scored against field 0's table region by convention).
    """
    flat = _field_ids(query_ids, cfg)
    q = embedding_bag(params["embed"], flat)          # (1, F, d)
    qv = q.mean(axis=1)[0]                            # (d,)
    cand = params["embed"][candidate_ids]             # (N, d)
    return cand @ qv                                  # (N,)
