"""Decoder-only transformer family (dense + MoE), TPU-pod-shardable.

Design points:
  * params stacked ``(L, ...)`` + ``lax.scan`` over layers — compact HLO,
    bounded compile time at 512 devices, remat per layer;
  * GQA attention with RoPE (full or ChatGLM-style half-dim rotary); KV
    heads replicate over excess model shards;
  * Megatron-style TP via sharding constraints; optional FSDP (params
    sharded over data on a non-layer dim) and sequence-parallel residual
    stream for the 100B-class configs;
  * MoE blocks via ``layers.moe`` (shard_map EP/TP) with a local fallback
    when no mesh is present (CPU smoke tests);
  * decode with a KV cache sharded over (data, heads-or-seq); prefill
    returns the populated cache.

Everything is explicit-dtype (bf16 activations / f32 router & softmax).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from ..layers.common import (act_fn, apply_rope, cross_entropy_from_logits,
                             make_norm, normal_init)
from ..layers.moe import (MoEConfig, _dispatch_compute, init_moe_params,
                          moe_ffn, moe_param_specs)


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None
    rope_frac: float = 1.0
    rope_theta: float = 10_000.0
    act: str = "silu"
    norm: str = "rmsnorm"
    use_bias: bool = False
    tie_embeddings: bool = True
    moe: MoEConfig | None = None
    dtype: Any = jnp.bfloat16
    remat: bool = True
    fsdp: bool = False          # shard params over 'data' too (100B class)
    seq_shard: bool = False     # sequence-parallel residual stream
    attn_head_shard: bool = True  # explicit head-sharding wsc on q
    loss_seq_chunk: int = 0     # chunk the LM head over sequence
    max_cache_len: int = 32768

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table
        row-shards on any mesh (padded logits are masked in the loss)."""
        if self.vocab_size % 256 == 0:
            return self.vocab_size
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def n_params(self) -> int:
        d, l, v = self.d_model, self.n_layers, self.vocab_size
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * hq * dh * 2 + d * hkv * dh * 2
        if self.moe is None:
            ffn = 3 * d * self.d_ff
        else:
            ffn = (3 * d * self.moe.d_ff_expert * self.moe.n_experts
                   + d * self.moe.n_experts
                   + 3 * d * self.moe.d_ff_expert * self.moe.n_shared_experts)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn + 2 * d) + emb + d

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params
        d, l = self.d_model, self.n_layers
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * hq * dh * 2 + d * hkv * dh * 2
        ffn = (3 * d * self.moe.d_ff_expert
               * (self.moe.top_k + self.moe.n_shared_experts)
               + d * self.moe.n_experts)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn + 2 * d) + emb + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: TransformerConfig):
    l, d, v = cfg.n_layers, cfg.d_model, cfg.padded_vocab
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = iter(jax.random.split(key, 16))
    dt = cfg.dtype
    p = {
        "embed": normal_init(next(ks), (v, d), dtype=dt),
        "ln1": normal_init(next(ks), (l, d), stddev=0.0, dtype=jnp.float32)
        + 1.0,
        "wq": normal_init(next(ks), (l, d, hq * dh), dtype=dt),
        "wk": normal_init(next(ks), (l, d, hkv * dh), dtype=dt),
        "wv": normal_init(next(ks), (l, d, hkv * dh), dtype=dt),
        "wo": normal_init(next(ks), (l, hq * dh, d), dtype=dt),
        "ln2": normal_init(next(ks), (l, d), stddev=0.0, dtype=jnp.float32)
        + 1.0,
        "ln_f": normal_init(next(ks), (d,), stddev=0.0, dtype=jnp.float32)
        + 1.0,
    }
    if cfg.moe is None:
        p["w_gate"] = normal_init(next(ks), (l, d, cfg.d_ff), dtype=dt)
        p["w_up"] = normal_init(next(ks), (l, d, cfg.d_ff), dtype=dt)
        p["w_down"] = normal_init(next(ks), (l, cfg.d_ff, d), dtype=dt)
    else:
        p["moe"] = init_moe_params(next(ks), d, cfg.moe, l, dtype=dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = normal_init(next(ks), (d, v), dtype=dt)
    return p


def param_specs(cfg: TransformerConfig) -> dict:
    """PartitionSpecs (logical: 'data' = FSDP shard dim, 'model' = TP)."""
    dp = "data" if cfg.fsdp else None
    specs = {
        "embed": P("model", dp),
        "ln1": P(None, None),
        "wq": P(None, dp, "model"),
        "wk": P(None, dp, None),   # kv heads may not divide the TP axis
        "wv": P(None, dp, None),
        "wo": P(None, "model", dp),
        "ln2": P(None, None),
        "ln_f": P(None),
    }
    if cfg.moe is None:
        specs["w_gate"] = P(None, dp, "model")
        specs["w_up"] = P(None, dp, "model")
        specs["w_down"] = P(None, "model", dp)
    else:
        specs["moe"] = moe_param_specs(cfg.moe, cfg.fsdp)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(dp, "model")
    return specs


def _dataxes(mesh):
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _wsc(x, spec, mesh):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _head_axis(cfg: TransformerConfig, mesh):
    """'model' when the query-head count divides the TP axis, else None
    (granite's 24 heads on a 16-way axis fall back to flat-dim sharding)."""
    if mesh is None:
        return None
    return "model" if cfg.n_heads % mesh.shape["model"] == 0 else None


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attention(x, lp, cfg: TransformerConfig, mesh, positions):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dax = _dataxes(mesh)
    q = jnp.einsum("bsd,dh->bsh", x, lp["wq"],
                   preferred_element_type=jnp.float32).astype(cfg.dtype)
    k = jnp.einsum("bsd,dh->bsh", x, lp["wk"],
                   preferred_element_type=jnp.float32).astype(cfg.dtype)
    v = jnp.einsum("bsd,dh->bsh", x, lp["wv"],
                   preferred_element_type=jnp.float32).astype(cfg.dtype)
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_frac, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_frac, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)
    if cfg.attn_head_shard:
        q = _wsc(q, P(dax, _head_axis(cfg, mesh), None, None), mesh)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    o = kops.flash_attention(q, k, v, causal=True)            # (B,Hq,S,Dh)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    out = jnp.einsum("bsh,hd->bsd", o, lp["wo"],
                     preferred_element_type=jnp.float32).astype(cfg.dtype)
    return out, (k, v)


def _dense_ffn(x, lp, cfg: TransformerConfig, mesh):
    g = jnp.einsum("bsd,df->bsf", x, lp["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,df->bsf", x, lp["w_up"],
                   preferred_element_type=jnp.float32)
    h = (act_fn(cfg.act)(g) * u).astype(cfg.dtype)
    h = _wsc(h, P(_dataxes(mesh), None, "model"), mesh)
    out = jnp.einsum("bsf,fd->bsd", h, lp["w_down"],
                     preferred_element_type=jnp.float32).astype(cfg.dtype)
    return out


def _moe_ffn_local(x, lp, cfg: TransformerConfig):
    """Single-device MoE fallback (smoke tests, no mesh)."""
    b, s, d = x.shape
    t = b * s
    capacity = int(cfg.moe.capacity_factor * t * cfg.moe.top_k
                   / cfg.moe.n_experts) + 1
    out, aux = _dispatch_compute(
        x.reshape(t, d), lp["router"], lp["w_gate"], lp["w_up"],
        lp["w_down"], cfg=cfg.moe, e_off=0,
        n_total_experts=cfg.moe.n_experts, act=cfg.act, capacity=capacity)
    y = out.reshape(b, s, d).astype(cfg.dtype)
    if cfg.moe.n_shared_experts:
        g = act_fn(cfg.act)(jnp.einsum("bsd,df->bsf", x, lp["sh_gate"],
                            preferred_element_type=jnp.float32))
        u = jnp.einsum("bsd,df->bsf", x, lp["sh_up"],
                       preferred_element_type=jnp.float32)
        sh = jnp.einsum("bsf,fd->bsd", (g * u).astype(x.dtype),
                        lp["sh_down"], preferred_element_type=jnp.float32)
        y = y + sh.astype(y.dtype)
    return y, aux


def _layer(x, lp, cfg: TransformerConfig, mesh, positions):
    dax = _dataxes(mesh)
    norm = make_norm(cfg.norm)
    res_spec = (P(dax, "model", None) if cfg.seq_shard
                else P(dax, None, None))
    x = _wsc(x, res_spec, mesh)
    h = norm(x, {"scale": lp["ln1"]})
    h = _wsc(h, P(dax, None, None), mesh)
    attn_out, _ = _attention(h, lp, cfg, mesh, positions)
    x = x + _wsc(attn_out, res_spec, mesh)
    h = norm(x, {"scale": lp["ln2"]})
    h = _wsc(h, P(dax, None, None), mesh)
    if cfg.moe is None:
        ff = _dense_ffn(h, lp, cfg, mesh)
        aux = jnp.zeros((), jnp.float32)
    elif mesh is None:
        ff, aux = _moe_ffn_local(h, lp["moe"], cfg)
    else:
        ff, aux = moe_ffn(h, lp["moe"], cfg.moe, mesh, act=cfg.act,
                          dtype=cfg.dtype)
    x = x + _wsc(ff, res_spec, mesh)
    return x, aux


def _layer_params(p, cfg: TransformerConfig):
    keys = ["ln1", "wq", "wk", "wv", "wo", "ln2"]
    if cfg.moe is None:
        keys += ["w_gate", "w_up", "w_down"]
        return {k: p[k] for k in keys}
    lp = {k: p[k] for k in keys}
    lp["moe"] = p["moe"]
    return lp


def forward(params, tokens, cfg: TransformerConfig, mesh=None):
    """Token ids (B, S) -> final hidden states (B, S, d) + mean aux loss."""
    b, s = tokens.shape
    dax = _dataxes(mesh)
    x = params["embed"][tokens].astype(cfg.dtype)
    x = _wsc(x, P(dax, None, None), mesh)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    layer_stack = _layer_params(params, cfg)

    def body(x, lp):
        fn = partial(_layer, cfg=cfg, mesh=mesh, positions=positions)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, aux = fn(x, lp)
        return x, aux

    # n_layers <= 2 unrolls: exact per-layer costs for the dry-run probes
    # (XLA cost analysis counts a scan body once); big stacks scan.
    if cfg.n_layers > 2:
        x, auxs = jax.lax.scan(body, x, layer_stack)
        aux = auxs.mean()
    else:
        auxs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], layer_stack)
            x, a = body(x, lp)
            auxs.append(a)
        aux = jnp.stack(auxs).mean()
    x = make_norm(cfg.norm)(x, {"scale": params["ln_f"]})
    return x, aux


def _lm_logits(x, params, cfg: TransformerConfig, mesh):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        logits = jnp.where(iota < cfg.vocab_size, logits, -1e30)
    return _wsc(logits, P(_dataxes(mesh), None, "model"), mesh)


def loss_fn(params, batch, cfg: TransformerConfig, mesh=None):
    tokens, labels = batch["tokens"], batch["labels"]
    x, aux = forward(params, tokens, cfg, mesh)
    s = x.shape[1]
    chunk = cfg.loss_seq_chunk or s
    n_chunks = max(1, s // chunk)
    if n_chunks > 1:
        xc = x.reshape(x.shape[0], n_chunks, chunk, x.shape[2])
        lc = labels.reshape(labels.shape[0], n_chunks, chunk)

        def per_chunk(c):
            xi, li = c
            logits = _lm_logits(xi, params, cfg, mesh)
            return cross_entropy_from_logits(logits, li, cfg.vocab_size)

        ce = jax.lax.map(per_chunk, (xc.transpose(1, 0, 2, 3),
                                     lc.transpose(1, 0, 2)))
        ce = ce.transpose(1, 0, 2).reshape(labels.shape)
    else:
        logits = _lm_logits(x, params, cfg, mesh)
        ce = cross_entropy_from_logits(logits, labels, cfg.vocab_size)
    loss = ce.mean() + 0.01 * aux
    return loss.astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def cache_specs(cfg: TransformerConfig, mesh) -> dict:
    """KV cache: batch over (pod,data); heads over model when divisible,
    else the sequence dim (flash-decoding split-K sharding)."""
    dax = _dataxes(mesh)
    if mesh is not None and cfg.n_kv_heads % mesh.shape["model"] == 0:
        kv = P(None, dax, "model", None, None)
    else:
        kv = P(None, dax, None, "model", None)
    return {"k": kv, "v": kv, "len": P()}


def prefill(params, tokens, cfg: TransformerConfig, mesh=None,
            max_len: int | None = None):
    """Run the prompt, return (cache, last-position logits)."""
    b, s = tokens.shape
    ml = max_len or cfg.max_cache_len
    dax = _dataxes(mesh)
    x = params["embed"][tokens].astype(cfg.dtype)
    x = _wsc(x, P(dax, None, None), mesh)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    layer_stack = _layer_params(params, cfg)

    def body(x, lp):
        fn = partial(_layer_with_kv, cfg=cfg, mesh=mesh, positions=positions,
                     max_len=ml)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, kv = fn(x, lp)
        return x, kv

    if cfg.n_layers > 2:
        x, kvs = jax.lax.scan(body, x, layer_stack)
    else:
        ks_, vs_ = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], layer_stack)
            x, (k_, v_) = body(x, lp)
            ks_.append(k_)
            vs_.append(v_)
        kvs = (jnp.stack(ks_), jnp.stack(vs_))
    x = make_norm(cfg.norm)(x, {"scale": params["ln_f"]})
    logits = _lm_logits(x[:, -1:, :], params, cfg, mesh)
    cache = {"k": kvs[0], "v": kvs[1],
             "len": jnp.array(s, jnp.int32)}
    return cache, logits


def _layer_with_kv(x, lp, cfg, mesh, positions, max_len):
    dax = _dataxes(mesh)
    norm = make_norm(cfg.norm)
    h = norm(x, {"scale": lp["ln1"]})
    attn_out, (k, v) = _attention(h, lp, cfg, mesh, positions)
    x = x + attn_out
    h = norm(x, {"scale": lp["ln2"]})
    if cfg.moe is None:
        ff = _dense_ffn(h, lp, cfg, mesh)
    elif mesh is None:
        ff, _ = _moe_ffn_local(h, lp["moe"], cfg)
    else:
        ff, _ = moe_ffn(h, lp["moe"], cfg.moe, mesh, act=cfg.act,
                        dtype=cfg.dtype)
    x = x + ff
    s = k.shape[2]
    pad = [(0, 0), (0, 0), (0, max_len - s), (0, 0)]
    return x, (jnp.pad(k, pad), jnp.pad(v, pad))


def decode_step(params, cache, tokens, cfg: TransformerConfig, mesh=None):
    """One token for every sequence: tokens (B, 1) -> (logits, new cache)."""
    b = tokens.shape[0]
    dax = _dataxes(mesh)
    x = params["embed"][tokens].astype(cfg.dtype)
    pos = jnp.broadcast_to(cache["len"][None], (b, 1)).astype(jnp.int32)
    layer_stack = _layer_params(params, cfg)
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def body(carry, xs):
        x = carry
        lp, kc, vc = xs
        norm = make_norm(cfg.norm)
        h = norm(x, {"scale": lp["ln1"]})
        q = jnp.einsum("bsd,dh->bsh", h, lp["wq"],
                       preferred_element_type=jnp.float32).astype(cfg.dtype)
        k = jnp.einsum("bsd,dh->bsh", h, lp["wk"],
                       preferred_element_type=jnp.float32).astype(cfg.dtype)
        v = jnp.einsum("bsd,dh->bsh", h, lp["wv"],
                       preferred_element_type=jnp.float32).astype(cfg.dtype)
        q = apply_rope(q.reshape(b, 1, hq, dh), pos, cfg.rope_frac,
                       cfg.rope_theta)
        k = apply_rope(k.reshape(b, 1, hkv, dh), pos, cfg.rope_frac,
                       cfg.rope_theta)
        v = v.reshape(b, 1, hkv, dh)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k.transpose(0, 2, 1, 3), cache["len"], axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v.transpose(0, 2, 1, 3), cache["len"], axis=2)
        o = _cached_attention(q.transpose(0, 2, 1, 3), kc, vc,
                              cache["len"] + 1, cfg)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, hq * dh)
        attn_out = jnp.einsum("bsh,hd->bsd", o, lp["wo"],
                              preferred_element_type=jnp.float32
                              ).astype(cfg.dtype)
        x = x + attn_out
        h = norm(x, {"scale": lp["ln2"]})
        if cfg.moe is None:
            ff = _dense_ffn(h, lp, cfg, mesh)
        elif mesh is None:
            ff, _ = _moe_ffn_local(h, lp["moe"], cfg)
        else:
            ff, _ = moe_ffn(h, lp["moe"], cfg.moe, mesh, act=cfg.act,
                            dtype=cfg.dtype)
        x = x + ff
        return x, (kc, vc)

    if cfg.n_layers > 2:
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (layer_stack, cache["k"], cache["v"]))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(lambda a: a[i],
                                (layer_stack, cache["k"], cache["v"]))
            x, (kc, vc) = body(x, xs_i)
            ks_l.append(kc)
            vs_l.append(vc)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)
    x = make_norm(cfg.norm)(x, {"scale": params["ln_f"]})
    logits = _lm_logits(x, params, cfg, mesh)
    new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
    return logits, new_cache


def _cached_attention(q, kc, vc, valid_len, cfg: TransformerConfig):
    """q: (B, Hq, 1, Dh) vs cache (B, Hkv, M, Dh) masked to valid_len."""
    b, hq, _, dh = q.shape
    hkv = kc.shape[1]
    group = hq // hkv
    m = kc.shape[2]
    qg = q.reshape(b, hkv, group, dh).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhmd->bhgm", qg, kc.astype(jnp.float32))
    logits = logits / (dh ** 0.5)
    mask = jnp.arange(m) < valid_len
    logits = jnp.where(mask[None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgm,bhmd->bhgd", p, vc.astype(jnp.float32))
    return o.reshape(b, hq, 1, dh).astype(cfg.dtype)
