"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

Four aggregators (mean, max, min, std) × three degree scalers (identity,
amplification log(d+1)/δ, attenuation δ/log(d+1)) -> 12·d concat ->
linear tower per layer, residual.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ...layers.common import layernorm, normal_init
from .data import (GraphBatch, scatter_max, scatter_mean, scatter_min,
                   scatter_sum)


@dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 1433
    n_classes: int = 16
    delta: float = 2.5   # avg log-degree normalizer (dataset statistic)


def init_pna(key, cfg: PNAConfig):
    l, d = cfg.n_layers, cfg.d_hidden
    ks = iter(jax.random.split(key, 8))
    return {
        "enc": normal_init(next(ks), (cfg.d_in, d)),
        "pre": normal_init(next(ks), (l, d, d)),
        "post": normal_init(next(ks), (l, 12 * d, d)),
        "self": normal_init(next(ks), (l, d, d)),
        "ln": jnp.ones((l, d), jnp.float32),
        "dec": normal_init(next(ks), (d, cfg.n_classes)),
    }


def pna_forward(params, g: GraphBatch, cfg: PNAConfig):
    n = g.n_nodes
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    h = jnp.asarray(g.node_feat, jnp.float32) @ params["enc"]
    deg = scatter_sum(jnp.ones((src.shape[0], 1), jnp.float32), dst, n)
    logd = jnp.log(deg + 1.0)
    amp = (logd / cfg.delta)
    att = cfg.delta / jnp.maximum(logd, 1e-2)

    has_nbr = deg > 0  # segment_max is -inf on isolated nodes: mask them

    def step(h, lp):
        pre, post, w_self, ln = lp
        msg = h[src] @ pre
        mean = scatter_mean(msg, dst, n)
        mx = jnp.where(has_nbr, scatter_max(msg, dst, n), 0.0)
        mn = jnp.where(has_nbr, scatter_min(msg, dst, n), 0.0)
        sq = scatter_mean(msg * msg, dst, n)
        std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-6)
        aggs = jnp.concatenate([mean, mx, mn, std], axis=-1)      # (N, 4d)
        scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], -1)
        h = h + jax.nn.relu(layernorm(scaled @ post + h @ w_self, ln))
        return h, None

    stack = (params["pre"], params["post"], params["self"], params["ln"])
    if cfg.n_layers > 2:
        h, _ = jax.lax.scan(lambda c, lp: step(c, lp), h, stack)
    else:  # unrolled: exact dry-run cost probes
        for i in range(cfg.n_layers):
            h, _ = step(h, tuple(a[i] for a in stack))
    return h @ params["dec"]


def pna_loss(params, g: GraphBatch, cfg: PNAConfig):
    logits = pna_forward(params, g, cfg)
    labels = jnp.asarray(g.labels, jnp.int32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logp.shape, 1)
    nll = -jnp.sum(jnp.where(iota == labels[:, None], logp, 0.0), axis=-1)
    return nll.mean()
