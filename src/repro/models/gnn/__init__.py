from .data import GraphBatch, pad_graph, random_graph_batch
from .gatedgcn import GatedGCNConfig, gatedgcn_forward, init_gatedgcn
from .pna import PNAConfig, init_pna, pna_forward
from .egnn import EGNNConfig, egnn_forward, init_egnn
from .mace import MACEConfig, init_mace, mace_forward

__all__ = [
    "GraphBatch", "pad_graph", "random_graph_batch",
    "GatedGCNConfig", "gatedgcn_forward", "init_gatedgcn",
    "PNAConfig", "init_pna", "pna_forward",
    "EGNNConfig", "egnn_forward", "init_egnn",
    "MACEConfig", "init_mace", "mace_forward",
]
