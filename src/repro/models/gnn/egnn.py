"""EGNN — E(n)-equivariant GNN [Satorras et al., arXiv:2102.09844].

    m_ij = φ_e(h_i, h_j, ||x_i − x_j||²)
    x'_i = x_i + (1/deg) Σ_j (x_i − x_j) φ_x(m_ij)
    h'_i = φ_h(h_i, Σ_j m_ij)

Coordinates transform equivariantly under E(n) (rotation/translation);
features are invariant — property-tested under random rotations.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ...layers.common import normal_init
from .data import GraphBatch, scatter_mean, scatter_sum


@dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    n_out: int = 1


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": normal_init(ks[i], (dims[i], dims[i + 1])),
             "b": jnp.zeros((dims[i + 1],), jnp.float32)}
            for i in range(len(dims) - 1)]


def _mlp(layers, x, act=jax.nn.silu, last_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or last_act:
            x = act(x)
    return x


def init_egnn(key, cfg: EGNNConfig):
    d = cfg.d_hidden
    ks = iter(jax.random.split(key, 4 + 3 * cfg.n_layers))
    p = {"enc": normal_init(next(ks), (cfg.d_in, d)),
         "dec": _mlp_init(next(ks), (d, d, cfg.n_out)),
         "layers": []}
    for _ in range(cfg.n_layers):
        p["layers"].append({
            "phi_e": _mlp_init(next(ks), (2 * d + 1, d, d)),
            "phi_x": _mlp_init(next(ks), (d, d, 1)),
            "phi_h": _mlp_init(next(ks), (2 * d, d, d)),
        })
    return p


def egnn_forward(params, g: GraphBatch, cfg: EGNNConfig):
    n = g.n_nodes
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    h = jnp.asarray(g.node_feat, jnp.float32) @ params["enc"]
    x = jnp.asarray(g.coords, jnp.float32)

    for lp in params["layers"]:
        xi, xj = x[dst], x[src]
        diff = xi - xj                                # (E, 3)
        dist2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = _mlp(lp["phi_e"], jnp.concatenate(
            [h[dst], h[src], dist2], axis=-1), last_act=True)   # (E, d)
        coef = _mlp(lp["phi_x"], m)                   # (E, 1)
        x = x + scatter_mean(diff * coef, dst, n)
        agg = scatter_sum(m, dst, n)
        h = h + _mlp(lp["phi_h"], jnp.concatenate([h, agg], axis=-1))
    return h, x


def egnn_energy(params, g: GraphBatch, cfg: EGNNConfig):
    """Invariant per-graph readout (sum-pooled)."""
    h, _ = egnn_forward(params, g, cfg)
    out = _mlp(params["dec"], h)                      # (N, n_out)
    gid = jnp.asarray(g.graph_id if g.graph_id is not None
                      else jnp.zeros(g.n_nodes, jnp.int32), jnp.int32)
    return jax.ops.segment_sum(out, gid, num_segments=g.n_graphs)


def egnn_loss(params, g: GraphBatch, cfg: EGNNConfig):
    e = egnn_energy(params, g, cfg)
    target = jnp.asarray(g.labels, jnp.float32).reshape(e.shape[0], -1)
    return jnp.mean((e - target[:, : e.shape[1]]) ** 2)
