"""MACE — higher-order equivariant message passing [arXiv:2206.07697].

Implementation notes (hardware adaptation, see DESIGN.md):
  * node states are real-spherical-harmonic irreps up to l_max=2 packed as
    a dense (N, C, 9) tensor — TPU-friendly contiguous channels instead of
    e3nn's ragged irrep lists;
  * the symmetric product basis (correlation order 3) is built by iterated
    pairwise coupling with the *real Gaunt tensor* G[ab,c] = ∫ Y_a Y_b Y_c dΩ,
    computed **exactly** at import time by a Gauss-Legendre × uniform-φ
    spherical quadrature (exact for the ≤ degree-6 integrands involved);
    intermediate irreps are capped at l ≤ 2 (MACE's own practice for its
    message irreps);
  * radial basis: 8 Gaussian RBFs -> MLP -> per-l radial weights.

Energy readout is rotation-invariant (property-tested); l=1 components
transform equivariantly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from ...layers.common import normal_init
from .data import GraphBatch, scatter_sum

N_SH = 9  # (l,m) pairs for l <= 2


def real_sph_harm(u: jnp.ndarray) -> jnp.ndarray:
    """Real orthonormal spherical harmonics l<=2 of unit vectors (E,3)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    c0 = 0.5 * np.sqrt(1.0 / np.pi)
    c1 = np.sqrt(3.0 / (4 * np.pi))
    c2a = 0.5 * np.sqrt(15.0 / np.pi)
    c2b = 0.25 * np.sqrt(5.0 / np.pi)
    c2c = 0.25 * np.sqrt(15.0 / np.pi)
    return jnp.stack([
        jnp.full_like(x, c0),          # (0, 0)
        c1 * y,                        # (1,-1)
        c1 * z,                        # (1, 0)
        c1 * x,                        # (1, 1)
        c2a * x * y,                   # (2,-2)
        c2a * y * z,                   # (2,-1)
        c2b * (3 * z * z - 1.0),       # (2, 0)
        c2a * x * z,                   # (2, 1)
        c2c * (x * x - y * y),         # (2, 2)
    ], axis=-1)


def _real_sph_harm_np(u: np.ndarray) -> np.ndarray:
    """Pure-numpy twin of :func:`real_sph_harm` (safe inside jit traces)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    c0 = 0.5 * np.sqrt(1.0 / np.pi)
    c1 = np.sqrt(3.0 / (4 * np.pi))
    c2a = 0.5 * np.sqrt(15.0 / np.pi)
    c2b = 0.25 * np.sqrt(5.0 / np.pi)
    c2c = 0.25 * np.sqrt(15.0 / np.pi)
    return np.stack([
        np.full_like(x, c0), c1 * y, c1 * z, c1 * x,
        c2a * x * y, c2a * y * z, c2b * (3 * z * z - 1.0),
        c2a * x * z, c2c * (x * x - y * y)], axis=-1)


@lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """G[a, b, c] = ∫ Y_a Y_b Y_c dΩ, exact via GL(8) × 16-pt trapezoid."""
    nodes, weights = np.polynomial.legendre.leggauss(8)
    nphi = 16
    phi = 2 * np.pi * np.arange(nphi) / nphi
    u, p = np.meshgrid(nodes, phi, indexing="ij")       # (8, 16)
    w = np.repeat(weights[:, None], nphi, 1) * (2 * np.pi / nphi)
    st = np.sqrt(1 - u ** 2)
    pts = np.stack([st * np.cos(p), st * np.sin(p), u], axis=-1)
    ys = _real_sph_harm_np(pts.reshape(-1, 3)).reshape(8, nphi, N_SH)
    g = np.einsum("ij,ija,ijb,ijc->abc", w, ys, ys, ys)
    g[np.abs(g) < 1e-12] = 0.0
    return g


L_OF = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2])  # l of each SH slot


@dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128      # channels C
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    d_in: int = 16
    r_cut: float = 3.0
    n_out: int = 1
    # §Perf (see EXPERIMENTS.md): 'outer' scatters the (E, C, 9) message
    # outer product (baseline); 'loop' runs 9 per-m segment-sums and never
    # materializes it.  bf16 halves message/coupling traffic (f32
    # accumulation).  couple_chunks splits the Gaunt couplings over node
    # chunks to bound the (chunk, C, 81) intermediate.
    a_basis_mode: str = "outer"
    compute_bf16: bool = False
    couple_chunks: int = 1
    # shard the (node-local) Gaunt couplings over the idle model axis
    shard_couple: bool = False
    remat: bool = False   # recompute message products in backward


def init_mace(key, cfg: MACEConfig):
    c = cfg.d_hidden
    ks = iter(jax.random.split(key, 6 + 6 * cfg.n_layers))
    p = {"enc": normal_init(next(ks), (cfg.d_in, c)), "layers": []}
    for _ in range(cfg.n_layers):
        p["layers"].append({
            # radial: n_rbf -> hidden -> one weight per l
            "rad_w1": normal_init(next(ks), (cfg.n_rbf, 32)),
            "rad_w2": normal_init(next(ks), (32, 3)),
            "w_msg": normal_init(next(ks), (c, c)),
            # channel mixing per correlation order x l
            "w_B": normal_init(next(ks), (cfg.correlation, 3, c, c),
                               stddev=0.05),
            "w_h": normal_init(next(ks), (c, c)),
        })
    p["readout"] = {
        "w1": normal_init(next(ks), (c, c)),
        "w2": normal_init(next(ks), (c, cfg.n_out)),
    }
    return p


def _rbf(r: jnp.ndarray, n: int, r_cut: float) -> jnp.ndarray:
    centers = jnp.linspace(0.0, r_cut, n)
    gamma = (n / r_cut) ** 2
    return jnp.exp(-gamma * (r[:, None] - centers[None, :]) ** 2)


def _couple(a: jnp.ndarray, b: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """(N,C,9) x (N,C,9) -> (N,C,9) via the Gaunt tensor."""
    return jnp.einsum("ncp,ncq,pqr->ncr", a, b, g)


def _maybe_shard(x, spec):
    """with_sharding_constraint iff an ambient mesh exists (dry-run);
    no-op in single-device tests."""
    import jax.sharding as jsh
    try:
        return jax.lax.with_sharding_constraint(
            x, jsh.PartitionSpec(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x


def mace_forward(params, g: GraphBatch, cfg: MACEConfig):
    n = g.n_nodes
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    x = jnp.asarray(g.coords, jnp.float32)
    gaunt = jnp.asarray(gaunt_tensor(), jnp.float32)
    l_of = jnp.asarray(L_OF)

    # initial node irreps: invariant channel in l=0, zero elsewhere
    h0 = jnp.asarray(g.node_feat, jnp.float32) @ params["enc"]   # (N, C)
    state = jnp.zeros((n, cfg.d_hidden, N_SH), jnp.float32)
    state = state.at[:, :, 0].set(h0)
    if cfg.shard_couple:
        state = _maybe_shard(state, ("model", None, None))

    diff = x[dst] - x[src]
    r = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    unit = diff / r[:, None]
    ylm = real_sph_harm(unit)                                    # (E, 9)
    rbf = _rbf(r, cfg.n_rbf, cfg.r_cut)                          # (E, nrbf)

    cdt = jnp.bfloat16 if cfg.compute_bf16 else jnp.float32

    def layer_fn(state, lp):
        rad = jax.nn.silu(rbf @ lp["rad_w1"]) @ lp["rad_w2"]     # (E, 3)
        edge_basis = (ylm * rad[:, l_of]).astype(cdt)            # (E, 9)
        # A-basis: invariant message channels spread over edge irreps
        msg = ((state[:, :, 0] @ lp["w_msg"])[src]).astype(cdt)  # (E, C)
        if cfg.a_basis_mode == "loop":
            # never materialize the (E, C, 9) outer product: one
            # f32-accumulated segment-sum per spherical component
            ams = []
            for m in range(N_SH):
                am = scatter_sum((msg * edge_basis[:, m:m + 1])
                                 .astype(jnp.float32), dst, n)
                if cfg.shard_couple:  # keep node tensors model-sharded
                    am = _maybe_shard(am, ("model", None))
                ams.append(am)
            a = jnp.stack(ams, axis=-1)                          # (N, C, 9)
        else:
            a = scatter_sum(
                (msg[:, :, None] * edge_basis[:, None, :])
                .astype(jnp.float32), dst, n)                    # (N, C, 9)
        # product basis, correlation order 1..3 (iterated Gaunt coupling)
        a = a.astype(cdt)
        if cfg.shard_couple:
            # node-local math: the model axis contributes HBM bandwidth
            a = _maybe_shard(a, ("model", None, None))
        if cfg.couple_chunks > 1:
            k = cfg.couple_chunks
            pad = (-n) % k
            a_p = jnp.pad(a, ((0, pad), (0, 0), (0, 0)))
            parts = []
            for i in range(k):
                blk = a_p[i * (n + pad) // k: (i + 1) * (n + pad) // k]
                bs_blk = [blk]
                cur = blk
                for _ in range(cfg.correlation - 1):
                    cur = _couple(cur, blk, gaunt.astype(cdt))
                    bs_blk.append(cur)
                parts.append(jnp.stack(bs_blk))
            bs = list(jnp.concatenate(parts, axis=1)[:, :n])
        else:
            bs = [a]
            cur = a
            for _ in range(cfg.correlation - 1):
                cur = _couple(cur, a, gaunt.astype(cdt))
                bs.append(cur)
        bs = [b.astype(jnp.float32) for b in bs]
        m = jnp.zeros_like(a)
        for order, b in enumerate(bs):
            for l in range(3):
                sel = (l_of == l)
                mixed = jnp.einsum("ncp,cd->ndp", b * sel[None, None, :],
                                   lp["w_B"][order, l])
                m = m + mixed
        # update: residual on the full irrep state; invariant mix
        state = state + m
        state = state.at[:, :, 0].add(state[:, :, 0] @ lp["w_h"])
        return state

    for lp in params["layers"]:
        fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
        state = fn(state, lp)
    return state


def mace_energy(params, g: GraphBatch, cfg: MACEConfig):
    state = mace_forward(params, g, cfg)
    inv = state[:, :, 0]                                         # (N, C)
    out = jax.nn.silu(inv @ params["readout"]["w1"])
    out = out @ params["readout"]["w2"]                          # (N, n_out)
    gid = jnp.asarray(g.graph_id if g.graph_id is not None
                      else jnp.zeros(g.n_nodes, jnp.int32), jnp.int32)
    return jax.ops.segment_sum(out, gid, num_segments=g.n_graphs)


def mace_loss(params, g: GraphBatch, cfg: MACEConfig):
    e = mace_energy(params, g, cfg)
    target = jnp.asarray(g.labels, jnp.float32).reshape(e.shape[0], -1)
    return jnp.mean((e - target[:, : e.shape[1]]) ** 2)
