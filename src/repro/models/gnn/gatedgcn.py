"""GatedGCN [Bresson & Laurent, arXiv:1711.07553 / benchmarking-GNNs
arXiv:2003.00982]: edge-gated message passing with edge-feature updates.

    e'_ij = E1 h_i + E2 h_j + E3 e_ij
    η_ij  = σ(e'_ij) / (Σ_k σ(e'_ik) + ε)
    h'_i  = ReLU(LN(h_i + U h_i + Σ_j η_ij ⊙ (V h_j)))

LayerNorm replaces BatchNorm (stateless under jit/pod execution).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ...layers.common import layernorm, normal_init
from .data import GraphBatch, scatter_sum


@dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_edge_in: int = 0
    n_classes: int = 16


def init_gatedgcn(key, cfg: GatedGCNConfig):
    l, d = cfg.n_layers, cfg.d_hidden
    ks = iter(jax.random.split(key, 12))
    p = {
        "enc": normal_init(next(ks), (cfg.d_in, d)),
        "edge_enc": normal_init(next(ks), (max(1, cfg.d_edge_in), d)),
        "U": normal_init(next(ks), (l, d, d)),
        "V": normal_init(next(ks), (l, d, d)),
        "E1": normal_init(next(ks), (l, d, d)),
        "E2": normal_init(next(ks), (l, d, d)),
        "E3": normal_init(next(ks), (l, d, d)),
        "ln_h": jnp.ones((l, d), jnp.float32),
        "ln_e": jnp.ones((l, d), jnp.float32),
        "dec": normal_init(next(ks), (d, cfg.n_classes)),
    }
    return p


def gatedgcn_forward(params, g: GraphBatch, cfg: GatedGCNConfig):
    n = g.n_nodes
    src = jnp.asarray(g.src, jnp.int32)
    dst = jnp.asarray(g.dst, jnp.int32)
    h = jnp.asarray(g.node_feat, jnp.float32) @ params["enc"]
    if g.edge_feat is not None:
        e = jnp.asarray(g.edge_feat, jnp.float32) @ params["edge_enc"]
    else:
        e = jnp.zeros((src.shape[0], cfg.d_hidden), jnp.float32)

    def step(h, e, lp):
        u, v, e1, e2, e3, ln_h, ln_e = lp
        hi, hj = h[dst], h[src]
        e_new = hi @ e1 + hj @ e2 + e @ e3
        gate = jax.nn.sigmoid(e_new)
        denom = scatter_sum(gate, dst, n) + 1e-6
        agg = scatter_sum(gate * (hj @ v), dst, n) / denom
        h = h + jax.nn.relu(layernorm(h @ u + agg, ln_h))
        e = e + jax.nn.relu(layernorm(e_new, ln_e))
        return h, e

    def scan_body(carry, lp):
        h, e = carry
        h, e = step(h, e, lp)
        return (h, e), None

    stack = (params["U"], params["V"], params["E1"], params["E2"],
             params["E3"], params["ln_h"], params["ln_e"])
    if cfg.n_layers > 2:
        (h, e), _ = jax.lax.scan(scan_body, (h, e), stack)
    else:  # unrolled: exact dry-run cost probes
        for i in range(cfg.n_layers):
            h, e = step(h, e, tuple(a[i] for a in stack))
    return h @ params["dec"]


def gatedgcn_loss(params, g: GraphBatch, cfg: GatedGCNConfig):
    logits = gatedgcn_forward(params, g, cfg)
    labels = jnp.asarray(g.labels, jnp.int32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logp.shape, 1)
    nll = -jnp.sum(jnp.where(iota == labels[:, None], logp, 0.0), axis=-1)
    return nll.mean()
