"""Graph batch container shared by every GNN.

Message passing over ``jax.ops.segment_sum``/``segment_max`` on an
edge-index — JAX has no CSR SpMM, so the scatter IS the system (see
DESIGN.md).  Edges are stored COO (src, dst); for distributed runs the
edge arrays are sharded over the data axes and partial node aggregates are
psum-merged (same schedule as the join engine's counting SpMV).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class GraphBatch:
    """COO graph (optionally a batch of graphs flattened with offsets)."""

    src: Any          # (E,) int32
    dst: Any          # (E,) int32
    n_nodes: int
    node_feat: Any = None       # (N, F)
    edge_feat: Any = None       # (E, Fe)
    coords: Any = None          # (N, 3) for equivariant models
    graph_id: Any = None        # (N,) int32 graph membership (batched mols)
    n_graphs: int = 1
    labels: Any = None

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


def pad_graph(g: GraphBatch, n_nodes: int, n_edges: int) -> GraphBatch:
    """Pad to static sizes; padded edges self-loop onto a dummy node."""
    def pad_to(x, n, fill=0):
        if x is None:
            return None
        pad = [(0, n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
        return np.pad(np.asarray(x), pad, constant_values=fill)

    dummy = n_nodes - 1
    src = pad_to(g.src, n_edges, dummy)
    dst = pad_to(g.dst, n_edges, dummy)
    return GraphBatch(
        src=src, dst=dst, n_nodes=n_nodes,
        node_feat=pad_to(g.node_feat, n_nodes),
        edge_feat=pad_to(g.edge_feat, n_edges),
        coords=pad_to(g.coords, n_nodes),
        graph_id=pad_to(g.graph_id, n_nodes, g.n_graphs - 1),
        n_graphs=g.n_graphs, labels=g.labels)


def random_graph_batch(n_nodes: int, n_edges: int, d_feat: int,
                       seed: int = 0, coords: bool = False,
                       d_edge: int = 0, n_graphs: int = 1,
                       n_classes: int = 8) -> GraphBatch:
    """Deterministic synthetic graph batch (symmetrized COO)."""
    rng = np.random.default_rng(seed)
    half = n_edges // 2
    s = rng.integers(0, n_nodes, half).astype(np.int32)
    d = rng.integers(0, n_nodes, half).astype(np.int32)
    src = np.concatenate([s, d])
    dst = np.concatenate([d, s])
    g = GraphBatch(
        src=src, dst=dst, n_nodes=n_nodes,
        node_feat=rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        edge_feat=(rng.standard_normal((src.shape[0], d_edge))
                   .astype(np.float32) if d_edge else None),
        coords=(rng.standard_normal((n_nodes, 3)).astype(np.float32)
                if coords else None),
        graph_id=np.sort(rng.integers(0, n_graphs, n_nodes)
                         ).astype(np.int32),
        n_graphs=n_graphs,
        labels=rng.integers(0, n_classes, n_nodes).astype(np.int32))
    return g


def scatter_sum(msg, dst, n_nodes: int):
    return jax.ops.segment_sum(msg, dst, num_segments=n_nodes)


def scatter_max(msg, dst, n_nodes: int):
    return jax.ops.segment_max(msg, dst, num_segments=n_nodes)


def scatter_min(msg, dst, n_nodes: int):
    return -jax.ops.segment_max(-msg, dst, num_segments=n_nodes)


def scatter_mean(msg, dst, n_nodes: int, eps: float = 1e-9):
    s = scatter_sum(msg, dst, n_nodes)
    cnt = jax.ops.segment_sum(jnp.ones_like(msg[..., :1]), dst,
                              num_segments=n_nodes)
    return s / (cnt + eps)
