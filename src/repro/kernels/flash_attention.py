"""Blocked online-softmax (flash) attention for TPU, causal + GQA.

Not a paper contribution — the assigned LM architectures' prefill cells are
attention-dominated, so the perf-critical layer gets an explicit
VMEM-tiled kernel.  Classic scheme: grid (batch·heads, q blocks, k blocks)
with the k-block dimension innermost/sequential; running max / denominator
/ accumulator live in VMEM scratch across k steps; causal blocks above the
diagonal are skipped with ``pl.when`` (structural zero work, the same
tile-skip idea the intersect kernel uses).

Block sizes default to (128, 128) — MXU-aligned on the (q, k) dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_BQ = 128
DEF_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  n_kb: int, q_offset: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: the first query of this q block is at stream position
    # q_offset + qb*bq; skip k blocks strictly above the diagonal.
    q_start = q_offset + qb * bq
    k_start = kb * bk
    needed = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...][:, :1]                 # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)            # (BQ, 1)
        l_prev = l_scr[...][:, :1]
        l_new = alpha * l_prev + p.sum(axis=1, keepdims=True)
        acc = acc_scr[...]
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, scale: float | None = None,
                           bq: int = DEF_BQ, bk: int = DEF_BK,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D); GQA via Hq % Hkv == 0.

    Queries are the last Tq positions of the Tk stream (prefill: Tq == Tk).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    bq_ = min(bq, tq)
    bk_ = min(bk, tk)
    assert tq % bq_ == 0 and tk % bk_ == 0
    qr = q.reshape(b * hq, tq, d)
    kr = k.reshape(b * hkv, tk, d)
    vr = v.reshape(b * hkv, tk, d)
    n_kb = tk // bk_
    grid = (b * hq, tq // bq_, n_kb)

    def kv_index(h, i, j):
        return ((h // hq) * hkv + (h % hq) // group, j, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq_, bk=bk_, n_kb=n_kb, q_offset=tk - tq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk_, d), kv_index),
            pl.BlockSpec((1, bk_, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, tq, d)
