"""Bitset intersection kernels — the dense half of the hybrid layout.

Hub neighborhoods (see ``graphs/layout.py``) are stored as uint32
characteristic vectors over the word-aligned node domain.  Two kernels
cover the two dense intersection shapes, both with the same per-row
``(rows, counts)`` contract as ``kernels/intersect.py``:

* **bitset ∩ bitset** — AND + SWAR popcount, accumulated across word
  tiles.  Cost is ``O(n_words / lanes)`` VPU ops per row pair,
  independent of set cardinality — the hub∩hub crossover the sorted-array
  tile-leapfrog cannot reach (it pays ``O(deg/128)`` tile visits).
* **bitset ∩ array** — gather-test membership: for each (sorted, padded)
  array element, gather one word of the row's bitset and test one bit.
  One gather per element replaces ``log2(deg)`` binary-search rounds.

Grid layout mirrors ``intersect.py``: (row blocks, word/value tiles) with
a VMEM accumulator; tile 0 initializes the output.  The pure-jnp oracles
live in ``kernels/ref.py`` (``bitset_intersect_count_ref`` /
``bitset_member_count_ref``); ``kernels/ops.py`` routes between them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import popcount32

DEF_ROWS = 8     # rows per block (sublane dim)
DEF_TILE = 128   # uint32 words / array values per tile (lane dim)


# ---------------------------------------------------------------------------
# bitset ∩ bitset: AND + popcount accumulate
# ---------------------------------------------------------------------------

def _bitset_and_kernel(a_ref, b_ref, out_ref):
    wt = pl.program_id(1)

    @pl.when(wt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = a_ref[...] & b_ref[...]                    # (R, TILE) uint32
    out_ref[:, 0] += popcount32(v).sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("rows_per_blk", "tile",
                                             "interpret"))
def bitset_intersect_count_pallas(a_words: jax.Array, b_words: jax.Array,
                                  rows_per_blk: int = DEF_ROWS,
                                  tile: int = DEF_TILE,
                                  interpret: bool = True) -> jax.Array:
    """Per-row ``popcount(a & b)`` of (R, W) uint32 bitset rows.

    R % rows_per_blk == 0 and W % tile == 0 (pad with zero words —
    zero-padding is the identity for AND + popcount).
    """
    r, w = a_words.shape
    assert b_words.shape == (r, w)
    assert r % rows_per_blk == 0 and w % tile == 0
    grid = (r // rows_per_blk, w // tile)
    out = pl.pallas_call(
        _bitset_and_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_blk, tile), lambda i, j: (i, j)),
            pl.BlockSpec((rows_per_blk, tile), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rows_per_blk, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        interpret=interpret,
    )(a_words.astype(jnp.uint32), b_words.astype(jnp.uint32))
    return out[:, 0]


# ---------------------------------------------------------------------------
# bitset ∩ array: gather-test membership
# ---------------------------------------------------------------------------

def _bitset_member_kernel(words_ref, b_ref, blen_ref, out_ref, *, tile: int):
    bt = pl.program_id(1)

    @pl.when(bt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    words = words_ref[...]                          # (R, W) full bitset rows
    b = b_ref[...]                                  # (R, TILE) int32
    blen = blen_ref[...]                            # (R, 1)
    col = bt * tile + jax.lax.broadcasted_iota(jnp.int32, b.shape, 1)
    valid = col < blen
    q = jnp.where(valid, b, 0)                      # padded lanes -> bit 0
    w = jnp.take_along_axis(words, (q >> 5).astype(jnp.int32), axis=1)
    hit = (((w >> (q & 31).astype(jnp.uint32)) & 1) != 0) & valid
    out_ref[:, 0] += hit.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("rows_per_blk", "tile",
                                             "interpret"))
def bitset_member_count_pallas(words: jax.Array, b: jax.Array,
                               b_len: jax.Array,
                               rows_per_blk: int = DEF_ROWS,
                               tile: int = DEF_TILE,
                               interpret: bool = True) -> jax.Array:
    """Per-row |bitset ∩ B| — membership of padded sorted int32 lists
    ``b`` (valid prefix ``b_len``) in per-row bitsets ``words`` (R, W).

    R % rows_per_blk == 0, LB % tile == 0.  Array values must lie within
    the bitsets' word-aligned domain ``[0, 32*W)``.
    """
    r, w = words.shape
    lb = b.shape[1]
    assert b.shape[0] == r and r % rows_per_blk == 0 and lb % tile == 0
    grid = (r // rows_per_blk, lb // tile)
    out = pl.pallas_call(
        functools.partial(_bitset_member_kernel, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_blk, w), lambda i, j: (i, 0)),
            pl.BlockSpec((rows_per_blk, tile), lambda i, j: (i, j)),
            pl.BlockSpec((rows_per_blk, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_blk, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        interpret=interpret,
    )(words.astype(jnp.uint32), b.astype(jnp.int32),
      b_len.astype(jnp.int32)[:, None])
    return out[:, 0]
