"""Tile-leapfrog sorted-set intersection — the LFTJ inner loop on TPU.

The scalar leapfrog gallops over two sorted lists, skipping runs that
cannot match.  A systolic/vector machine cannot pointer-chase, so the skip
is lifted to *tile granularity*: for each (A-tile, B-tile) pair the kernel
first compares the tiles' min/max bounds — disjoint ranges are skipped
wholesale (``pl.when`` on a scalar), the vector analogue of a Minesweeper
gap box — and only overlapping tiles pay the dense 8×128 VPU membership
compare.  Sortedness makes the expected number of surviving tile pairs
linear in the tile count (the classic merge-path argument), so the kernel
does ``O((LA+LB)/128)`` tile visits instead of ``O(LA·LB/128²)``.

Layout: per frontier row, two padded sorted int32 lists.  Grid is
(row blocks, A tiles); B tiles are an inner loop so the per-row running
count lives in a VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_ROWS = 8     # frontier rows per block (sublane dim)
DEF_TILE = 128   # values per tile (lane dim)


def _intersect_kernel(a_ref, alen_ref, b_ref, blen_ref, out_ref, *,
                      tile: int, n_b_tiles: int):
    at = pl.program_id(1)
    a = a_ref[...]                      # (R, TILE)
    alen = alen_ref[...]                # (R, 1)
    rows = a.shape[0]
    a_col = at * tile + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a_valid = a_col < alen              # (R, TILE)
    # tile bounds for the leapfrog skip (invalid lanes excluded)
    big = jnp.iinfo(jnp.int32).max
    a_min = jnp.min(jnp.where(a_valid, a, big))
    a_max = jnp.max(jnp.where(a_valid, a, -1))

    @pl.when(at == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    blen = blen_ref[...]                # hoisted: constant across B tiles

    def b_tile_body(state):
        bt, count, _ = state
        b = b_ref[:, pl.dslice(bt * tile, tile)]          # (R, TILE)
        b_col = bt * tile + jax.lax.broadcasted_iota(jnp.int32, b.shape, 1)
        b_valid = b_col < blen
        b_min = jnp.min(jnp.where(b_valid, b, big))
        b_max = jnp.max(jnp.where(b_valid, b, -1))
        # gap-box skip: disjoint [a_min,a_max] x [b_min,b_max] tile pairs
        # branch around the dense compare entirely — a skipped pair pays
        # only the scalar bounds check, no (R, TILE, TILE) VPU work
        overlap = (a_min <= b_max) & (b_min <= a_max)

        def dense_compare(_):
            eq = (a[:, :, None] == b[:, None, :])
            eq &= a_valid[:, :, None] & b_valid[:, None, :]
            hit = eq.any(axis=2)                           # (R, TILE)
            return hit.sum(axis=1, dtype=jnp.int32)

        add = jax.lax.cond(overlap, dense_compare,
                           lambda _: jnp.zeros((rows,), jnp.int32), None)
        # sortedness: every later B tile has min >= b_min, so once
        # b_min > a_max no tile can overlap again (a fully-padded tile
        # reports b_min == INT_MAX and also terminates the scan)
        return bt + 1, count + add, b_min > a_max

    _, count, _ = jax.lax.while_loop(
        lambda s: (s[0] < n_b_tiles) & jnp.logical_not(s[2]),
        b_tile_body,
        (jnp.int32(0), jnp.zeros((rows,), jnp.int32), jnp.bool_(False)))
    out_ref[:, 0] += count


@functools.partial(jax.jit, static_argnames=("rows_per_blk", "tile",
                                             "interpret"))
def intersect_count_pallas(a: jax.Array, a_len: jax.Array, b: jax.Array,
                           b_len: jax.Array, rows_per_blk: int = DEF_ROWS,
                           tile: int = DEF_TILE,
                           interpret: bool = True) -> jax.Array:
    """Per-row |A ∩ B| of padded sorted int32 lists.

    a: (R, LA), b: (R, LB) sorted, unique within the valid prefix;
    a_len/b_len: (R,).  R % rows_per_blk == 0; LA, LB % tile == 0
    (pad with any value; masking is by length).
    """
    r, la = a.shape
    lb = b.shape[1]
    assert r % rows_per_blk == 0 and la % tile == 0 and lb % tile == 0
    n_a_tiles = la // tile
    n_b_tiles = lb // tile
    grid = (r // rows_per_blk, n_a_tiles)
    out = pl.pallas_call(
        functools.partial(_intersect_kernel, tile=tile,
                          n_b_tiles=n_b_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_blk, tile), lambda i, j: (i, j)),
            pl.BlockSpec((rows_per_blk, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((rows_per_blk, lb), lambda i, j: (i, 0)),
            pl.BlockSpec((rows_per_blk, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_blk, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        interpret=interpret,
    )(a.astype(jnp.int32), a_len.astype(jnp.int32)[:, None],
      b.astype(jnp.int32), b_len.astype(jnp.int32)[:, None])
    return out[:, 0]
