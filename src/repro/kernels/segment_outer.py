"""Fused segment-outer-product — MACE's A-basis without the (E, C, M)
materialization (EXPERIMENTS.md §Perf cell C's residual bottleneck).

    A[n, c, m] = Σ_{j : dst_j = n} msg[j, c] · basis[j, m]

Edges arrive sorted by destination.  Grid = (node blocks, edge tiles);
per tile the kernel computes the per-edge outer products **and** the
node-scatter in one MXU matmul:

    acc[BN, C·M] += onehot(dst − n0)ᵀ[BN, TE] @ (msg ⊗ basis)[TE, C·M]

so the (E, C, M) tensor only ever exists one (TE, C·M) tile at a time in
VMEM, and the scatter becomes a matmul (systolic-friendly — no
random-access writes).  Accumulation lives in a VMEM scratch across the
edge-tile grid dimension; edge tiles beyond a block's range are masked by
the dst-in-range predicate (the first/last tiles of a block may straddle
block boundaries, which the same predicate handles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEF_TE = 128   # edges per tile
DEF_BN = 8     # nodes per block


def _kernel(starts_ref, msg_ref, basis_ref, dst_ref, out_ref, acc_scr, *,
            bn: int, te: int, n_tiles: int, total_tiles: int):
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tiles past the edge array clip to the last tile in the index_map;
    # gate them out so the last tile is never double-accumulated
    in_range = starts_ref[b] + t < total_tiles

    @pl.when(in_range)
    def _accumulate():
        msg = msg_ref[...]                       # (TE, C)
        basis = basis_ref[...]                   # (TE, M)
        dst = dst_ref[...]                       # (1, TE)
        n0 = b * bn
        rel = dst[0] - n0                        # (TE,)
        valid = (rel >= 0) & (rel < bn)
        # one-hot scatter matrix (TE, BN)
        oh = (rel[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (te, bn), 1))
        oh &= valid[:, None]
        # per-edge outer products, flattened (TE, C*M)
        prod = (msg[:, :, None] * basis[:, None, :]).reshape(te, -1)
        acc_scr[...] += jax.lax.dot_general(
            oh.astype(jnp.float32), prod.astype(jnp.float32),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (BN, C*M)

    @pl.when(t == n_tiles - 1)
    def _flush():
        out_ref[...] = acc_scr[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_tiles", "bn",
                                             "te", "interpret"))
def segment_outer_pallas(msg: jax.Array, basis: jax.Array,
                         dst: jax.Array, block_tile0: jax.Array,
                         n_nodes: int, n_tiles: int, bn: int = DEF_BN,
                         te: int = DEF_TE,
                         interpret: bool = True) -> jax.Array:
    """msg (E, C), basis (E, M), dst (E,) sorted ascending (pad with
    n_nodes), block_tile0 (n_blocks,) = first edge-tile index overlapping
    each node block, n_tiles = static max tiles per block — both from
    :func:`block_tile_starts`.  Returns (n_nodes, C, M) float32.
    """
    e, c = msg.shape
    m = basis.shape[1]
    assert e % te == 0, "pad edges to the tile size"
    assert n_nodes % bn == 0, "pad nodes to the block size"
    n_blocks = n_nodes // bn
    total_tiles = e // te

    grid = (n_blocks, n_tiles)

    def msg_index(b, t, starts):
        return (jnp.minimum(starts[b] + t, total_tiles - 1), 0)

    def dst_index(b, t, starts):
        return (0, jnp.minimum(starts[b] + t, total_tiles - 1))

    out = pl.pallas_call(
        functools.partial(_kernel, bn=bn, te=te, n_tiles=n_tiles,
                          total_tiles=total_tiles),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((te, c), msg_index),
                pl.BlockSpec((te, m), msg_index),
                pl.BlockSpec((1, te), dst_index),
            ],
            out_specs=pl.BlockSpec((bn, c * m), lambda b, t, s: (b, 0)),
            scratch_shapes=[pltpu.VMEM((bn, c * m), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_nodes, c * m), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_tile0, jnp.int32), msg, basis,
      dst.astype(jnp.int32)[None, :])
    return out.reshape(n_nodes, c, m)


def block_tile_starts(dst_sorted: np.ndarray, n_nodes: int,
                      bn: int = DEF_BN, te: int = DEF_TE
                      ) -> tuple[np.ndarray, int]:
    """(first edge-tile per bn-node block, static max tiles per block)."""
    e = dst_sorted.shape[0]
    total_tiles = max(1, e // te)
    n_blocks = -(-n_nodes // bn)
    first_edge = np.searchsorted(dst_sorted, np.arange(n_blocks) * bn,
                                 side="left")
    last_edge = np.searchsorted(dst_sorted,
                                np.arange(1, n_blocks + 1) * bn - 1,
                                side="right")
    t0 = np.minimum(first_edge // te, total_tiles - 1).astype(np.int32)
    t1 = np.minimum(np.maximum(last_edge - 1, first_edge) // te,
                    total_tiles - 1)
    n_tiles = int(max(1, (t1 - t0).max() + 1))
    return t0, n_tiles


def segment_expand(prefix: np.ndarray, counts: np.ndarray,
                   values: np.ndarray) -> np.ndarray:
    """Host-side segmented expansion — the enumeration dual of the
    segment-outer scatter above.  Where the kernel folds per-edge products
    *into* nodes, this unfolds per-row extension segments *out of* rows:

        out = [prefix[i] ++ v  for i, seg in enumerate(segments)
                               for v in seg]

    ``prefix`` (C, k) rows are repeated by ``counts`` (C,) and the
    flattened segment ``values`` (counts.sum(),) become the new last
    column.  Rows stay in segment order, so a lex-sorted prefix with
    ascending per-row segments yields lex-sorted output — the invariant
    ``repro.results.ResultCursor`` streams pages under.  Returns int64.
    """
    prefix = np.asarray(prefix)
    counts = np.asarray(counts, dtype=np.int64)
    values = np.asarray(values)
    reps = np.repeat(np.arange(counts.shape[0]), counts)
    out = np.empty((values.shape[0], prefix.shape[1] + 1), dtype=np.int64)
    out[:, :-1] = prefix[reps]
    out[:, -1] = values
    return out


def segment_outer_ref(msg, basis, dst, n_nodes: int):
    """Oracle: segment-sum of explicit outer products."""
    prod = msg[:, :, None] * basis[:, None, :]
    safe = jnp.clip(dst, 0, n_nodes)  # pad rows (dst == n_nodes) dropped
    out = jax.ops.segment_sum(prod, safe, num_segments=n_nodes + 1)
    return out[:n_nodes].astype(jnp.float32)
