"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics the TPU kernels must reproduce; they are also the
default execution path on CPU (the Pallas kernels run under
``interpret=True`` only in tests on this container).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Segmented batched binary search (the vectorized ``seek_lub``)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_iter", "unroll"))
def searchsorted_segments_ref(values: jax.Array, lo: jax.Array,
                              hi: jax.Array, queries: jax.Array,
                              n_iter: int, unroll: bool = False
                              ) -> tuple[jax.Array, jax.Array]:
    """Branchless lower-bound of ``queries`` within ``values[lo:hi)``.

    values:  (M,) sorted within each segment
    lo, hi:  broadcastable to queries' shape — segment bounds per query
    queries: any shape
    n_iter:  static iteration count >= ceil(log2(max segment length)) + 1

    Returns (pos, found): ``pos`` = first index in [lo, hi) with
    ``values[pos] >= q`` (== hi if none), ``found`` = q present.
    """
    m = values.shape[0]
    q = queries
    lo0 = jnp.broadcast_to(lo, q.shape)
    hi0 = jnp.broadcast_to(hi, q.shape)
    lo_c, hi_c = lo0, hi0

    def body(_, state):
        lo_c, hi_c = state
        active = lo_c < hi_c
        mid = (lo_c + hi_c) >> 1
        v = values[jnp.clip(mid, 0, m - 1)]
        go_right = active & (v < q)
        lo_c = jnp.where(go_right, mid + 1, lo_c)
        hi_c = jnp.where(active & ~go_right, mid, hi_c)
        return lo_c, hi_c

    if unroll:
        # straight-line HLO so cost_analysis sees every round (dry-run)
        state = (lo_c, hi_c)
        for i in range(n_iter):
            state = body(i, state)
        lo_c, hi_c = state
    else:
        lo_c, hi_c = jax.lax.fori_loop(0, n_iter, body, (lo_c, hi_c))
    pos = lo_c
    found = (pos < hi0) & (values[jnp.clip(pos, 0, m - 1)] == q)
    return pos, found


@partial(jax.jit, static_argnames=("stride", "n1", "n2", "unroll"))
def searchsorted_segments_2level_ref(values: jax.Array, summary: jax.Array,
                                     lo: jax.Array, hi: jax.Array,
                                     queries: jax.Array, stride: int,
                                     n1: int, n2: int,
                                     unroll: bool = False):
    """Two-level segmented lower bound.

    ``summary[k] = values[k*stride]`` — the first level binary-searches the
    (tiny, cache/VMEM-resident) summary over the segment's *full* blocks;
    the second level searches a <= 2*stride window of the big table.  Cuts
    big-table gather rounds from ~log2(max_deg) to ~log2(2*stride).
    """
    q = queries
    lo_b = jnp.broadcast_to(lo, q.shape)
    hi_b = jnp.broadcast_to(hi, q.shape)
    fb0 = (lo_b + stride - 1) // stride        # first full block
    fb1 = hi_b // stride                       # one-past-last full block
    has_blocks = fb1 > fb0
    pos1, _ = searchsorted_segments_ref(
        summary, fb0, jnp.maximum(fb0, fb1), q, n1, unroll=unroll)
    wlo = jnp.where(has_blocks & (pos1 > fb0), (pos1 - 1) * stride, lo_b)
    wlo = jnp.maximum(wlo, lo_b)
    whi = jnp.where(has_blocks & (pos1 < fb1), pos1 * stride + 1, hi_b)
    whi = jnp.minimum(whi, hi_b)
    return searchsorted_segments_ref(values, wlo, whi, q, n2,
                                     unroll=unroll)


# ---------------------------------------------------------------------------
# Tile-leapfrog sorted intersection (counts)
# ---------------------------------------------------------------------------

@jax.jit
def intersect_count_ref(a: jax.Array, a_len: jax.Array,
                        b: jax.Array, b_len: jax.Array) -> jax.Array:
    """Per-row |A ∩ B| of two padded sorted int arrays.

    a: (R, LA), b: (R, LB); a_len/b_len: (R,) valid lengths.
    Oracle is the O(LA·LB) dense membership matrix (the in-tile compare the
    TPU kernel performs after tile skipping).
    """
    la = jnp.arange(a.shape[1])[None, :]
    lb = jnp.arange(b.shape[1])[None, :]
    va = la < a_len[:, None]
    vb = lb < b_len[:, None]
    eq = (a[:, :, None] == b[:, None, :]) & va[:, :, None] & vb[:, None, :]
    return eq.any(axis=2).sum(axis=1)


# ---------------------------------------------------------------------------
# Bitset intersection / membership (the hybrid-layout kernels)
# ---------------------------------------------------------------------------

def popcount32(v: jax.Array) -> jax.Array:
    """Per-element popcount of a uint32 array (SWAR bit trick)."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


@jax.jit
def bitset_intersect_count_ref(a_words: jax.Array,
                               b_words: jax.Array) -> jax.Array:
    """Per-row |A ∩ B| of two bitset rows: popcount(AND).

    a_words, b_words: (R, W) uint32 characteristic vectors over a common
    word-aligned domain.  Same ``(rows, counts)`` contract as
    :func:`intersect_count_ref` — the cost is O(W) words regardless of
    set cardinality, which is the dense-layout win for hub∩hub.
    """
    return popcount32(a_words & b_words).sum(axis=1)


@jax.jit
def bitset_member_ref(words: jax.Array, queries: jax.Array) -> jax.Array:
    """Gather-test membership: bit ``q & 31`` of ``words[r, q >> 5]``.

    words: (R, W) uint32 per-row bitsets; queries: (R, Q) int ids within
    the word-aligned domain.  Returns (R, Q) bool — the O(1)-per-query
    probe the hybrid engine uses in place of segmented binary search.
    """
    q = queries.astype(jnp.int32)
    w = jnp.take_along_axis(words, (q >> 5).astype(jnp.int32), axis=1)
    return ((w >> (q & 31).astype(jnp.uint32)) & 1) != 0


@jax.jit
def bitset_member_count_ref(words: jax.Array, b: jax.Array,
                            b_len: jax.Array) -> jax.Array:
    """Per-row |bitset ∩ B| for padded sorted arrays ``b`` with valid
    lengths ``b_len`` — the bitset∩array half of the hybrid layout,
    same ``(rows, counts)`` contract as :func:`intersect_count_ref`."""
    valid = jnp.arange(b.shape[1])[None, :] < b_len[:, None]
    hit = bitset_member_ref(words, jnp.where(valid, b, 0)) & valid
    return hit.sum(axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Flash attention (causal, GQA) — oracle
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """Plain softmax attention oracle.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D).  Hq % Hkv == 0 (GQA).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, tq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        tk = k.shape[2]
        # queries are the last tq positions of the tk-length stream
        qpos = jnp.arange(tq) + (tk - tq)
        mask = qpos[:, None] >= jnp.arange(tk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, tq, d).astype(q.dtype)
