"""Batched segmented binary search — the vectorized ``seek_lub`` on TPU.

Every lane carries one (query, segment) pair; ``n_iter`` branchless rounds
of midpoint gathers converge all lanes simultaneously.  This is the
log-time probe LFTJ and Minesweeper both build on (§2.2/§4.5), with the
B-tree ``seek_lub``/``seek_glb`` replaced by binary search over the
sorted-array trie.

VMEM layout: the sorted ``values`` array is the kernel's resident block
(cap ~1M int32 = 4 MB VMEM; larger relations are sharded before the call —
the engine shards the frontier, not the index).  The midpoint gather uses
an in-VMEM dynamic gather (``jnp.take``), which lowers to the TPU
dynamic-gather path on v4+ for 32-bit element types.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_ROWS = 8
DEF_LANES = 128


def _searchsorted_kernel(values_ref, lo_ref, hi_ref, q_ref,
                         pos_ref, found_ref, *, n_iter: int):
    values = values_ref[...]            # (1, M)
    m = values.shape[1]
    q = q_ref[...]
    lo = lo_ref[...]
    hi0 = hi_ref[...]
    hi = hi0

    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) >> 1
        midc = jnp.clip(mid, 0, m - 1)
        v = jnp.take(values[0], midc)
        go_right = active & (v < q)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    pos_ref[...] = lo
    vpos = jnp.take(values[0], jnp.clip(lo, 0, m - 1))
    found_ref[...] = ((lo < hi0) & (vpos == q)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_iter", "rows_per_blk",
                                             "interpret"))
def searchsorted_segments_pallas(values: jax.Array, lo: jax.Array,
                                 hi: jax.Array, queries: jax.Array,
                                 n_iter: int, rows_per_blk: int = DEF_ROWS,
                                 interpret: bool = True):
    """Pallas twin of :func:`repro.kernels.ref.searchsorted_segments_ref`.

    queries: (R, W); lo/hi broadcastable to (R, W); values: (M,).
    Returns (pos, found) with found as bool.
    """
    q = queries.astype(jnp.int32)
    r, w = q.shape
    lo = jnp.broadcast_to(lo, q.shape).astype(jnp.int32)
    hi = jnp.broadcast_to(hi, q.shape).astype(jnp.int32)
    assert r % rows_per_blk == 0 and w % DEF_LANES == 0, (r, w)
    m = values.shape[0]
    grid = (r // rows_per_blk,)
    pos, found = pl.pallas_call(
        functools.partial(_searchsorted_kernel, n_iter=n_iter),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((rows_per_blk, w), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_blk, w), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_blk, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_blk, w), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_blk, w), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, w), jnp.int32),
            jax.ShapeDtypeStruct((r, w), jnp.int32),
        ],
        interpret=interpret,
    )(values.astype(jnp.int32)[None, :], lo, hi, q)
    return pos, found.astype(bool)
