"""Jitted public wrappers for the kernel layer.

``use_pallas`` selects the Pallas TPU kernels (validated under
``interpret=True`` on CPU); default is the pure-jnp reference path, which XLA
fuses well on CPU and which lowers to identical HLO shapes for the roofline
dry-run.
"""
from __future__ import annotations

import os


from . import ref as _ref

_USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def use_pallas() -> bool:
    return _USE_PALLAS


def searchsorted_segments(values, lo, hi, queries, n_iter: int,
                          unroll: bool = False):
    if _USE_PALLAS:
        from .searchsorted import searchsorted_segments_pallas
        return searchsorted_segments_pallas(values, lo, hi, queries,
                                            n_iter=n_iter,
                                            interpret=_INTERPRET)
    return _ref.searchsorted_segments_ref(values, lo, hi, queries,
                                          n_iter=n_iter, unroll=unroll)


def bitset_intersect_count(a_words, b_words):
    if _USE_PALLAS:
        from .intersect_bitset import bitset_intersect_count_pallas
        return bitset_intersect_count_pallas(a_words, b_words,
                                             interpret=_INTERPRET)
    return _ref.bitset_intersect_count_ref(a_words, b_words)


def bitset_member_count(words, b, b_len):
    if _USE_PALLAS:
        from .intersect_bitset import bitset_member_count_pallas
        return bitset_member_count_pallas(words, b, b_len,
                                          interpret=_INTERPRET)
    return _ref.bitset_member_count_ref(words, b, b_len)


def flash_attention(q, k, v, causal: bool = True, scale=None):
    if _USE_PALLAS:
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                      interpret=_INTERPRET)
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
