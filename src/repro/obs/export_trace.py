"""Export a JSONL query trace — the CI bench-smoke trace artifact.

Runs one paper query (default: the 3-path) on a small synthetic
Zipf-degree graph under EXPLAIN ANALYZE, verifies count parity against
an untraced run of the same plan, and writes the trace as JSONL::

    PYTHONPATH=src python -m repro.obs.export_trace \\
        --query 3-path --out trace_3path.jsonl

The artifact lets CI diff per-level est-vs-observed cardinalities (and
kernel-path mix) across commits; the line schema is documented in
``docs/OBSERVABILITY.md``.

``--metrics PATH`` additionally runs the query under an active
:class:`DeviceProfile`, publishes it into the process
:class:`MetricsRegistry`, and dumps the flattened registry snapshot as
JSON — the companion metrics artifact (compile/kernel histograms,
jit-call counters, peak-live-bytes gauge).
"""
from __future__ import annotations

import argparse
import json
import sys

from ..core import GraphDB, execute, get_query
from ..graphs import node_sample
from ..graphs.generators import zipf_graph
from .explain import explain_analyze
from .metrics import get_registry
from .profile import DeviceProfile


def trace_gdb(n: int = 2000, m: int = 8000, seed: int = 0,
              selectivity: float = 8.0) -> GraphDB:
    """The small Zipf-skewed graph the trace artifact is produced on."""
    g = zipf_graph(n, m, seed=seed)
    unary = {f"v{i}": node_sample(g.n_nodes, selectivity, seed=17 * i + 1)
             for i in range(1, 5)}
    return GraphDB(g, unary)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--query", default="3-path",
                    help="paper query name (default: 3-path)")
    ap.add_argument("--engine", default="vlftj",
                    help="physical engine (default: vlftj — the "
                         "level-structured executor, so the trace "
                         "carries per-level est/obs cardinalities)")
    ap.add_argument("--out", default="trace.jsonl",
                    help="JSONL output path")
    ap.add_argument("--n", type=int, default=2000, help="graph nodes")
    ap.add_argument("--m", type=int, default=8000, help="graph edges")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics", default=None,
                    help="also profile the run and dump the process "
                         "MetricsRegistry snapshot as JSON here")
    args = ap.parse_args(argv)

    gdb = trace_gdb(args.n, args.m, seed=args.seed)
    query = get_query(args.query)
    prof = DeviceProfile(args.query, args.engine) if args.metrics else None
    if prof is not None:
        with prof.activate():
            res = explain_analyze(query, gdb, engine=args.engine)
    else:
        res = explain_analyze(query, gdb, engine=args.engine)
    untraced = execute(res.plan, gdb)
    if untraced != res.count:
        print(f"PARITY FAILURE: traced={res.count} untraced={untraced}",
              file=sys.stderr)
        return 1
    if prof is not None:
        prof.publish(trace=res.trace, registry=get_registry())
        with open(args.metrics, "w") as fh:
            json.dump(get_registry().snapshot(), fh, indent=1,
                      sort_keys=True)
            fh.write("\n")
    res.trace.to_jsonl(args.out)
    print(res.render())
    print(f"trace ({len(res.trace.levels)} levels, "
          f"{len(res.trace.events)} events) -> {args.out}")
    if prof is not None:
        print(f"profile ({prof.jit['calls']} jit calls, "
              f"{prof.memory['peak_live_bytes']} peak live bytes) "
              f"-> {args.metrics}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
