"""EXPLAIN ANALYZE: run a plan, render est-vs-observed per GAO level.

``explain_analyze(query, gdb)`` plans the query (or takes a prebuilt
plan), executes it under a fresh :class:`~repro.obs.trace.QueryTrace`,
and returns an :class:`ExplainResult` whose :meth:`~ExplainResult.render`
prints the plan tree with each level annotated by the planner's
estimated frontier cardinality, the observed one, and their Q-error —
the feedback channel the ROADMAP's adaptive re-planning item consumes::

    3-clique -> vlftj  count=1612  wall=0.12s
    L0 a  est=1000      obs=1000      q=1.00
    L1 b  est=12000     obs=11402     q=1.05   [bsearch=11402]
    L2 c  est=1430      obs=1612      q=1.13   [tile=9000, bsearch=2402]
    max q-error 1.13

All numbers come from the engine's host-side ``stats`` dict and the
plan's cost annotations — EXPLAIN ANALYZE costs one normal execution,
no extra device work.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..core.engine import execute_stats
from ..core.plan import GraphStats, JoinPlan
from ..core.planner import plan_query
from ..core.query import Query
from .trace import QueryTrace


@dataclass
class ExplainResult:
    """The outcome of one ``explain_analyze`` run.

    ``verification`` carries the static plan verifier's findings
    (:class:`repro.analysis.Finding`) — EXPLAIN ANALYZE *surfaces* them
    (including errors, rendered under the plan tree) rather than
    raising, so a rejected plan can still be inspected.
    """

    plan: JoinPlan
    count: int
    trace: QueryTrace
    engine_stats: dict = field(default_factory=dict)
    verification: list = field(default_factory=list)

    @property
    def levels(self) -> list[dict]:
        """Per-level records (GAO order): ``level``, ``var``,
        ``est_rows``, ``obs_rows``, ``q_error``, ``kernel``, …"""
        return [self.trace.levels[lv] for lv in sorted(self.trace.levels)]

    @property
    def max_q_error(self) -> float:
        return self.trace.max_q_error

    @staticmethod
    def _fmt(x) -> str:
        if x is None:
            return "?"
        x = float(x)
        if x == int(x) and abs(x) < 1e15:
            return str(int(x))
        return f"{x:.3g}"

    def render(self) -> str:
        """The annotated plan tree as printable text."""
        lines = [f"{self.plan.describe()}  count={self.count}  "
                 f"wall={self.trace.summary.get('wall_s', 0.0):.3f}s"]
        for rec in self.levels:
            lv = rec["level"]
            var = rec.get("var") or "?"
            q = rec.get("q_error")
            qs = ("q=inf" if q is not None and math.isinf(q)
                  else f"q={q:.2f}" if q is not None else "q=?")
            line = (f"  L{lv} {var:<3} est={self._fmt(rec.get('est_rows')):<10}"
                    f" obs={self._fmt(rec.get('obs_rows')):<10} {qs}")
            kern = rec.get("kernel")
            if kern:
                inner = ", ".join(f"{k}={v}" for k, v in sorted(kern.items()))
                line += f"   [{inner}]"
            lines.append(line)
        mq = self.max_q_error
        lines.append("  max q-error " +
                     ("inf" if math.isinf(mq) else f"{mq:.2f}"))
        for f in self.verification:
            lines.append(f"  verify: {f.severity} [{f.rule}] {f.message}")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()


def explain_analyze(query: Query, gdb, engine: str = "auto",
                    plan: JoinPlan | None = None, **kw) -> ExplainResult:
    """Plan (unless ``plan`` is given), execute under a fresh trace, and
    return the annotated :class:`ExplainResult`.  ``engine`` and extra
    keyword arguments pass through to planning/execution exactly as in
    :func:`repro.core.engine.count`."""
    if plan is None:
        plan = plan_query(query, GraphStats.of(gdb), engine=engine)
    # surface static verification through the result instead of raising:
    # EXPLAIN exists to inspect plans, including ones the executor would
    # reject (engine.count's verify=True path raises on the same errors)
    from ..analysis import PlanVerificationError, verify_for_execution
    try:
        findings = verify_for_execution(plan, gdb)
    except PlanVerificationError as e:
        findings = e.findings
    trace = QueryTrace(query.name, plan.gao, plan.engine)
    with trace.activate():
        count, stats = execute_stats(plan, gdb, **kw)
    return ExplainResult(plan=plan, count=count, trace=trace,
                         engine_stats=stats, verification=list(findings))
