"""Query tracing: a span tree keyed by the plan's GAO levels.

The paper's central claim — WCOJ engines win because per-level
intersection work tracks the *actual* intermediate cardinalities — is
exactly what a :class:`QueryTrace` records: per GAO level, the planner's
estimated frontier cardinality next to the observed one (plus the kernel
path taken, rows expanded, and wall time), and a timeline of execution
events (scheduler preempt/resume/restart, cross-shard exchanges, worker
spans).

Capture is deliberately cheap: every number a trace records is already
host-resident when it is recorded — frontier shapes between jitted level
steps, engine ``stats`` dict counters, exchange meters — so tracing adds
**zero device dispatches** (asserted in ``tests/test_obs.py``).  The
engines publish per-level observations into their own ``stats`` dicts
unconditionally (plain dict writes); a trace harvests them after the run
via :meth:`QueryTrace.record_engine`.  Cross-cutting components
(scheduler, dist drivers, pool) find the active trace through a
contextvar — :func:`current_trace` — so no signature threading is
needed, and a ``None`` answer costs one attribute read.

Export: :meth:`QueryTrace.to_jsonl` renders the trace as one JSON object
per line (header, level records, events, spans, summary) so benches and
CI can diff runs; :meth:`QueryTrace.from_jsonl` round-trips it.  The
line schema is documented in ``docs/OBSERVABILITY.md``.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import math
import time

#: JSONL schema version stamped into every trace header.
TRACE_SCHEMA_VERSION = 1

_ACTIVE: contextvars.ContextVar["QueryTrace | None"] = \
    contextvars.ContextVar("repro_obs_active_trace", default=None)


def current_trace() -> "QueryTrace | None":
    """The trace active in this context, or None (tracing disabled)."""
    return _ACTIVE.get()


def qerror(est: float, obs: float) -> float:
    """The symmetric Q-error ``max(est/obs, obs/est)`` — 1.0 is a
    perfect estimate; both-zero counts as perfect; one-sided zero is
    ``inf`` (the estimate missed an empty/non-empty transition)."""
    est, obs = float(est), float(obs)
    if est <= 0.0 and obs <= 0.0:
        return 1.0
    if est <= 0.0 or obs <= 0.0:
        return math.inf
    return max(est / obs, obs / est)


class QueryTrace:
    """One query execution's observability record.

    Three record kinds accumulate, all timestamped relative to trace
    creation (``t`` seconds):

    * **levels** — one dict per GAO level (upserted, so a resumed run
      refines its earlier record): ``level``, ``var``, ``est_rows``,
      ``obs_rows``, ``q_error``, ``rows_expanded``, ``kernel`` (path
      rows by strategy: array/bitset tile-vs-bsearch), ``wall_s``;
    * **events** — point occurrences: ``preempt``, ``resume``,
      ``restart`` (registry eviction), ``exchange`` (cross-shard
      adjacency traffic), ``admission_rejected``, …;
    * **spans** — named durations (``begin_span``/``end`` or the
      :meth:`span` context manager): quanta, pool worker drains,
      plan/execute phases.

    ``meta`` carries query/gao/engine identification; ``summary`` the
    final count and totals.  :meth:`activate` installs the trace as the
    context's current trace for the duration of a ``with`` block.
    """

    enabled = True

    def __init__(self, query_name: str = "", gao: tuple[str, ...] = (),
                 engine: str = ""):
        self.meta = {"query": query_name, "gao": list(gao),
                     "engine": engine, "schema": TRACE_SCHEMA_VERSION}
        self.levels: dict[int, dict] = {}
        self.events: list[dict] = []
        self.spans: list[dict] = []
        self.summary: dict = {}
        self._t0 = time.perf_counter()

    # -- recording -----------------------------------------------------------
    def _now(self) -> float:
        return round(time.perf_counter() - self._t0, 6)

    def set_meta(self, **kw) -> None:
        self.meta.update(kw)

    def level(self, level: int, **attrs) -> dict:
        """Upsert the per-level record; recomputes ``q_error`` whenever
        both ``est_rows`` and ``obs_rows`` are known."""
        rec = self.levels.setdefault(int(level), {"level": int(level)})
        rec.update({k: v for k, v in attrs.items() if v is not None})
        if "est_rows" in rec and "obs_rows" in rec:
            rec["q_error"] = qerror(rec["est_rows"], rec["obs_rows"])
        return rec

    def event(self, name: str, **attrs) -> dict:
        rec = {"name": name, "t": self._now(), **attrs}
        self.events.append(rec)
        return rec

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """``with trace.span("quantum", job=...):`` — records the
        duration on exit (exceptions still close the span)."""
        t0 = time.perf_counter()
        rec = {"name": name, "t": self._now(), **attrs}
        try:
            yield rec
        finally:
            rec["dur_s"] = round(time.perf_counter() - t0, 6)
            self.spans.append(rec)

    def record_engine(self, stats: dict,
                      gao: tuple[str, ...] = (),
                      est_rows: tuple[float, ...] = ()) -> None:
        """Harvest an engine ``stats`` dict (the unified namespace —
        ``repro.obs.schema``) into per-level records.

        ``stats['level_rows']`` maps GAO level -> observed frontier
        cardinality (the final level's entry is the output count on the
        counting path), ``level_wall_s`` / ``level_paths`` the per-level
        timings and kernel-path row tallies.  ``est_rows`` is the
        plan's ``level_est_rows`` annotation.
        """
        level_rows = stats.get("level_rows", {}) or {}
        walls = stats.get("level_wall_s", {}) or {}
        paths = stats.get("level_paths", {}) or {}
        n = max([len(gao), len(est_rows),
                 *(int(lv) + 1 for lv in level_rows)], default=0)
        for lv in range(n):
            self.level(
                lv,
                var=gao[lv] if lv < len(gao) else None,
                est_rows=(float(est_rows[lv]) if lv < len(est_rows)
                          else None),
                obs_rows=(int(level_rows[lv]) if lv in level_rows
                          else None),
                wall_s=walls.get(lv),
                kernel=paths.get(lv))

    def finish(self, count: int | None = None, **kw) -> None:
        self.summary.update({"wall_s": self._now(), **kw})
        if count is not None:
            self.summary["count"] = int(count)

    # -- context activation --------------------------------------------------
    @contextlib.contextmanager
    def activate(self):
        """Install as :func:`current_trace` for the block's duration."""
        token = _ACTIVE.set(self)
        try:
            yield self
        finally:
            _ACTIVE.reset(token)

    # -- derived views -------------------------------------------------------
    @property
    def max_q_error(self) -> float:
        qs = [rec["q_error"] for rec in self.levels.values()
              if "q_error" in rec]
        return max(qs) if qs else 1.0

    def events_named(self, name: str) -> list[dict]:
        return [e for e in self.events if e["name"] == name]

    # -- export --------------------------------------------------------------
    def to_dict(self) -> dict:
        return {"meta": dict(self.meta),
                "levels": [self.levels[lv] for lv in sorted(self.levels)],
                "events": list(self.events),
                "spans": list(self.spans),
                "summary": dict(self.summary)}

    def to_jsonl(self, path: str | None = None) -> str:
        """One JSON object per line: ``header``, ``level`` (GAO order),
        ``event`` / ``span`` (chronological), ``summary``.  Writes to
        ``path`` when given; returns the text either way."""
        def _clean(obj):
            if isinstance(obj, dict):
                return {str(k): _clean(v) for k, v in obj.items()}
            if isinstance(obj, (list, tuple)):
                return [_clean(v) for v in obj]
            if isinstance(obj, float):
                if math.isinf(obj):
                    return "inf" if obj > 0 else "-inf"
                if math.isnan(obj):
                    return "nan"
                return obj
            if hasattr(obj, "item"):      # numpy scalars
                return obj.item()
            return obj

        lines = [json.dumps({"kind": "header", **_clean(self.meta)})]
        for lv in sorted(self.levels):
            lines.append(json.dumps(
                {"kind": "level", **_clean(self.levels[lv])}))
        for e in self.events:
            lines.append(json.dumps({"kind": "event", **_clean(e)}))
        for s in self.spans:
            lines.append(json.dumps({"kind": "span", **_clean(s)}))
        lines.append(json.dumps({"kind": "summary",
                                 **_clean(self.summary)}))
        text = "\n".join(lines) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    @classmethod
    def from_jsonl(cls, text) -> "QueryTrace":
        """Rebuild a trace from :meth:`to_jsonl` output — the JSONL text
        itself or a path to it (timestamps and records preserved; the
        clock origin is not)."""
        import os
        if isinstance(text, os.PathLike):
            with open(text) as f:
                text = f.read()
        tr = cls()
        for line in text.splitlines():
            if not line.strip():
                continue
            rec = json.loads(line)
            kind = rec.pop("kind")
            if kind == "header":
                tr.meta = rec
            elif kind == "level":
                tr.levels[int(rec["level"])] = rec
            elif kind == "event":
                tr.events.append(rec)
            elif kind == "span":
                tr.spans.append(rec)
            elif kind == "summary":
                tr.summary = rec
        return tr


class NullTrace:
    """The disabled tracer: every recording method is a no-op and
    :attr:`enabled` is False, so call sites can skip building
    attributes.  ``NullTrace`` is never installed as the context's
    current trace — ``current_trace() is None`` is the normal
    disabled-path check — but code handed a trace object directly can
    take this instead of branching on None."""

    enabled = False

    def set_meta(self, **kw):
        pass

    def level(self, level, **attrs):
        return {}

    def event(self, name, **attrs):
        return {}

    @contextlib.contextmanager
    def span(self, name, **attrs):
        yield {}

    def record_engine(self, stats, gao=(), est_rows=()):
        pass

    def finish(self, count=None, **kw):
        pass

    @contextlib.contextmanager
    def activate(self):
        yield self


NULL_TRACE = NullTrace()
