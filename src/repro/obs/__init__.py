"""Observability: query tracing, EXPLAIN ANALYZE, and process metrics.

Three pieces (see ``docs/OBSERVABILITY.md`` for the full walkthrough):

* :class:`QueryTrace` / :func:`current_trace` — one query's span tree
  keyed by GAO levels: est-vs-observed frontier cardinality + Q-error
  per level, kernel paths, scheduler preempt/resume/restart events,
  cross-shard exchange traffic; JSONL export via ``to_jsonl``.
* :func:`explain_analyze` — run a query under a fresh trace and render
  the annotated plan tree.
* :class:`MetricsRegistry` / :func:`get_registry` — process-wide
  counters/gauges/histograms with labels, snapshotted by
  ``QueryServer.metrics()``.
* :class:`DeviceProfile` / :func:`current_profile` — device-side
  resource accounting one layer below the trace: jit compile/call
  counts and compile wall, per-kernel-family wall breakdown
  (``intersect`` / ``intersect_bitset`` / ``segment_outer``), and
  live-buffer memory watermarks sampled at GAO level boundaries.

Everything records host-resident numbers only: tracing, metrics, and
profiling add zero device dispatches (guarded by ``tests/test_obs.py``
and ``tests/test_profile.py``).
"""
from .explain import ExplainResult, explain_analyze
from .metrics import (Counter, DEFAULT_BUCKETS, Gauge, Histogram,
                      MetricsRegistry, get_registry)
from .profile import (DeviceProfile, KERNEL_FAMILIES, NULL_PROFILE,
                      NullProfile, PROFILE_SCHEMA_VERSION, current_profile)
from .schema import (ENGINE_REQUIRED_KEYS, ENGINE_STATS_SOURCE_KEYS,
                     normalize_engine_stats)
from .trace import (NULL_TRACE, NullTrace, QueryTrace, TRACE_SCHEMA_VERSION,
                    current_trace, qerror)

__all__ = [
    "Counter", "DEFAULT_BUCKETS", "DeviceProfile", "ENGINE_REQUIRED_KEYS",
    "ENGINE_STATS_SOURCE_KEYS",
    "ExplainResult", "Gauge", "Histogram", "KERNEL_FAMILIES",
    "MetricsRegistry", "NULL_PROFILE", "NULL_TRACE", "NullProfile",
    "NullTrace", "PROFILE_SCHEMA_VERSION", "QueryTrace",
    "TRACE_SCHEMA_VERSION", "current_profile", "current_trace",
    "explain_analyze", "get_registry", "normalize_engine_stats", "qerror",
]
