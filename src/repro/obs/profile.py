"""Device-side profiling: jit compiles, kernel walls, memory watermarks.

:class:`~repro.obs.trace.QueryTrace` (PR 8) answers *what* a query did
per GAO level — est-vs-observed cardinality, kernel-path mix, scheduler
events.  :class:`DeviceProfile` answers *why a level got slow* one layer
down:

* **jit** — compile vs cached-call counts and compile wall seconds,
  harvested at the engine's two dispatch sites (the
  ``VLFTJ._final_level_call`` AOT cache and the interior chunked
  ``_expand_level`` dispatches);
* **kernels** — a per-family host-wall breakdown (``intersect``,
  ``intersect_bitset``, ``segment_outer``): each dispatch the engine
  already performs is bracketed by two ``perf_counter`` reads, so the
  breakdown costs two clock reads per chunk and **zero extra device
  dispatches** — the same discipline as tracing, guarded by
  ``tests/test_profile.py``;
* **memory** — live-buffer watermarks sampled at GAO level boundaries
  (``jax.live_arrays()`` metadata only — ``nbytes`` is shape×dtype
  arithmetic, no device sync), plus the backend allocator's
  ``peak_bytes_in_use`` when the platform exposes ``memory_stats()``
  (CPU typically does not; the field stays ``None``);
* **workers** — per-worker drain seconds from the dist pool;
* **compile events** — every AOT compile with wall seconds and an
  ``attribution`` label the quantum scheduler sets per slice
  (``sched-3/q2``), so a compile storm is attributable to the job and
  quantum that triggered it.

Off by default: every hook is ``prof = current_profile(); if prof is
None: <nothing>``.  Activation mirrors tracing — a contextvar, so the
scheduler, pool, and cursor find the profile without signature
threading.  :meth:`DeviceProfile.publish` pushes the harvest into a
:class:`~repro.obs.trace.QueryTrace` (as spans) and a
:class:`~repro.obs.metrics.MetricsRegistry` (as histograms/counters) so
one export surface carries all three layers.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
import time

#: schema version stamped into every profile dict export.
PROFILE_SCHEMA_VERSION = 1

#: kernel families the wall breakdown buckets dispatches into.
KERNEL_FAMILIES = ("intersect", "intersect_bitset", "segment_outer")

_ACTIVE: contextvars.ContextVar["DeviceProfile | None"] = \
    contextvars.ContextVar("repro_obs_active_profile", default=None)


def current_profile() -> "DeviceProfile | None":
    """The profile active in this context, or None (profiling disabled)."""
    return _ACTIVE.get()


class DeviceProfile:
    """One query execution's device-side resource accounting.

    All recording methods are plain host dict arithmetic; the only
    recorder that looks at device state is :meth:`sample_memory`, and it
    reads array *metadata* (``nbytes``) — no transfer, no sync.

    Attributes:
        jit: ``{"compiles", "calls", "compile_wall_s"}`` — ``calls``
            counts every jitted/AOT kernel dispatch; ``compiles`` counts
            observable (AOT) compilations and ``compile_wall_s`` their
            summed wall seconds.  Interior first-call trace+compile time
            is not separable host-side; it shows up in that dispatch's
            kernel wall instead.
        kernels: family -> ``{"calls", "wall_s"}`` host-wall breakdown.
        memory: live-buffer watermarks — ``peak_live_bytes`` /
            ``peak_live_buffers`` over the samples taken at level
            boundaries, ``samples``, and ``device_peak_bytes`` (backend
            allocator peak, None when unavailable).
        compile_events: ``[{"key", "wall_s", "attribution", "t"}]``.
        worker_spans: ``[{"worker", "backend", "dur_s"}]`` pool drains.
    """

    enabled = True

    def __init__(self, query_name: str = "", engine: str = ""):
        self.meta = {"query": query_name, "engine": engine,
                     "schema": PROFILE_SCHEMA_VERSION}
        self.jit = {"compiles": 0, "calls": 0, "compile_wall_s": 0.0}
        self.kernels: dict[str, dict] = {}
        self.memory = {"samples": 0, "peak_live_bytes": 0,
                       "peak_live_buffers": 0, "device_peak_bytes": None}
        self.compile_events: list[dict] = []
        self.worker_spans: list[dict] = []
        self.attribution: str | None = None
        self._t0 = time.perf_counter()

    # -- recording -----------------------------------------------------------
    def _now(self) -> float:
        return round(time.perf_counter() - self._t0, 6)

    def set_meta(self, **kw) -> None:
        self.meta.update(kw)

    def record_jit_call(self, n: int = 1) -> None:
        self.jit["calls"] += n

    def record_compile(self, key: str, wall_s: float) -> None:
        """One observable (AOT) compilation: ``key`` names the compiled
        geometry, the event carries the current :attr:`attribution`."""
        self.jit["compiles"] += 1
        self.jit["compile_wall_s"] += float(wall_s)
        self.compile_events.append(
            {"key": str(key), "wall_s": round(float(wall_s), 6),
             "attribution": self.attribution, "t": self._now()})

    def record_kernel(self, family: str, wall_s: float,
                      calls: int = 1) -> None:
        rec = self.kernels.setdefault(family, {"calls": 0, "wall_s": 0.0})
        rec["calls"] += calls
        rec["wall_s"] += float(wall_s)

    def record_worker(self, worker: int, backend: str,
                      dur_s: float) -> None:
        self.worker_spans.append({"worker": int(worker), "backend": backend,
                                  "dur_s": round(float(dur_s), 6)})

    def sample_memory(self) -> None:
        """Live-buffer watermark sample (GAO level boundaries).

        ``jax.live_arrays()`` enumerates the client's live buffers;
        summing ``nbytes`` is pure metadata arithmetic.  The backend
        allocator's ``memory_stats()`` (GPU/TPU) is consulted when
        present — on CPU it is absent/None and the field stays None.
        """
        try:
            import jax
            live = jax.live_arrays()
        except Exception:       # pragma: no cover - jax is a core dep
            return
        nbytes = 0
        for a in live:
            try:
                nbytes += int(a.nbytes)
            except Exception:   # deleted between enumeration and read
                continue
        mem = self.memory
        mem["samples"] += 1
        mem["peak_live_bytes"] = max(mem["peak_live_bytes"], nbytes)
        mem["peak_live_buffers"] = max(mem["peak_live_buffers"], len(live))
        try:
            dev = jax.devices()[0]
            stats = dev.memory_stats() if hasattr(dev, "memory_stats") \
                else None
        except Exception:
            stats = None
        if stats:
            peak = stats.get("peak_bytes_in_use")
            if peak is not None:
                prev = mem["device_peak_bytes"] or 0
                mem["device_peak_bytes"] = max(prev, int(peak))

    # -- context -------------------------------------------------------------
    @contextlib.contextmanager
    def activate(self):
        """Install as :func:`current_profile` for the block's duration."""
        token = _ACTIVE.set(self)
        try:
            yield self
        finally:
            _ACTIVE.reset(token)

    @contextlib.contextmanager
    def attribute(self, label: str):
        """Label compiles recorded in the block (scheduler: per-quantum
        ``sched-<job>/q<k>`` attribution).  Nests; restores on exit."""
        prev = self.attribution
        self.attribution = label
        try:
            yield self
        finally:
            self.attribution = prev

    # -- export --------------------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-serializable snapshot of the whole profile."""
        return {"meta": dict(self.meta),
                "jit": {**self.jit,
                        "compile_wall_s": round(self.jit["compile_wall_s"],
                                                6)},
                "kernels": {f: {"calls": r["calls"],
                                "wall_s": round(r["wall_s"], 6)}
                            for f, r in sorted(self.kernels.items())},
                "memory": dict(self.memory),
                "compile_events": list(self.compile_events),
                "worker_spans": list(self.worker_spans)}

    def publish(self, trace=None, registry=None) -> None:
        """Push the harvest into the other observability surfaces.

        ``trace``: one ``profile/jit`` span (compile counts + wall) and
        one ``profile/kernel/<family>`` span per family, plus the memory
        watermark on the trace summary.  ``registry``: histograms
        ``profile_compile_seconds`` and ``profile_kernel_seconds{
        family=...}``, counter ``profile_jit_calls``, gauge
        ``profile_peak_live_bytes``.
        """
        if trace is not None:
            trace.spans.append({
                "name": "profile/jit", "t": 0.0,
                "compiles": self.jit["compiles"],
                "calls": self.jit["calls"],
                "dur_s": round(self.jit["compile_wall_s"], 6)})
            for fam, rec in sorted(self.kernels.items()):
                trace.spans.append({
                    "name": f"profile/kernel/{fam}", "t": 0.0,
                    "calls": rec["calls"],
                    "dur_s": round(rec["wall_s"], 6)})
            if self.memory["samples"]:
                trace.summary.setdefault(
                    "peak_live_bytes", self.memory["peak_live_bytes"])
        if registry is not None:
            for ev in self.compile_events:
                registry.histogram("profile_compile_seconds").observe(
                    ev["wall_s"])
            for fam, rec in self.kernels.items():
                registry.histogram("profile_kernel_seconds",
                                   family=fam).observe(rec["wall_s"])
            if self.jit["calls"]:
                registry.counter("profile_jit_calls").inc(self.jit["calls"])
            if self.memory["samples"]:
                g = registry.gauge("profile_peak_live_bytes")
                g.set(max(g.value, self.memory["peak_live_bytes"]))

    # -- derived views -------------------------------------------------------
    def kernel_wall_s(self, family: str | None = None) -> float:
        if family is not None:
            return self.kernels.get(family, {}).get("wall_s", 0.0)
        return math.fsum(r["wall_s"] for r in self.kernels.values())


class NullProfile:
    """Disabled profile: every recorder is a no-op.  Never installed as
    the context's profile — ``current_profile() is None`` is the normal
    disabled-path check — but code handed a profile directly can take
    this instead of branching on None."""

    enabled = False
    attribution = None

    def set_meta(self, **kw):
        pass

    def record_jit_call(self, n=1):
        pass

    def record_compile(self, key, wall_s):
        pass

    def record_kernel(self, family, wall_s, calls=1):
        pass

    def record_worker(self, worker, backend, dur_s):
        pass

    def sample_memory(self):
        pass

    @contextlib.contextmanager
    def activate(self):
        yield self

    @contextlib.contextmanager
    def attribute(self, label):
        yield self

    def publish(self, trace=None, registry=None):
        pass

    def to_dict(self):
        return {}


NULL_PROFILE = NullProfile()
