"""The unified ``QueryResult.stats`` engine namespace.

Each engine historically grew its own counter names (``ll_calls``,
``bitset_rows``, ``spmvs``, ``probes``, …).  Those raw names survive —
benches and tests key on them — but every engine path now *also* emits
one documented core schema, produced by :func:`normalize_engine_stats`
and carried under ``stats["engine"]`` in server results:

==================  =====================================================
key                 meaning
==================  =====================================================
``name``            the physical operator that ran ('vlftj', …)
``rows_expanded``   partial bindings fed into level expansion (the
                    quantum scheduler's work unit)
``frontier_peak``   largest materialized frontier (rows)
``kernel_dispatches``  device kernel launches (vlftj ``chunks``;
                    host-only engines report 0)
``jit_calls``       final-level executable invocations (``ll_calls``)
``jit_compiles``    final-level AOT compiles (``ll_compiles``) — calls
                    minus compiles is the jit-cache hit count
``level_rows``      GAO level -> observed frontier cardinality (the
                    "obs" side of per-level Q-error)
``level_wall_s``    GAO level -> host wall seconds spent in the level
``level_paths``     GAO level -> kernel path row tallies
                    ({'bitset'|'tile'|'bsearch': rows})
``raw``             the engine's native counters, untouched
==================  =====================================================

``tests/test_obs.py`` asserts every engine path emits every
``ENGINE_REQUIRED_KEYS`` entry; the full catalog (including the
scheduler / dist / cursor groups) is ``docs/OBSERVABILITY.md``.
"""
from __future__ import annotations

#: every normalized engine-stats dict carries exactly these keys.
ENGINE_REQUIRED_KEYS = ("name", "rows_expanded", "frontier_peak",
                        "kernel_dispatches", "jit_calls", "jit_compiles",
                        "level_rows", "level_wall_s", "level_paths", "raw")

#: the schema keys an engine must *source* natively (everything else
#: has a total default in :func:`normalize_engine_stats`): without
#: ``rows_expanded`` the quantum scheduler cannot meter the engine, and
#: without ``level_rows`` per-level Q-error has no "obs" side.  The
#: ``engine-stats-keys`` lint pass (``tools/lint_repro.py``) requires
#: both in every engine's ``self.stats`` dict literal.
ENGINE_STATS_SOURCE_KEYS = ("rows_expanded", "level_rows")


def normalize_engine_stats(name: str, stats: dict | None) -> dict:
    """Project an engine's native ``stats`` dict onto the unified schema.

    Total: every engine (including one with no native stats at all) maps
    to a dict with all :data:`ENGINE_REQUIRED_KEYS`; native counters
    survive under ``raw``.
    """
    raw = dict(stats or {})
    return {
        "name": name,
        "rows_expanded": int(raw.get("rows_expanded", 0)),
        "frontier_peak": int(raw.get("frontier_peak",
                                     raw.get("max_intermediate", 0))),
        "kernel_dispatches": int(raw.get("chunks", 0)),
        "jit_calls": int(raw.get("ll_calls", 0)),
        "jit_compiles": int(raw.get("ll_compiles", 0)),
        "level_rows": {int(k): int(v)
                       for k, v in (raw.get("level_rows") or {}).items()},
        "level_wall_s": {int(k): float(v)
                         for k, v in (raw.get("level_wall_s")
                                      or {}).items()},
        "level_paths": {int(k): dict(v)
                        for k, v in (raw.get("level_paths")
                                     or {}).items()},
        "raw": raw,
    }
