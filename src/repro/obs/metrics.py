"""Process-wide metrics registry: counters, gauges, histograms with labels.

The repo's subsystems each kept private ad-hoc tallies — the plan cache
its hit/miss integers, the cursor registry its closed-reason dict, the
quantum scheduler its quanta/restart counters, the worker pool its
makespans — with no common schema or export.  :class:`MetricsRegistry`
absorbs them behind one API in the Prometheus mold:

* :class:`Counter` — monotonically increasing (``inc``);
* :class:`Gauge` — last-write-wins level (``set``/``inc``/``dec``);
* :class:`Histogram` — bucketed distribution (``observe``) with
  ``sum``/``count``/``min``/``max``, for latencies and makespans.

Metrics are identified by ``(name, labels)`` — ``registry.counter(
"cursor_closed", reason="evicted")`` and ``reason="exhausted"`` are two
series of one metric family.  Everything is plain host-side dict
arithmetic: instrumentation adds no device work, and a hot loop that
increments a pre-bound handle pays one integer add.

``get_registry()`` returns the process-wide default registry (what
:meth:`repro.serve.QueryServer.metrics` snapshots); construct private
registries for isolation (tests, per-deployment export).  The full
metrics catalog lives in ``docs/OBSERVABILITY.md``.
"""
from __future__ import annotations

import threading

#: default histogram buckets (seconds-flavoured, but unit-agnostic):
#: powers of ~4 from 1ms to ~1min plus +inf.
DEFAULT_BUCKETS = (0.001, 0.004, 0.016, 0.064, 0.256, 1.024, 4.096,
                   16.384, 65.536, float("inf"))


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Counter:
    """Monotonic counter.  ``inc`` by any non-negative amount."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins level (queue depths, open cursors, bytes parked)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def dec(self, n=1) -> None:
        self.value -= n

    def snapshot(self):
        return self.value


class Histogram:
    """Bucketed distribution with cumulative bucket counts.

    ``snapshot()`` returns ``{"count", "sum", "min", "max", "buckets"}``
    where ``buckets`` maps each upper bound to the cumulative count of
    observations ``<=`` it (the Prometheus convention, so series diff
    cleanly across scrapes).
    """

    __slots__ = ("name", "labels", "buckets", "counts", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, labels: dict,
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.buckets = tuple(buckets)
        if not self.buckets or self.buckets[-1] != float("inf"):
            self.buckets = self.buckets + (float("inf"),)
        self.counts = [0] * len(self.buckets)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1

    @staticmethod
    def _le(ub: float) -> str:
        """Prometheus ``le`` label text for an upper bound — explicit
        ``"+Inf"`` for the terminal bucket (scrapers require it; a float
        ``inf`` key would also render as non-standard JSON)."""
        return "+Inf" if ub == float("inf") else f"{ub:g}"

    def snapshot(self):
        """JSON-safe summary.  ``buckets`` maps the ``le`` label text
        (``"0.064"``, …, always ending in ``"+Inf"``) to the cumulative
        count of observations ``<=`` that bound; the ``+Inf`` bucket
        always equals ``count`` (the cumulative invariant —
        ``tests/test_obs.py``)."""
        return {"count": self.count, "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "buckets": {self._le(ub): c
                            for ub, c in zip(self.buckets, self.counts)}}


class MetricsRegistry:
    """Thread-safe registry of labelled metrics with one snapshot API.

    ``counter``/``gauge``/``histogram`` return the live handle for the
    ``(name, labels)`` series, creating it on first use — bind the
    handle once outside a loop and ``inc`` inside it.  ``snapshot()``
    renders every series as ``"name{k=v,...}" -> value`` (histograms:
    their summary dict); ``reset()`` forgets everything (tests).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (cls.__name__, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, labels, **kw)
                self._metrics[key] = m
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, buckets: tuple[float, ...] | None = None,
                  **labels) -> Histogram:
        kw = {} if buckets is None else {"buckets": tuple(buckets)}
        return self._get(Histogram, name, labels, **kw)

    @staticmethod
    def _series_name(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
        return f"{name}{{{inner}}}"

    def snapshot(self) -> dict:
        """Point-in-time flat view of every series — JSON-serializable.

        Counters and gauges render as ``"name{k=v,...}" -> value``;
        histograms flatten Prometheus-style into ``name_count`` /
        ``name_sum`` / ``name_min`` / ``name_max`` plus cumulative
        ``name_bucket{le=...}`` series (``le=+Inf`` always present).
        """
        out: dict = {}
        with self._lock:
            for m in self._metrics.values():
                if not isinstance(m, Histogram):
                    out[self._series_name(m.name, m.labels)] = m.snapshot()
                    continue
                s = m.snapshot()
                for stat in ("count", "sum", "min", "max"):
                    out[self._series_name(f"{m.name}_{stat}",
                                          m.labels)] = s[stat]
                for le, c in s["buckets"].items():
                    out[self._series_name(f"{m.name}_bucket",
                                          {**m.labels, "le": le})] = c
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def __len__(self) -> int:
        return len(self._metrics)


#: the process-wide default registry (``QueryServer.metrics()`` snapshots
#: this one unless the server was built with a private registry).
_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _DEFAULT
