"""Backward-expansion enumeration for the message-passing engines.

The counting engines (``core/yannakakis.py``, the tree half of
``core/hybrid.py``) collapse sub-pattern bindings into per-node tallies
on the way *up* the variable tree — which is exactly why they could only
count.  Enumeration runs the passes backward ("Old Techniques for New
Join Algorithms": Yannakakis' downward semijoin pass gives dangling-free
enumeration for the acyclic parts):

* **yannakakis** — the upward messages, re-run as boolean semijoins
  (``CountingYannakakis.semijoin_reduce``), leave per-variable active
  sets in which *every* value extends to a full output tuple.  The
  reduced domains are attached to the query as unary predicates and a
  guided vectorized-LFTJ descent materializes the tuples — every
  frontier row survives to the end, so the expansion does no wasted
  work (the classic zero-dangling-intermediates property).

* **hybrid** — the tree part's root message seeds the cyclic core as in
  counting, the core is enumerated by vectorized LFTJ, and the tree
  bindings behind each attachment value are expanded backward with the
  yannakakis path above, restricted to the attachment values the core
  actually produced.  Core tuples and tree expansions are then glued by
  a segmented product per attachment value — the factorized structure
  (tree bindings depend on the core only through the attachment) is
  what makes the join linear in the output.
"""
from __future__ import annotations

import numpy as np

from ..core.device_graph import GraphDB
from ..core.plan import JoinPlan
from ..core.query import Atom, Query
from ..core.vlftj import VLFTJ
from ..core.yannakakis import CountingYannakakis


def _restricted(query: Query, gdb: GraphDB,
                active: dict[str, np.ndarray],
                tag: str) -> tuple[Query, GraphDB]:
    """Attach per-variable active-value sets as unary predicates.

    The derived :class:`GraphDB` shares the parent's CSR and cached
    device arrays (bitmaps for the new predicates are built lazily on a
    copied cache, so the parent is never polluted)."""
    unary = dict(gdb.unary)
    atoms = list(query.atoms)
    for var, ids in active.items():
        name = f"__{tag}_{var}"
        unary[name] = np.asarray(ids)
        atoms.append(Atom(name, (var,)))
    q2 = Query(tuple(atoms), query.filters, f"{query.name}+{tag}")
    return q2, GraphDB(gdb.csr, unary, _dev=dict(gdb._dev))


def yannakakis_rows(engine: CountingYannakakis
                    ) -> tuple[np.ndarray, tuple[str, ...]]:
    """Backward-expansion enumeration: ``(rows, columns)`` with rows
    int64, lex-sorted, columns = ``engine.gao`` (full variable cover)."""
    gao = engine.gao
    active = {v: np.flatnonzero(m)
              for v, m in engine.semijoin_reduce().items()}
    if any(ids.shape[0] == 0 for ids in active.values()):
        return np.zeros((0, len(gao)), dtype=np.int64), gao
    q2, gdb2 = _restricted(engine.query, engine.gdb, active, "act")
    plan2 = JoinPlan(query=q2, engine="vlftj", gao=gao)
    return VLFTJ(q2, gdb2, plan=plan2).enumerate(), gao


def _group_starts(sorted_keys: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(unique, start, count) over a sorted 1-D key array."""
    if sorted_keys.shape[0] == 0:
        z = np.zeros(0, dtype=np.int64)
        return sorted_keys, z, z
    change = np.empty(sorted_keys.shape[0], dtype=bool)
    change[0] = True
    change[1:] = sorted_keys[1:] != sorted_keys[:-1]
    start = np.flatnonzero(change).astype(np.int64)
    count = np.diff(np.append(start, sorted_keys.shape[0]))
    return sorted_keys[start], start, count


def hybrid_rows(hj) -> tuple[np.ndarray, tuple[str, ...]]:
    """Enumerate a :class:`~repro.core.hybrid.HybridJoin`'s full output:
    ``(rows, columns)``, rows int64 (unsorted — callers order), columns =
    core GAO followed by the tree variables (attachment deduplicated)."""
    plan = hj.join_plan
    d = plan.decomposition
    if d is None:
        # unsupported shape: plain vectorized LFTJ, like count()
        if hj._core_plan is not None:
            ex = VLFTJ(hj.query, hj.gdb, plan=hj._core_plan, **hj.vlftj_kw)
        else:
            ex = VLFTJ(hj.query, hj.gdb, **hj.vlftj_kw)
        return ex.enumerate(), ex.gao
    # 1) tree part: attachment values with at least one tree expansion
    cy = CountingYannakakis(d.tree_query, hj.gdb, root=d.attachment)
    msg = np.asarray(cy.message_to_root(d.attachment))
    seeds = np.flatnonzero(msg > 0).astype(np.int32)
    tree_vars_rest: tuple[str, ...] = tuple(
        v for v in d.tree_query.variables if v != d.attachment)
    columns = d.core_gao + tree_vars_rest
    if seeds.shape[0] == 0:
        return np.zeros((0, len(columns)), dtype=np.int64), columns
    # 2) cyclic core, seeded (attachment is the first core-GAO variable)
    core = VLFTJ(d.core_query, hj.gdb, plan=hj._core_plan, **hj.vlftj_kw)
    core_rows = core.enumerate(seeds=seeds)
    if core_rows.shape[0] == 0:
        return np.zeros((0, len(columns)), dtype=np.int64), columns
    # 3) tree bindings behind each attachment value the core produced
    att_vals = np.unique(core_rows[:, 0])
    tq2, tgdb = _restricted(d.tree_query, hj.gdb,
                            {d.attachment: att_vals}, "core")
    tree_rows, tree_gao = yannakakis_rows(
        CountingYannakakis(tq2, tgdb, root=d.attachment))
    # 4) segmented product per attachment value
    aj = tree_gao.index(d.attachment)
    tr = tree_rows[np.argsort(tree_rows[:, aj], kind="stable")]
    uvals, start, count = _group_starts(tr[:, aj])
    gi = np.searchsorted(uvals, core_rows[:, 0])
    sizes = count[gi]
    total = int(sizes.sum())
    reps = np.repeat(np.arange(core_rows.shape[0]), sizes)
    offs = np.repeat(np.cumsum(sizes) - sizes, sizes)
    within = np.arange(total) - offs
    tidx = start[gi][reps] + within
    rest_cols = [c for c, v in enumerate(tree_gao) if v != d.attachment]
    rest_order = [tree_gao[c] for c in rest_cols]
    perm = [rest_order.index(v) for v in tree_vars_rest]
    rows = np.concatenate(
        [core_rows[reps], tr[tidx][:, rest_cols][:, perm]], axis=1)
    return rows, columns
