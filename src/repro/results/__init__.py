"""Plan-aware result enumeration: flat/factorized result sets, streaming
cursors, and backward expansion for the counting engines.

Entry points: ``repro.core.engine.enumerate`` (unified, all six engines)
and ``repro.core.engine.stream`` (page cursor); the query server's
``QueryRequest.limit/cursor`` pagination and the dist layer's
``PartitionedJoin.enumerate`` build on the same pieces.
"""
from .backward import hybrid_rows, yannakakis_rows
from .cursor import ResultCursor
from .factorize import factorize_vlftj
from .result_set import FactorizedResult, FLevel, ResultSet, lex_sorted

__all__ = [
    "FactorizedResult", "FLevel", "ResultSet", "ResultCursor",
    "factorize_vlftj", "hybrid_rows", "yannakakis_rows", "lex_sorted",
]
