"""Result representations: flat tuples and factorized (trie) form.

The paper's pitch is that a WCOJ-powered RDBMS keeps the *relational*
interface — queries return tuples, not just counts.  EmptyHeaded-style
engines go one step further and emit results in compressed/factorized
form: the output of a join along a GAO is naturally a trie (shared
prefixes = union nodes, path concatenation = product nodes), and keeping
it factorized avoids materializing the cross-products the final levels
would otherwise flatten.

Two concrete representations share one small API
(``count`` / ``expand`` / ``project`` / ``nbytes``):

* :class:`ResultSet` — flat ``(n, k)`` int64 rows, columns named by
  ``vars``, rows in lexicographic order.  The canonical exchange format
  every engine's ``enumerate()`` agrees on.
* :class:`FactorizedResult` — one :class:`FLevel` per GAO position: a
  union of values per parent entry (``values[i]`` extends
  ``parent[i]``-th entry of the previous level).  Leaves are output
  tuples, so ``count()`` is O(1); ``expand()`` walks parent chains with
  vectorized gathers and returns the flat rows in lex order; a
  GAO-prefix ``project()`` is a trie truncation (already deduplicated,
  no expansion).  Storage is 2 cells per trie node versus ``k`` cells
  per flat row — the per-level union/product compression.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def lex_sorted(rows: np.ndarray) -> np.ndarray:
    """Rows sorted lexicographically by columns left-to-right."""
    rows = np.asarray(rows)
    if rows.shape[0] <= 1:
        return rows
    return rows[np.lexsort(rows.T[::-1])]


def _dedup_sorted(rows: np.ndarray) -> np.ndarray:
    """Distinct rows of a lex-sorted array."""
    if rows.shape[0] <= 1:
        return rows
    keep = np.empty(rows.shape[0], dtype=bool)
    keep[0] = True
    keep[1:] = (rows[1:] != rows[:-1]).any(axis=1)
    return rows[keep]


@dataclass(frozen=True)
class ResultSet:
    """Flat join output: ``rows`` (n, len(vars)) int64, lex-sorted."""

    vars: tuple[str, ...]
    rows: np.ndarray

    @classmethod
    def from_rows(cls, vars_: tuple[str, ...], rows: np.ndarray,
                  sort: bool = True) -> "ResultSet":
        rows = np.asarray(rows, dtype=np.int64).reshape(-1, len(vars_))
        return cls(tuple(vars_), lex_sorted(rows) if sort else rows)

    def count(self) -> int:
        return int(self.rows.shape[0])

    def __len__(self) -> int:
        return self.count()

    def expand(self) -> np.ndarray:
        """Flat rows (already flat — API parity with FactorizedResult)."""
        return self.rows

    def project(self, vars_: tuple[str, ...]) -> "ResultSet":
        """Distinct sub-tuples over ``vars_`` (lex-sorted)."""
        cols = [self.vars.index(v) for v in vars_]
        return ResultSet(tuple(vars_),
                         _dedup_sorted(lex_sorted(self.rows[:, cols])))

    def reorder(self, vars_: tuple[str, ...]) -> "ResultSet":
        """Same tuples with columns permuted to ``vars_`` and re-sorted."""
        if tuple(vars_) == self.vars:
            return self
        if set(vars_) != set(self.vars):
            raise ValueError(f"cannot reorder {self.vars} to {vars_}")
        cols = [self.vars.index(v) for v in vars_]
        return ResultSet(tuple(vars_), lex_sorted(self.rows[:, cols]))

    @property
    def nbytes(self) -> int:
        return int(self.rows.nbytes)


@dataclass(frozen=True)
class FLevel:
    """One trie level: ``values[i]`` extends entry ``parent[i]`` of the
    previous level (level 0 parents are all zero and unused)."""

    values: np.ndarray  # (n_i,) int64
    parent: np.ndarray  # (n_i,) int64


@dataclass(frozen=True)
class FactorizedResult:
    """Trie-factorized join output along a GAO.

    The EmptyHeaded-style compressed representation: level ``j`` holds
    the distinct bindings of ``vars[j]`` *per parent path*, each entry
    pointing at its parent in level ``j-1`` (:class:`FLevel`).  A
    high-fanout join whose flat output is ``count() × k`` int64 cells
    stores only the union-node arrays — ``nbytes`` vs a flat
    ``ResultSet`` is the compression ratio ``BENCH_enumerate.json``
    tracks.

    Attributes:
        vars: column order — always the plan's GAO (trie order *is*
            lex order, so ``expand()`` needs no sort).
        levels: one :class:`FLevel` per variable; ``levels[-1].values``
            has exactly ``count()`` entries (one leaf per tuple).

    Construction: ``results.factorize_vlftj(executor)`` builds the trie
    natively from the penultimate frontier + final-level extension
    segments without materializing the flat cross-product;
    :meth:`from_rows` trie-compresses any engine's flat rows.  The
    planner costs flat-vs-factorized emission and stamps the cheaper
    mode into ``JoinPlan.output_mode``, which ``core.engine.enumerate``
    honours.

    Example::

        fr = engine.enumerate(q, gdb, mode="factorized")
        fr.count()                  # O(1), no expansion
        fr.project(fr.vars[:2])     # GAO-prefix: trie truncation
        rows = fr.expand()          # flat (count, k) lex-ordered rows
    """

    vars: tuple[str, ...]
    levels: tuple[FLevel, ...]

    @classmethod
    def from_rows(cls, vars_: tuple[str, ...], rows: np.ndarray,
                  sort: bool = True) -> "FactorizedResult":
        """Trie-compress flat rows (any engine's output qualifies)."""
        k = len(vars_)
        rows = np.asarray(rows, dtype=np.int64).reshape(-1, k)
        if sort:
            rows = lex_sorted(rows)
        n = rows.shape[0]
        levels: list[FLevel] = []
        change = np.zeros(n, dtype=bool)
        prev_idx = np.zeros(n, dtype=np.int64)
        for j in range(k):
            cj = np.empty(n, dtype=bool)
            if n:
                cj[0] = True
                cj[1:] = rows[1:, j] != rows[:-1, j]
            change = cj if j == 0 else (change | cj)
            sel = np.flatnonzero(change)
            parent = (prev_idx[sel] if j
                      else np.zeros(sel.shape[0], dtype=np.int64))
            levels.append(FLevel(rows[sel, j].copy(), parent))
            prev_idx = np.cumsum(change) - 1
        return cls(tuple(vars_), tuple(levels))

    def count(self) -> int:
        """Output cardinality — one leaf per tuple, so O(1)."""
        return int(self.levels[-1].values.shape[0])

    def __len__(self) -> int:
        return self.count()

    def _chain(self, level: int) -> np.ndarray:
        """Expand levels[0..level] by walking parent chains upward."""
        m = self.levels[level].values.shape[0]
        out = np.empty((m, level + 1), dtype=np.int64)
        idx = np.arange(m)
        for j in range(level, -1, -1):
            lvl = self.levels[j]
            out[:, j] = lvl.values[idx]
            idx = lvl.parent[idx]
        return out

    def expand(self) -> np.ndarray:
        """Flat (count, k) rows in lex order (trie order is lex order)."""
        return self._chain(len(self.levels) - 1)

    def project(self, vars_: tuple[str, ...]) -> ResultSet:
        """Distinct sub-tuples; a GAO-prefix projection is a trie
        truncation — no expansion, already deduplicated."""
        vars_ = tuple(vars_)
        if vars_ == self.vars[: len(vars_)]:
            return ResultSet(vars_, self._chain(len(vars_) - 1))
        cols = [self.vars.index(v) for v in vars_]
        return ResultSet(vars_,
                         _dedup_sorted(lex_sorted(self.expand()[:, cols])))

    @property
    def nbytes(self) -> int:
        return int(sum(lv.values.nbytes + lv.parent.nbytes
                       for lv in self.levels))
