"""Native factorized emission from the vectorized LFTJ.

``FactorizedResult.from_rows`` can trie-compress any engine's flat
output, but that still pays for the flat cross-product first.  This
builder never materializes it: the *penultimate* frontier (every prefix
binding) is trie-compressed directly, and the final GAO level's
surviving extensions — computed chunk-by-chunk with
``VLFTJ.last_level_extensions`` — become the leaf level's
``(values, parent)`` segments.  Peak memory is the penultimate frontier
plus one expansion chunk, the same bound the streaming cursor gives,
while the result supports O(1) ``count()`` and prefix ``project()``
without ever expanding.
"""
from __future__ import annotations

import numpy as np

from ..core.vlftj import VLFTJ
from .result_set import FactorizedResult, FLevel


def factorize_vlftj(ex: VLFTJ) -> FactorizedResult:
    """Factorized output of a vectorized-LFTJ plan, columns = its GAO."""
    k = len(ex.plan)
    if k == 1:
        vals = np.sort(ex._domain_values(ex.plan[0]).astype(np.int64))
        return FactorizedResult(
            ex.gao, (FLevel(vals, np.zeros(vals.shape[0], np.int64)),))
    frontier = np.asarray(
        ex._run(count_only=False, max_levels=k - 1), dtype=np.int64)
    if frontier.shape[0] == 0:
        empty = np.zeros(0, dtype=np.int64)
        return FactorizedResult(
            ex.gao, tuple(FLevel(empty, empty) for _ in range(k)))
    frontier = frontier[np.lexsort(frontier.T[::-1])]
    counts = np.empty(frontier.shape[0], dtype=np.int64)
    tails: list[np.ndarray] = []
    cf = ex.chunk_rows
    for s in range(0, frontier.shape[0], cf):
        chunk = frontier[s:s + cf]
        real = chunk.shape[0]
        if real < cf:
            chunk = np.pad(chunk, ((0, cf - real), (0, 0)))
        valid = np.zeros(cf, dtype=bool)
        valid[:real] = True
        c, vals = ex.last_level_extensions(chunk.astype(np.int32), valid)
        counts[s:s + real] = c[:real]
        tails.append(vals)
    # drop prefixes with no surviving extension, so every trie path ends
    # in a leaf and prefix project() never reports dangling bindings
    live = counts > 0
    frontier, counts = frontier[live], counts[live]
    prefix = FactorizedResult.from_rows(ex.gao[:-1], frontier, sort=False)
    # frontier rows are distinct join results, so the last prefix level
    # has exactly one entry per frontier row — tails parent straight in
    leaf_vals = (np.concatenate(tails) if tails
                 else np.zeros(0, dtype=np.int64))
    parent = np.repeat(np.arange(frontier.shape[0], dtype=np.int64),
                       counts)
    return FactorizedResult(ex.gao,
                            prefix.levels + (FLevel(leaf_vals, parent),))
