"""Chunked, bounded-memory streaming of join output in fixed-size pages.

Flat enumeration of a worst-case-optimal join materializes the full
cross-product of the final GAO level — the one thing the counting path
(Idea 8) carefully avoids.  :class:`ResultCursor` keeps that property
for enumeration: it materializes only the *penultimate* frontier, sorts
it lexicographically once, then re-enters the final VLFTJ level
(``VLFTJ.last_level_extensions``) one frontier chunk at a time,
flattening each chunk with :func:`repro.kernels.segment_outer
.segment_expand` and handing out pages of ``page_rows`` rows.

Expansion chunks are sized by *measured* fanout: a first counting pass
(``VLFTJ.last_level_counts`` — the cheap Idea-8 path, run at the
executor's full chunk width) yields per-row extension counts, and chunk
boundaries are cut where cumulative counts cross ``page_rows``.  One
chunk therefore contributes at most ``max(width, page_rows)`` buffered
rows (a single row can emit up to ``width``), and pulling stops as soon
as a page is covered, so the tail buffer never exceeds ``page_rows +
max(width, page_rows)`` rows (``width`` = the executor's padded
candidate-tile width, a data constant) — tracked in
``stats['peak_buffer_rows']`` and asserted in the tests.  Both passes
pad to fixed geometries, so the executor's AOT-compiled final-level
cache (``VLFTJ._final_level_call``) serves every page with two compiles
total — no per-page jit dispatch, no re-trace.  A *dense* final level
(no bound edge neighbor — rare; GAO choice avoids it) has domain-sized
fanout instead, so it streams one frontier row at a time with its
extension run sliced to the page size, keeping the same bound.
Concatenating every page reproduces ``VLFTJ.enumerate`` exactly: the
frontier is lex-sorted, per-row extensions ascend, so pages arrive in
global lexicographic order.

``from_rows`` / ``from_blocks`` wrap already-materialized output (the
non-VLFTJ engines, the dist layer's merged part streams) in the same
page interface so the query server paginates every engine uniformly.
"""
from __future__ import annotations

import time
from typing import Iterable, Iterator

import numpy as np

from ..core.vlftj import VLFTJ
from ..kernels.segment_outer import segment_expand


def _segment_expand(prefix, counts, vals):
    """``segment_expand`` with the device-profile kernel-wall hook —
    two clock reads when a profile is active, nothing otherwise."""
    # lazy: repro.obs pulls in repro.core at package level
    from ..obs.profile import current_profile
    prof = current_profile()
    if prof is None:
        return segment_expand(prefix, counts, vals)
    t0 = time.perf_counter()
    out = segment_expand(prefix, counts, vals)
    prof.record_jit_call()
    prof.record_kernel("segment_outer", time.perf_counter() - t0)
    return out


class ResultCursor:
    """Page iterator over join output in the source's column order.

    ``take(n)`` returns the next ``n`` rows (fewer at the end, an empty
    ``(0, k)`` array once drained); ``next_page()`` returns
    ``take(page_rows)`` or ``None`` when exhausted; iteration yields
    pages.  ``vars`` names the columns; rows are int64 and arrive in
    lexicographic order.

    Args:
        executor: the :class:`~repro.core.vlftj.VLFTJ` instance to
            stream from (its plan fixes the column order ``vars``).
        page_rows: rows per page — also the tail-buffer bound knob (the
            buffer never exceeds ``page_rows + max(width, page_rows)``
            rows).
        seeds: optional pre-bindings of the first GAO variable.
        frontier: optional *resume* frontier — an ``(n, w)`` int32 array
            of partial bindings with ``w <= k - 1`` GAO columns already
            bound, e.g. a suspended
            :class:`~repro.serve.scheduler.PlanSnapshot`'s state.  The
            cursor continues the join from level ``w`` instead of level
            0; with ``w == k - 1`` (the penultimate frontier) no
            interior level runs at all and paging starts immediately.
        skip_rows: drop this many leading output rows before serving
            any — the other half of snapshot resume: a stream that
            already delivered ``n`` rows restarts with ``skip_rows=n``
            and continues row-for-row where it left off (the block
            stream is deterministic, so the skip is exact).

    Raises:
        ValueError: ``page_rows < 1``.
        repro.serve.scheduler.Preempted: propagated from the executor's
            plan ``level_callback`` when a quantum budget expires while
            the first ``take``/``next_page`` call is still building the
            penultimate frontier (interior levels run lazily on first
            pull).  The carried snapshot resumes via ``frontier=``.

    Example::

        cur = ResultCursor(VLFTJ(q, gdb, plan=plan), page_rows=512)
        first = cur.take(512)
        # ... suspend: remember cur.penultimate / cur.rows_emitted ...
        cur2 = ResultCursor(VLFTJ(q, gdb, plan=plan), page_rows=512,
                            frontier=cur.penultimate,
                            skip_rows=cur.rows_emitted)
        rest = [p for p in cur2]    # continues after `first`, exactly
    """

    def __init__(self, executor: VLFTJ, page_rows: int = 1024,
                 seeds: np.ndarray | None = None,
                 frontier: np.ndarray | None = None,
                 skip_rows: int = 0):
        if page_rows < 1:
            raise ValueError("page_rows must be >= 1")
        #: the live VLFTJ this cursor streams from (None for wrapped
        #: sources) — its ``stats`` carry the kernel counters a trace or
        #: metrics snapshot harvests after paging
        self.executor: VLFTJ | None = executor
        self.vars = executor.gao
        self.page_rows = page_rows
        self.stats = {"pages": 0, "rows": 0, "chunks": 0, "count_chunks": 0,
                      "peak_buffer_rows": 0, "frontier_rows": 0}
        self._k = len(executor.gao)
        self._buf: list[np.ndarray] = []
        self._buffered = 0
        self._drained = False
        self.exhausted = False
        #: the lex-sorted penultimate frontier, available once the first
        #: page is pulled (None for single-level plans and wrapped
        #: sources) — what a mid-paging suspension snapshots
        self.penultimate: np.ndarray | None = None
        self._skip = int(skip_rows)
        blocks = self._vlftj_blocks(executor, seeds, frontier)
        self._blocks: Iterator[np.ndarray] = (
            blocks if not self._skip else self._skipped(blocks))

    # -- alternate sources ---------------------------------------------------
    @classmethod
    def from_blocks(cls, columns: tuple[str, ...],
                    blocks: Iterable[np.ndarray],
                    page_rows: int = 1024) -> "ResultCursor":
        """Cursor over an iterable of row blocks already in lex order."""
        cur = cls.__new__(cls)
        cur.executor = None
        cur.vars = tuple(columns)
        cur.page_rows = page_rows
        cur.stats = {"pages": 0, "rows": 0, "chunks": 0, "count_chunks": 0,
                     "peak_buffer_rows": 0, "frontier_rows": 0}
        cur._k = len(cur.vars)
        cur._buf = []
        cur._buffered = 0
        cur._drained = False
        cur.exhausted = False
        cur.penultimate = None
        cur._skip = 0
        cur._blocks = iter(blocks)
        return cur

    @classmethod
    def from_rows(cls, columns: tuple[str, ...], rows: np.ndarray,
                  page_rows: int = 1024) -> "ResultCursor":
        """Cursor over one materialized (lex-sorted) row array."""
        rows = np.asarray(rows, dtype=np.int64).reshape(-1, len(columns))
        return cls.from_blocks(columns, [rows] if rows.shape[0] else [],
                               page_rows)

    # -- the VLFTJ streaming source ------------------------------------------
    def _skipped(self, blocks: Iterator[np.ndarray]) -> Iterator[np.ndarray]:
        """Drop the first ``skip_rows`` output rows (snapshot resume)."""
        left = self._skip
        for block in blocks:
            if left >= block.shape[0]:
                left -= block.shape[0]
                continue
            yield block[left:] if left else block
            left = 0

    def _vlftj_blocks(self, ex: VLFTJ, seeds: np.ndarray | None,
                      resume: np.ndarray | None = None
                      ) -> Iterator[np.ndarray]:
        k = len(ex.plan)
        if k == 1:
            vals = (np.asarray(seeds) if seeds is not None
                    else ex._domain_values(ex.plan[0]))
            vals = np.sort(vals.astype(np.int64))
            self.stats["frontier_rows"] = int(vals.shape[0])
            for s in range(0, vals.shape[0], self.page_rows):
                yield vals[s:s + self.page_rows, None]
            return
        if resume is not None:
            seed_frontier = np.asarray(resume, dtype=np.int32)
        elif seeds is not None:
            seed_frontier = np.asarray(seeds, dtype=np.int32)[:, None]
        else:
            seed_frontier = None
        frontier = np.asarray(
            ex._run(count_only=False, frontier=seed_frontier,
                    max_levels=k - 1), dtype=np.int64)
        if frontier.shape[0] == 0:
            return
        frontier = frontier[np.lexsort(frontier.T[::-1])]
        self.penultimate = frontier
        self.stats["frontier_rows"] = int(frontier.shape[0])
        if not ex.plan[-1].edge_sources:
            # dense final level (no bound edge neighbor): the fanout is
            # the unary-filtered *domain*, not the adjacency width, so
            # chunking by rows cannot bound the buffer — stream one row
            # at a time and slice its extension run to the page size
            for i in range(frontier.shape[0]):
                counts, vals = ex.last_level_extensions(
                    frontier[i:i + 1].astype(np.int32))
                self.stats["chunks"] += 1
                for s in range(0, vals.shape[0], self.page_rows):
                    part = vals[s:s + self.page_rows]
                    yield _segment_expand(
                        frontier[i:i + 1],
                        np.array([part.shape[0]], dtype=np.int64), part)
            return
        # Two interleaved passes, both under the buffer bound.  Per
        # counting window (the executor's full chunk width — the cheap
        # Idea-8 path), per-row final-level counts are measured and
        # expansion chunks are cut where cumulative counts cross
        # page_rows (one overfull row may emit up to `width`).  Sizing
        # chunks by measured fanout instead of the worst-case tile
        # width is what keeps the dispatch count at ~output/page_rows
        # rather than frontier/(page_rows/width) — the ~10x small-page
        # throughput penalty this replaces.  Counting stays lazy, one
        # window ahead of the pages actually pulled, so a client that
        # stops after the first page pays one counting dispatch, not
        # the whole frontier.  Every dispatch is padded to a fixed
        # geometry, so the executor's AOT-compiled final-level cache
        # serves all pages with two compiles total.
        F = frontier.shape[0]
        cstep = ex.chunk_rows
        cap = max(1, min(ex.chunk_rows, self.page_rows))
        for w0 in range(0, F, cstep):
            wreal = min(cstep, F - w0)
            window = frontier[w0:w0 + wreal]
            wpad = (window if wreal == cstep
                    else np.pad(window, ((0, cstep - wreal), (0, 0))))
            wvalid = np.zeros(cstep, dtype=bool)
            wvalid[:wreal] = True
            counts = ex.last_level_counts(
                wpad.astype(np.int32), wvalid)[:wreal]
            self.stats["count_chunks"] += 1
            cum = np.concatenate([[0], np.cumsum(counts)])
            i = 0
            while i < wreal:
                j = int(np.searchsorted(cum, cum[i] + self.page_rows,
                                        side="right")) - 1
                j = min(max(j, i + 1), i + cap, wreal)
                real = j - i
                chunk = window[i:j]
                if real < cap:
                    chunk = np.pad(chunk, ((0, cap - real), (0, 0)))
                valid = np.zeros(cap, dtype=bool)
                valid[:real] = True
                ccounts, vals = ex.last_level_extensions(
                    chunk.astype(np.int32), valid)
                self.stats["chunks"] += 1
                if vals.shape[0]:
                    yield _segment_expand(chunk[:real], ccounts[:real],
                                          vals)
                i = j

    # -- paging --------------------------------------------------------------
    def take(self, n: int | None = None) -> np.ndarray:
        """The next ``n`` rows (default ``page_rows``); empty when drained."""
        n = self.page_rows if n is None else n
        while self._buffered < n and not self._drained:
            try:
                block = next(self._blocks)
            except StopIteration:
                self._drained = True
                break
            if block.shape[0]:
                self._buf.append(block)
                self._buffered += int(block.shape[0])
                self.stats["peak_buffer_rows"] = max(
                    self.stats["peak_buffer_rows"], self._buffered)
        if self._buf:
            cat = (self._buf[0] if len(self._buf) == 1
                   else np.concatenate(self._buf, axis=0))
            out, rest = cat[:n], cat[n:]
            self._buf = [rest] if rest.shape[0] else []
            self._buffered = int(rest.shape[0])
        else:
            out = np.zeros((0, self._k), dtype=np.int64)
        self.stats["pages"] += 1
        self.stats["rows"] += int(out.shape[0])
        self.exhausted = self._drained and self._buffered == 0
        return out

    @property
    def rows_emitted(self) -> int:
        """Total output rows delivered so far, counting any resume skip
        — the ``rows_emitted`` a mid-paging snapshot records."""
        return self._skip + self.stats["rows"]

    def next_page(self) -> np.ndarray | None:
        """``take(page_rows)``, or ``None`` once the stream is exhausted."""
        page = self.take(self.page_rows)
        return page if page.shape[0] else None

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            page = self.next_page()
            if page is None:
                return
            yield page
