"""Node samples (the paper's selectivity predicates) + k-hop neighbor
sampling (the `minibatch_lg` GNN substrate).

The paper samples node predicates ``v1, v2, ...`` with probability ``1/s``
(s = "selectivity"; s=10 keeps ~10%).  The neighbor sampler implements
GraphSAGE-style fanout sampling over the CSR trie: per hop, each frontier
node draws ``fanout`` neighbors (with replacement — vectorizable and
standard); outputs are padded dense arrays + masks, ready to feed the
jitted GNN step with static shapes.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph


def node_sample(n_nodes: int, selectivity: float, seed: int = 0,
                ) -> np.ndarray:
    """Sorted node ids, each kept with probability 1/selectivity."""
    rng = np.random.default_rng(seed)
    keep = rng.random(n_nodes) < (1.0 / selectivity)
    ids = np.flatnonzero(keep).astype(np.int64)
    if ids.size == 0:
        ids = rng.integers(0, n_nodes, size=1).astype(np.int64)
    return ids


class NeighborSampler:
    """k-hop fanout sampler producing padded (layered) blocks."""

    def __init__(self, g: CSRGraph, fanouts: tuple[int, ...],
                 seed: int = 0):
        self.g = g
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, batch_nodes: np.ndarray):
        """Returns a list of hops; each hop is a dict with
        ``src`` (frontier), ``nbr`` (frontier_size, fanout) sampled
        neighbor ids, and ``mask`` marking real (non-padded) samples.
        The next hop's frontier is the flattened unique neighbors.
        """
        g = self.g
        frontier = np.asarray(batch_nodes, dtype=np.int64)
        hops = []
        all_deg = g.degrees          # cached on the CSRGraph
        for fanout in self.fanouts:
            deg = all_deg[frontier]
            # with-replacement draws: offset = floor(u * deg)
            u = self.rng.random((frontier.shape[0], fanout))
            off = np.floor(u * np.maximum(deg, 1)[:, None]).astype(np.int64)
            flat = g.indptr[frontier][:, None] + off
            flat = np.clip(flat, 0, max(0, g.indices.shape[0] - 1))
            nbr = g.indices[flat] if g.indices.shape[0] else np.zeros_like(flat)
            mask = (deg > 0)[:, None] & np.ones_like(nbr, dtype=bool)
            hops.append({"src": frontier, "nbr": nbr, "mask": mask})
            frontier = np.unique(nbr[mask])
            if frontier.size == 0:
                frontier = np.zeros(1, dtype=np.int64)
        return hops
