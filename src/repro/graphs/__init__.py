from .csr import CSRGraph, degrees_from_indptr
from .generators import (barabasi_albert, erdos_renyi, powerlaw_cluster,
                         zipf_graph, SNAP_LIKE)
from .io import load_edgelist, save_edgelist
from .layout import (HybridLayout, degree_sort_permutation, map_rows_back,
                     renumber_csr)
from .sampling import node_sample, NeighborSampler

__all__ = [
    "CSRGraph", "degrees_from_indptr", "barabasi_albert", "erdos_renyi",
    "powerlaw_cluster", "zipf_graph", "SNAP_LIKE", "load_edgelist",
    "save_edgelist", "HybridLayout", "degree_sort_permutation",
    "map_rows_back", "renumber_csr", "node_sample", "NeighborSampler",
]
