from .csr import CSRGraph
from .generators import (barabasi_albert, erdos_renyi, powerlaw_cluster,
                         zipf_graph, SNAP_LIKE)
from .io import load_edgelist, save_edgelist
from .sampling import node_sample, NeighborSampler

__all__ = [
    "CSRGraph", "barabasi_albert", "erdos_renyi", "powerlaw_cluster",
    "zipf_graph", "SNAP_LIKE", "load_edgelist", "save_edgelist",
    "node_sample", "NeighborSampler",
]
