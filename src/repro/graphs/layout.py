"""Degree-adaptive adjacency layouts: renumbering + hybrid bitset packing.

EmptyHeaded's order-of-magnitude wins come from choosing the *physical
representation of each neighborhood* by density: a hub's neighbor set is
cheaper as a dense bitset (membership = one word gather + bit test,
intersection = AND + popcount over ``n_nodes/32`` words) than as a sorted
array (membership = ``log2(deg)`` gather rounds).  "Old Techniques for New
Join Algorithms" adds the enabling trick: renumber vertices by descending
degree so every hub lands in a small contiguous id prefix — the hub test
becomes ``id < n_hubs``, the bitset table is a dense ``(n_hubs, n_words)``
matrix, and Zipf-distributed adjacency mass concentrates in the low ids.

This module is the layout half of that stack:

* :func:`degree_sort_permutation` / :func:`renumber_csr` — the stable
  degree-descending renumbering pass (permutation + inverse; query results
  map back with :func:`map_rows_back`);
* :class:`HybridLayout` — packs every neighborhood above a degree/density
  threshold into fixed-width uint32 bitset rows (word-aligned over the
  full node domain) while the CSR sorted arrays stay authoritative for
  enumeration and probe expansion.

``core.device_graph.HybridGraphDB`` wires the layout into the engines;
``kernels/intersect_bitset.py`` holds the matching Pallas kernels.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .csr import CSRGraph

#: default layout thresholds (see HybridLayout.build).  The degree floor
#: is where the bitset membership test (2 gathers) overtakes binary
#: search (log2(deg)+1 gather rounds) — empirically degree ~2 on the
#: vectorized check path, so the floor is low and *memory* is what
#: adapts: the density rule (a bitset row costs n/32 words regardless
#: of degree) and word_budget keep sparse neighborhoods as arrays on
#: large graphs, and degree sorting means the budget always keeps the
#: heaviest hubs.
DEF_MIN_DEGREE = 2
DEF_DENSITY = 1.0 / 1024.0
DEF_WORD_BUDGET = 1 << 24   # max uint32 words across all bitset rows


# ---------------------------------------------------------------------------
# degree-sorted renumbering
# ---------------------------------------------------------------------------

def degree_sort_permutation(csr: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    """Stable degree-descending permutation of the vertex ids.

    Returns ``(order, inv)`` with ``order[new_id] = old_id`` (ties broken
    by ascending old id, so the pass is deterministic and stable) and
    ``inv[old_id] = new_id`` — the inverse permutation used to map query
    results back to the original id space.
    """
    n = csr.n_nodes
    order = np.lexsort((np.arange(n), -csr.degrees))
    inv = np.empty(n, dtype=np.int64)
    inv[order] = np.arange(n)
    return order, inv


def renumber_csr(csr: CSRGraph, inv: np.ndarray) -> CSRGraph:
    """Apply an old→new vertex relabeling to a CSR graph.

    The edge set is identical up to relabeling; neighbor lists come back
    sorted in the *new* id space (hubs first under a degree-sort ``inv``).
    """
    ea = csr.edge_array()
    inv = np.asarray(inv, dtype=np.int64)
    return CSRGraph.from_edges(inv[ea[:, 0]], inv[ea[:, 1]],
                               n_nodes=csr.n_nodes, symmetrize=False,
                               drop_loops=False)


def map_rows_back(rows: np.ndarray, order: np.ndarray) -> np.ndarray:
    """Map result rows from renumbered ids back to original ids
    (``order`` as returned by :func:`degree_sort_permutation`)."""
    rows = np.asarray(rows)
    if rows.size == 0:
        return rows.astype(np.int64)
    return np.asarray(order, dtype=np.int64)[rows]


# ---------------------------------------------------------------------------
# hybrid bitset layout
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HybridLayout:
    """Bitset rows for the hub id prefix of a degree-renumbered CSR.

    ``words[h, w]`` holds bits ``32*w .. 32*w+31`` of hub ``h``'s
    neighborhood characteristic vector over the full (word-padded) node
    domain: bit ``v & 31`` of ``words[h, v >> 5]`` is set iff edge
    ``(h, v)`` exists.  Hubs are exactly the vertices ``0 .. n_hubs-1``
    (degree sorting makes the dense prefix and the degree threshold
    coincide); everything else keeps only its sorted CSR array.
    """

    n_nodes: int
    n_hubs: int
    n_words: int            # uint32 words per bitset row
    min_degree: int         # effective degree threshold actually applied
    words: np.ndarray       # (n_hubs, n_words) uint32

    @classmethod
    def build(cls, csr: CSRGraph, min_degree: int = DEF_MIN_DEGREE,
              density: float = DEF_DENSITY,
              word_budget: int = DEF_WORD_BUDGET,
              max_hubs: int | None = None) -> "HybridLayout":
        """Pack every sufficiently dense neighborhood into a bitset row.

        A vertex is a hub when ``degree >= max(min_degree,
        density * n_nodes)`` — the density form is EmptyHeaded's layout
        rule (a bitset AND touches ``n/32`` words, so it beats the sorted
        array once the array would pay comparable gathers), the absolute
        floor keeps tiny graphs from bitsetting everything.  Only the
        maximal *prefix* of vertices passing the threshold is packed
        (on a degree-renumbered graph that is every qualifying vertex;
        on an unsorted graph the layout degrades gracefully to fewer or
        zero hubs instead of mis-tagging).  ``word_budget`` caps total
        bitset memory.
        """
        n = csr.n_nodes
        deg = csr.degrees
        n_words = max(1, (n + 31) // 32)
        thr = max(int(min_degree), int(np.ceil(density * n)), 1)
        qualifies = deg >= thr
        # maximal qualifying prefix (== all qualifying ids when renumbered)
        k = int(np.argmin(qualifies)) if not qualifies.all() else n
        k = min(k, max(0, word_budget // n_words))
        if max_hubs is not None:
            k = min(k, int(max_hubs))
        words = np.zeros((k, n_words), dtype=np.uint32)
        if k:
            end = int(csr.indptr[k])
            rows = np.repeat(np.arange(k), deg[:k])
            cols = csr.indices[:end]
            np.bitwise_or.at(words, (rows, cols >> 5),
                             (np.uint32(1) << (cols & 31).astype(np.uint32)))
        return cls(n_nodes=n, n_hubs=k, n_words=n_words, min_degree=thr,
                   words=words)

    def rep_tags(self) -> np.ndarray:
        """Per-vertex representation tag: bitset row index for hubs,
        ``-1`` for array-only vertices (int32, device-shippable)."""
        tag = np.full(self.n_nodes, -1, dtype=np.int32)
        tag[:self.n_hubs] = np.arange(self.n_hubs, dtype=np.int32)
        return tag

    def neighbors_from_bits(self, h: int) -> np.ndarray:
        """Decode hub ``h``'s bitset row back to a sorted id array
        (test oracle for the packer)."""
        bits = np.unpackbits(self.words[h].view(np.uint8), bitorder="little")
        return np.flatnonzero(bits[:self.n_nodes]).astype(np.int64)
