"""CSR adjacency — the sorted-array trie for the binary ``edge`` relation.

The first trie level is the dense ``indptr`` over node ids; the second level
is the per-node sorted neighbor list.  This is the index layout every engine
(reference and vectorized) and every GNN in the model zoo shares.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (m,) int64, sorted within each row
    n_nodes: int
    # cached np.diff(indptr) — every consumer (sampling, stats, vlftj
    # bucketing, layout building) reads degrees repeatedly
    _degrees: np.ndarray | None = field(default=None, repr=False,
                                        compare=False)

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray,
                   n_nodes: int | None = None, symmetrize: bool = True,
                   drop_loops: bool = True) -> "CSRGraph":
        src = np.asarray(src, dtype=np.int64).ravel()
        dst = np.asarray(dst, dtype=np.int64).ravel()
        if symmetrize:
            src, dst = (np.concatenate([src, dst]),
                        np.concatenate([dst, src]))
        if drop_loops:
            keep = src != dst
            src, dst = src[keep], dst[keep]
        if n_nodes is None:
            n_nodes = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
        # sort by (src, dst), dedup
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        if src.size:
            keep = np.empty(src.shape[0], dtype=bool)
            keep[0] = True
            keep[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
            src, dst = src[keep], dst[keep]
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        return cls(indptr=indptr, indices=dst, n_nodes=n_nodes)

    # -- basic stats ---------------------------------------------------------
    @property
    def n_edges(self) -> int:
        """Directed edge count (2x undirected count when symmetrized)."""
        return int(self.indices.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        """Per-node degree, computed once and cached (treat as
        read-only; shared by sampling, stats, and layout builders)."""
        if self._degrees is None:
            self._degrees = degrees_from_indptr(self.indptr)
        return self._degrees

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max(initial=0))

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    # -- conversions ---------------------------------------------------------
    def edge_array(self) -> np.ndarray:
        """(m, 2) sorted edge tuple table (the Relation layout)."""
        src = np.repeat(np.arange(self.n_nodes, dtype=np.int64),
                        self.degrees)
        return np.stack([src, self.indices], axis=1)

    def to_relation(self, name: str = "edge"):
        from ..core.relation import Relation
        r = Relation.__new__(Relation)
        r.data = self.edge_array()
        r.name = name
        return r

    def padded_neighbors(self, pad_to: int | None = None,
                         fill: int = -1) -> tuple[np.ndarray, np.ndarray]:
        """Dense (n, max_deg) neighbor matrix + mask (GNN/vec-join tiles)."""
        d = self.degrees
        width = int(pad_to if pad_to is not None else self.max_degree)
        out = np.full((self.n_nodes, width), fill, dtype=np.int64)
        mask = np.zeros((self.n_nodes, width), dtype=bool)
        cols = np.arange(width)
        valid = cols[None, :] < np.minimum(d[:, None], width)
        flat = np.clip(self.indptr[:-1, None] + cols[None, :], 0,
                       max(0, self.indices.shape[0] - 1))
        if self.indices.shape[0]:
            out[valid] = self.indices[flat[valid]]
        mask[valid] = True
        return out, mask


def degrees_from_indptr(indptr: np.ndarray) -> np.ndarray:
    """Degrees of a CSR row-pointer array — the one place the
    ``np.diff(indptr)`` idiom lives (``CSRGraph.degrees`` caches it;
    raw-indptr holders like the sharded CSR call it directly)."""
    return np.diff(indptr)


def triangle_count_csr(g: CSRGraph) -> int:
    """Host oracle: number of triangles via sorted-neighbor intersection."""
    total = 0
    ind, ptr = g.indices, g.indptr
    for u in range(g.n_nodes):
        nu = ind[ptr[u]:ptr[u + 1]]
        nu = nu[nu > u]
        for v in nu:
            nv = ind[ptr[v]:ptr[v + 1]]
            nv = nv[nv > v]
            total += np.intersect1d(nu, nv, assume_unique=True).shape[0]
    return int(total)
