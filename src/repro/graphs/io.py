"""SNAP-format edge-list IO (``# comment`` headers, whitespace pairs)."""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph


def load_edgelist(path: str, symmetrize: bool = True) -> CSRGraph:
    pairs = np.loadtxt(path, dtype=np.int64, comments="#", ndmin=2)
    if pairs.shape[1] < 2:
        raise ValueError(f"{path}: expected 2+ columns")
    # compact node ids (SNAP files may have sparse id spaces)
    src, dst = pairs[:, 0], pairs[:, 1]
    uniq, inv = np.unique(np.concatenate([src, dst]), return_inverse=True)
    src_c, dst_c = inv[: src.shape[0]], inv[src.shape[0]:]
    return CSRGraph.from_edges(src_c, dst_c, n_nodes=uniq.shape[0],
                               symmetrize=symmetrize)


def save_edgelist(g: CSRGraph, path: str) -> None:
    ea = g.edge_array()
    keep = ea[:, 0] < ea[:, 1]  # one direction only
    np.savetxt(path, ea[keep], fmt="%d",
               header="saved by repro.graphs.io", comments="# ")
