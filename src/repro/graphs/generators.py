"""Synthetic graph generators approximating the paper's SNAP datasets.

The real SNAP collection is not available offline; these generators produce
graphs with matching (nodes, edges) scale and heavy-tailed degree
distributions.  ``SNAP_LIKE`` mirrors Table (§5.1)'s datasets so benchmarks
can be keyed by the paper's dataset names.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph


def erdos_renyi(n: int, m: int, seed: int = 0) -> CSRGraph:
    """G(n, m): m undirected edges sampled uniformly (w/ dedup)."""
    rng = np.random.default_rng(seed)
    # oversample to survive dedup/loop-dropping
    k = int(m * 1.3) + 16
    src = rng.integers(0, n, size=k)
    dst = rng.integers(0, n, size=k)
    keep = src != dst
    src, dst = src[keep][:m], dst[keep][:m]
    return CSRGraph.from_edges(src, dst, n_nodes=n)


def barabasi_albert(n: int, m_per_node: int, seed: int = 0) -> CSRGraph:
    """Preferential attachment (vectorized repeated-node trick)."""
    rng = np.random.default_rng(seed)
    m = m_per_node
    targets = list(range(m))
    repeated: list[int] = []
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(m, n):
        src_l.extend([v] * m)
        dst_l.extend(targets)
        repeated.extend(targets)
        repeated.extend([v] * m)
        # next targets: preferential sample from `repeated`
        idx = rng.integers(0, len(repeated), size=3 * m)
        uniq = list(dict.fromkeys(int(repeated[i]) for i in idx))[:m]
        while len(uniq) < m:  # pragma: no cover - tiny graphs
            c = int(rng.integers(0, v + 1))
            if c not in uniq:
                uniq.append(c)
        targets = uniq
    return CSRGraph.from_edges(np.array(src_l), np.array(dst_l), n_nodes=n)


def powerlaw_cluster(n: int, m_per_node: int, tri_p: float = 0.5,
                     seed: int = 0) -> CSRGraph:
    """BA + triangle-closing step (Holme–Kim), denser in triangles —
    matches social graphs (facebook/epinions) better than plain BA."""
    rng = np.random.default_rng(seed)
    g = barabasi_albert(n, m_per_node, seed)
    # close random wedges with probability tri_p
    deg = g.degrees
    cand = np.flatnonzero(deg >= 2)
    extra_src, extra_dst = [], []
    n_close = int(tri_p * n)
    if cand.size:
        for u in rng.choice(cand, size=min(n_close, cand.size),
                            replace=False):
            nb = g.neighbors(int(u))
            if nb.shape[0] >= 2:
                i, j = rng.choice(nb.shape[0], size=2, replace=False)
                extra_src.append(int(nb[i]))
                extra_dst.append(int(nb[j]))
    if extra_src:
        ea = g.edge_array()
        src = np.concatenate([ea[:, 0], np.array(extra_src)])
        dst = np.concatenate([ea[:, 1], np.array(extra_dst)])
        return CSRGraph.from_edges(src, dst, n_nodes=n, symmetrize=True)
    return g


def zipf_graph(n: int, m: int, alpha: float = 1.4,
               seed: int = 0) -> CSRGraph:
    """Edges whose endpoints follow a Zipf popularity law — a handful of
    hubs own most of the adjacency mass.  The skew workload for the
    distributed layer: a static seed deal balances fine, but frontier
    rows that *reach* a hub mid-join explode on whichever shard holds
    them (``dist/rebalance.py``)."""
    rng = np.random.default_rng(seed)
    weights = np.arange(1, n + 1, dtype=np.float64) ** -alpha
    p = weights / weights.sum()
    src = rng.choice(n, size=m, p=p)
    dst = rng.choice(n, size=m, p=p)
    keep = src != dst
    return CSRGraph.from_edges(src[keep], dst[keep], n_nodes=n)


#: name -> (generator, kwargs) scaled like the paper's SNAP datasets.
#: Edge counts are undirected, as in §5.1's table.
SNAP_LIKE: dict[str, dict] = {
    # small/benchmark-friendly scales (full paper sizes possible but slow on
    # the CPU container; the generators take n/m directly for scaling runs)
    "ca-GrQc":          dict(kind="plc", n=5_242, m_per_node=5),
    "p2p-Gnutella04":   dict(kind="er", n=10_876, m=39_994),
    "wiki-Vote":        dict(kind="plc", n=7_115, m_per_node=14),
    "ego-Facebook":     dict(kind="plc", n=4_039, m_per_node=21),
    "ca-CondMat":       dict(kind="plc", n=23_133, m_per_node=8),
    "p2p-Gnutella31":   dict(kind="er", n=62_586, m=147_892),
    "email-Enron":      dict(kind="plc", n=36_692, m_per_node=10),
    "loc-Brightkite":   dict(kind="plc", n=58_228, m_per_node=7),
    "soc-Epinions1":    dict(kind="plc", n=75_879, m_per_node=6),
    "soc-Slashdot0811": dict(kind="plc", n=77_360, m_per_node=11),
}


def make_snap_like(name: str, seed: int = 0, scale: float = 1.0) -> CSRGraph:
    spec = dict(SNAP_LIKE[name])
    kind = spec.pop("kind")
    if "n" in spec:
        spec["n"] = max(8, int(spec["n"] * scale))
    if "m" in spec:
        spec["m"] = max(8, int(spec["m"] * scale))
    if kind == "er":
        return erdos_renyi(seed=seed, **spec)
    if kind == "plc":
        return powerlaw_cluster(seed=seed, **spec)
    raise ValueError(kind)
