"""``repro.analysis`` — static analyses gating execution and CI.

Three passes, one reporting currency (:class:`Finding`):

* :mod:`~repro.analysis.verifier` — static plan verification (rules
  ``V101``–``V110``), enforced pre-dispatch via
  :func:`verify_for_execution` (``verify=True`` default in
  ``engine.count`` / ``enumerate`` / ``stream`` and the query server);
* :mod:`~repro.analysis.recompile` — the jit-recompilation budget
  auditor (``V107``), cross-checkable against ``DeviceProfile`` compile
  counts at runtime;
* ``tools/lint_repro.py`` — AST lint rules over the repo source,
  reporting the same :class:`Finding` records.

``python -m repro.analysis --tier1`` runs the verifier + auditor over
the planner's output for every tier-1 query shape (the CI
``static-analysis`` job); ``--self-test`` proves the gate fires.  Rule
catalog and suppression syntax: ``docs/ANALYSIS.md``.
"""
from .findings import (SEVERITIES, Finding, FindingReport,
                       PlanVerificationError, filter_suppressed)
from .recompile import (DEFAULT_RECOMPILE_BUDGET, RecompileAudit,
                        audit_recompilation, check_runtime)
from .verifier import (filters_quotient_automorphism, verify_for_execution,
                       verify_plan, verify_snapshot)

__all__ = [
    "Finding", "FindingReport", "PlanVerificationError", "SEVERITIES",
    "filter_suppressed",
    "RecompileAudit", "audit_recompilation", "check_runtime",
    "DEFAULT_RECOMPILE_BUDGET",
    "verify_plan", "verify_for_execution", "verify_snapshot",
    "filters_quotient_automorphism",
]
