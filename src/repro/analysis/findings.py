"""The one reporting currency of ``repro.analysis``: :class:`Finding`.

Every static pass in this package — the plan verifier
(``analysis.verifier``), the jit-recompilation auditor
(``analysis.recompile``), and the AST lint rules (``tools/lint_repro.py``)
— reports through this dataclass, so one CI gate and one JSON artifact
schema cover all three.  A finding names the rule that produced it, a
severity (only ``"error"`` gates), a location (``path:line`` — for plan
findings the path is the synthetic ``plan:<query>`` and the line the GAO
level), the defect, and a fix hint.

Suppression: a source line carrying ``# repro: noqa-<rule>`` silences
that rule on that line (lint passes only — plan findings have no source
line to annotate).  The catalog of rule ids lives in ``docs/ANALYSIS.md``.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

#: severities, most severe first.  Only ``error`` fails the CI gate.
SEVERITIES = ("error", "warning", "note")

#: inline suppression marker: ``# repro: noqa-<rule-id>``.
NOQA_RE = re.compile(r"#\s*repro:\s*noqa-([A-Za-z0-9_-]+)")


@dataclass(frozen=True)
class Finding:
    """One defect reported by a static pass.

    ``rule`` is the catalog id (``V101`` … for the plan verifier,
    kebab-case names for lint rules), ``severity`` one of
    :data:`SEVERITIES`, ``path``/``line`` the location (``line`` 0 when
    the finding has no source anchor), ``message`` the defect statement
    and ``hint`` how to fix it.
    """

    rule: str
    severity: str
    path: str
    line: int
    message: str
    hint: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"options: {SEVERITIES}")

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: {self.severity} [{self.rule}] {self.message}"
        if self.hint:
            out += f"  (fix: {self.hint})"
        return out

    def to_dict(self) -> dict:
        return asdict(self)


class PlanVerificationError(ValueError):
    """A plan failed static verification.  Carries the error-severity
    :class:`Finding` list that rejected it (``.findings``); the message
    is their one-line formats joined."""

    def __init__(self, findings: list):
        self.findings = list(findings)
        super().__init__("; ".join(f.format() for f in self.findings)
                         or "plan verification failed")


@dataclass
class FindingReport:
    """A batch of findings plus the gate decision over them."""

    findings: list = field(default_factory=list)

    def errors(self) -> list:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def gate_passes(self) -> bool:
        return not self.errors()

    def to_json(self, **meta) -> str:
        doc = {**meta,
               "n_findings": len(self.findings),
               "n_errors": len(self.errors()),
               "gate": "pass" if self.gate_passes else "fail",
               "findings": [f.to_dict() for f in self.findings]}
        return json.dumps(doc, indent=2, sort_keys=True)


def suppressed(finding: Finding, source_lines: list[str]) -> bool:
    """True when the finding's source line carries its noqa marker."""
    if not finding.line or finding.line > len(source_lines):
        return False
    line = source_lines[finding.line - 1]
    return finding.rule in NOQA_RE.findall(line)


def filter_suppressed(findings: list[Finding],
                      sources: dict[str, str]) -> list[Finding]:
    """Drop findings whose anchor line carries ``# repro: noqa-<rule>``.

    ``sources`` maps path -> file text for every path findings may
    reference; paths not in the map (e.g. synthetic ``plan:*`` paths)
    are never suppressed.
    """
    out = []
    split: dict[str, list[str]] = {}
    for f in findings:
        if f.path in sources:
            lines = split.setdefault(f.path, sources[f.path].splitlines())
            if suppressed(f, lines):
                continue
        out.append(f)
    return out
