"""CI entry point: verify planner output for every tier-1 query shape.

Verification is a pure function of ``(plan, GraphStats)`` — no graph
data, no device — so this job plans each tier-1 shape against two
synthetic stats profiles (array-only and hybrid-with-bitsets), runs the
static verifier + recompilation auditor over every candidate plan the
planner can produce, and emits one JSON findings document
(:class:`repro.analysis.FindingReport` schema, same artifact shape as
``tools/lint_repro.py --format=json``).

Exit status is the gate: 0 iff no error-severity finding.
``--self-test`` mirrors ``tools/bench_compare.py``: seed malformed
plans, require the verifier to reject every one of them *and* accept
the clean planner output — proving the gate can fire before trusting
that it didn't.

Usage::

    python -m repro.analysis --tier1 [--format=json] [--out findings.json]
    python -m repro.analysis --self-test
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from ..core.plan import GraphStats
from ..core.planner import candidate_plans, plan_query
from ..core.query import get_query
from .findings import FindingReport
from .verifier import verify_plan

#: the six tier-1 query shapes of the paper's benchmark (§5.1) that the
#: acceptance gate verifies planner output for.
TIER1_SHAPES = ("3-clique", "4-clique", "4-cycle", "3-path",
                "2-lollipop", "3-lollipop")

#: synthetic stats profiles: verification never reads graph data, so CI
#: exercises both the array-only and the hybrid/bitset planning paths
#: without building a graph.
_N = 10_000
STATS_PROFILES = {
    "array": GraphStats(
        n_nodes=_N, n_edges=200_000, max_degree=500, avg_degree=20.0,
        unary_sizes=(("v1", 1_000), ("v2", 1_000))),
    "hybrid": GraphStats(
        n_nodes=_N, n_edges=200_000, max_degree=500, avg_degree=20.0,
        unary_sizes=(("v1", 1_000), ("v2", 1_000)),
        n_hubs=128, hub_degree_threshold=64, hub_edge_fraction=0.97,
        bitset_words=(_N + 31) // 32),
}


def tier1_plans(output: str = "count"):
    """Yield ``(label, plan, stats)`` for every planner-produced plan
    across the tier-1 shapes and both stats profiles."""
    for shape in TIER1_SHAPES:
        q = get_query(shape)
        for profile, stats in STATS_PROFILES.items():
            plans = {p.engine: p for p in candidate_plans(q, stats)}
            plans["auto"] = plan_query(q, stats, engine="auto",
                                       output=output)
            for tag, plan in plans.items():
                yield f"{shape}/{profile}/{tag}", plan, stats


def run_tier1(report: FindingReport) -> int:
    n_plans = 0
    for label, plan, stats in tier1_plans():
        n_plans += 1
        for f in verify_plan(plan, stats):
            report.findings.append(dataclasses.replace(
                f, path=f"{label}:{f.path}"))
    return n_plans


def self_test() -> int:
    """Seed malformed plans; the verifier must reject each — and accept
    the clean planner output (a gate that always fires is as useless as
    one that never does)."""
    q = get_query("3-clique")
    stats = STATS_PROFILES["hybrid"]
    good = plan_query(q, stats, engine="vlftj")
    seeds = {
        # V101: GAO drops a query variable
        "uncovered-var": dataclasses.replace(good, gao=good.gao[:-1],
                                             levels=good.levels),
        # V105: bitset level against hub-free stats
        "bitset-no-layout": (dataclasses.replace(
            good, level_layouts=("bitset",) * len(good.gao)),
            STATS_PROFILES["array"]),
        # V107: recompile budget of 0 keys
        "over-budget": good,
    }
    failures = []
    for name, seed in seeds.items():
        seed_stats = stats
        kw = {}
        if isinstance(seed, tuple):
            seed, seed_stats = seed
        if name == "over-budget":
            kw["recompile_budget"] = 1
        errs = [f for f in verify_plan(seed, seed_stats, **kw)
                if f.severity == "error"]
        if not errs:
            failures.append(f"seeded {name} plan was NOT rejected")
        else:
            print(f"self-test: {name} rejected by "
                  f"{sorted({f.rule for f in errs})}")
    clean = [f for f in verify_plan(good, stats) if f.severity == "error"]
    if clean:
        failures.append(f"clean planner output rejected: {clean}")
    for msg in failures:
        print(f"self-test FAILED: {msg}", file=sys.stderr)
    if not failures:
        print("self-test OK: all seeded plans rejected; clean plan passes")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--tier1", action="store_true",
                    help="verify planner output for the six tier-1 "
                         "query shapes (default action)")
    ap.add_argument("--self-test", action="store_true",
                    help="seed malformed plans and require rejection")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="write the JSON findings document here")
    args = ap.parse_args(argv)
    if args.self_test:
        return self_test()
    report = FindingReport()
    n_plans = run_tier1(report)
    doc = report.to_json(job="verify-tier1", shapes=list(TIER1_SHAPES),
                         plans_verified=n_plans)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(doc + "\n")
    if args.format == "json":
        print(doc)
    else:
        for f in report.findings:
            print(f.format())
        print(f"verify-tier1: {n_plans} plans, "
              f"{len(report.findings)} finding(s), "
              f"{len(report.errors())} error(s)")
    return 0 if report.gate_passes else 1


if __name__ == "__main__":
    raise SystemExit(main())
