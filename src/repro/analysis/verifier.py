"""Static plan verifier: abstract interpretation over a frozen
:class:`~repro.core.plan.JoinPlan` before any device dispatch.

EmptyHeaded gets plan trustworthiness from a compile-time GHD/layout
checker; this is our equivalent for the ``plan -> execute`` split.  The
verifier never touches graph *data* — it interprets the plan against
:class:`~repro.core.plan.GraphStats` (and, when available, the executing
``GraphDB``'s layout metadata), so verification is as cacheable as
planning itself.

Rule catalog (ids are stable; ``docs/ANALYSIS.md`` is the reference):

=====  ====================================================================
V101   GAO covers the query variables exactly; vectorized levels past the
       first are bound by >= 1 edge or unary constraint
V102   plan internals align: compiled ``levels`` match
       ``compile_levels(query, gao)``; annotation tuples
       (``level_layouts``/``level_est_rows``/``level_costs``) have per-
       level arity; hybrid decomposition / yannakakis root well-formed
V103   dense-scan levels (no incident constraint at bind time) — warning
V104   frontier dtype/shape propagation: int32 id space, finite
       non-negative cardinality estimates
V105   layout consistency: bitset/mixed levels require hub metadata, and
       the plan's stats must agree with the executing db's
       ``HybridLayout`` (``bitset_words``/``n_hubs``)
V106   renumbering-invariance: non-quotient order filters on a
       renumbered db are an error when the plan's stats fingerprint is
       stale (cross-db reuse); a warning on the same db (documented
       caveat in ``HybridGraphDB``)
V107   jit-recompilation budget (``analysis.recompile``)
V108   ``level_callback`` protocol conformance: callable with the
       ``(level, frontier, mult)`` arity, no device arrays captured —
       a callback closing over device state cannot be snapshotted by
       ``PlanSnapshot`` and pins device buffers across suspends
V109   ``output_mode`` semantics
V110   ``PlanSnapshot`` conformance (``verify_snapshot``): host arrays
       only, pickle-free serializability
=====  ====================================================================

Only **error**-severity findings reject a plan; warnings/notes surface
through ``explain_analyze``.  Enforcement entry point:
:func:`verify_for_execution` (memoized, raised by ``engine.count`` /
``enumerate`` / ``stream`` / the query server under ``verify=True``).
"""
from __future__ import annotations

import inspect
import weakref
from collections import OrderedDict
from itertools import combinations

import numpy as np

from ..core.plan import GraphStats, JoinPlan, compile_levels
from ..core.query import Query
from .findings import Finding, PlanVerificationError
from .recompile import DEFAULT_RECOMPILE_BUDGET, audit_recompilation

_INT32_MAX = 2 ** 31 - 1
_OUTPUT_MODES = ("count", "flat", "factorized")
_LAYOUTS = ("array", "bitset", "mixed")
_VECTOR_ENGINES = ("vlftj", "lftj_ref")


def _plan_path(plan: JoinPlan) -> str:
    return f"plan:{plan.query.name}/{plan.engine}"


def filters_quotient_automorphism(query: Query) -> bool:
    """True iff every ``LessThan`` filter breaks a query automorphism.

    A filter ``u < v`` quotients an automorphism when swapping ``u`` and
    ``v`` maps the atom set to itself (binary atoms compared as
    ``(rel, {vars})`` — the benchmark ``edge`` relation is loaded
    symmetric).  Then each filter halves a genuine output symmetry and
    the count is invariant under any vertex renumbering (the clique
    chains, 2-lollipop's ``d<e``).  A filter between non-interchangeable
    variables (4-cycle's ``a<b``: ``a`` and ``b`` have different
    neighborhoods in the atom set) merely *slices* the id space, so the
    count depends on the numbering — the ``HybridGraphDB`` caveat.
    """
    if not query.filters:
        return True
    atom_set = {(a.rel, frozenset(a.vars)) if a.arity == 2
                else (a.rel, a.vars) for a in query.atoms}
    for f in query.filters:
        swap = {f.left: f.right, f.right: f.left}
        mapped = {(rel, frozenset(swap.get(v, v) for v in vs))
                  if isinstance(vs, frozenset)
                  else (rel, tuple(swap.get(v, v) for v in vs))
                  for rel, vs in atom_set}
        if mapped != atom_set:
            return False
    # the filters must also compose: chains like a<b<c<d quotient the
    # full symmetric group only if every *pair* of chained variables is
    # interchangeable (transpositions generate the group)
    chained = {v for f in query.filters for v in (f.left, f.right)}
    for u, v in combinations(sorted(chained), 2):
        swap = {u: v, v: u}
        mapped = {(rel, frozenset(swap.get(x, x) for x in vs))
                  if isinstance(vs, frozenset)
                  else (rel, tuple(swap.get(x, x) for x in vs))
                  for rel, vs in atom_set}
        if mapped != atom_set:
            return False
    return True


def _is_device_array(obj) -> bool:
    mod = type(obj).__module__ or ""
    return mod.startswith("jax") or mod.startswith("jaxlib")


def _captured_device_arrays(fn) -> list[str]:
    """Names through which ``fn`` closes over jax device values."""
    hits: list[str] = []
    closure = getattr(fn, "__closure__", None) or ()
    names = getattr(getattr(fn, "__code__", None), "co_freevars", ())
    for name, cell in zip(names, closure):
        try:
            val = cell.cell_contents
        except ValueError:
            continue
        if _is_device_array(val):
            hits.append(name)
    self_obj = getattr(fn, "__self__", None)
    if self_obj is not None:
        for attr, val in list(getattr(self_obj, "__dict__", {}).items()):
            if _is_device_array(val):
                hits.append(f"self.{attr}")
    return hits


# ---------------------------------------------------------------------------
# rule passes (each appends Findings; none raises)
# ---------------------------------------------------------------------------

def _check_gao(plan: JoinPlan, out: list[Finding]) -> None:
    path = _plan_path(plan)
    qvars = set(plan.query.variables)
    gao = plan.gao
    if plan.engine == "hybrid" and plan.decomposition is not None:
        # a hybrid plan's GAO is the *core* GAO; the tree half binds by
        # message passing.  Coverage = core vars here, tree ∪ core = the
        # full query (checked now), decomposition shape = V102.
        d = plan.decomposition
        union = set(d.tree_query.variables) | set(d.core_query.variables)
        if union != qvars:
            out.append(Finding(
                "V101", "error", path, 0,
                f"hybrid tree/core split covers {sorted(union)} but the "
                f"query binds {sorted(qvars)}",
                "every query variable must land in the tree or the "
                "core subquery"))
        qvars = set(d.core_query.variables)
    if len(set(gao)) != len(gao):
        out.append(Finding("V101", "error", path, 0,
                           f"GAO {gao} repeats a variable",
                           "a GAO is a permutation of the query variables"))
        return
    missing = qvars - set(gao)
    extra = set(gao) - qvars
    if missing:
        out.append(Finding("V101", "error", path, 0,
                           f"GAO {gao} does not cover query variable(s) "
                           f"{sorted(missing)}",
                           "every query variable binds at exactly one "
                           "GAO level"))
    if extra:
        out.append(Finding("V101", "error", path, 0,
                           f"GAO {gao} binds non-query variable(s) "
                           f"{sorted(extra)}",
                           "drop variables the query never mentions"))
    if missing or extra:
        return
    if plan.engine in _VECTOR_ENGINES and plan.levels:
        for i, lp in enumerate(plan.levels):
            if i == 0:
                continue
            if not lp.edge_sources and not lp.unary and not lp.needs_degree:
                out.append(Finding(
                    "V101", "error", path, i + 1,
                    f"level {i} ({lp.var!r}) is bound by no edge or unary "
                    f"atom — a cross-product scan the vectorized executor "
                    f"does not implement",
                    "reorder the GAO so every level is adjacent to an "
                    "earlier one, or route to an engine with cross-"
                    "product support"))


def _check_alignment(plan: JoinPlan, out: list[Finding]) -> None:
    path = _plan_path(plan)
    k = len(plan.gao)
    if plan.engine in _VECTOR_ENGINES:
        if len(plan.levels) != k:
            out.append(Finding(
                "V102", "error", path, 0,
                f"{len(plan.levels)} compiled level(s) for a {k}-level "
                f"GAO", "levels must be compile_levels(query, gao)"))
        else:
            try:
                expect = compile_levels(plan.query, plan.gao)
            except (ValueError, KeyError):
                expect = None   # V101 territory (uncovered vars)
            if expect is not None and tuple(plan.levels) != expect:
                drift = [i for i, (a, b) in
                         enumerate(zip(plan.levels, expect)) if a != b]
                out.append(Finding(
                    "V102", "error", path, (drift[0] + 1) if drift else 0,
                    f"compiled levels disagree with compile_levels("
                    f"query, gao) at level(s) {drift}",
                    "never hand-edit plan.levels; rebuild via "
                    "dataclasses.replace on (query, gao)"))
    for name, tup in (("level_layouts", plan.level_layouts),
                      ("level_est_rows", plan.level_est_rows),
                      ("level_costs", plan.level_costs)):
        if tup and len(tup) != k:
            out.append(Finding(
                "V102", "error", path, 0,
                f"{name} has {len(tup)} entries for a {k}-level GAO",
                f"{name} is per-GAO-level (or empty)"))
    for i, m in enumerate(plan.level_layouts):
        if m not in _LAYOUTS:
            out.append(Finding(
                "V102", "error", path, i + 1,
                f"unknown level layout {m!r}", f"options: {_LAYOUTS}"))
    if plan.engine == "hybrid":
        d = plan.decomposition
        if d is None:
            # legitimate: HybridJoin falls back to a whole-query VLFTJ
            # when the query has no tree/core split (hybrid.py) — but it
            # needs a GAO to do it
            if not plan.gao:
                out.append(Finding(
                    "V102", "error", path, 0,
                    "hybrid plan with neither a tree/core decomposition "
                    "nor a fallback GAO",
                    "build hybrid plans through planner.plan_query"))
        elif d.attachment not in d.core_gao \
                or set(d.core_gao) != set(d.core_query.variables):
            out.append(Finding(
                "V102", "error", path, 0,
                f"hybrid core GAO {d.core_gao} / attachment "
                f"{d.attachment!r} inconsistent with the core query "
                f"variables {d.core_query.variables}",
                "attachment must be a core variable and core_gao a "
                "permutation of the core query's variables"))
    if plan.engine == "yannakakis" and plan.root is not None \
            and plan.root not in plan.query.variables:
        out.append(Finding(
            "V102", "error", path, 0,
            f"yannakakis root {plan.root!r} is not a query variable",
            "root must name a join-tree vertex variable"))


def _check_dense_levels(plan: JoinPlan, out: list[Finding]) -> None:
    if plan.engine not in _VECTOR_ENGINES:
        return
    path = _plan_path(plan)
    for i, lp in enumerate(plan.levels):
        if i > 0 and not lp.edge_sources and lp.unary:
            out.append(Finding(
                "V103", "warning", path, i + 1,
                f"level {i} ({lp.var!r}) binds by unary scan only — the "
                f"frontier crosses with the full unary set",
                "prefer a GAO binding each variable adjacent to an "
                "earlier one"))
        if i == 0 and not lp.unary and not lp.needs_degree \
                and not lp.edge_sources:
            out.append(Finding(
                "V103", "note", path, 1,
                f"seed level ({lp.var!r}) scans the full vertex domain",
                "harmless on small graphs; a unary anchor shrinks it"))


def _check_frontier_flow(plan: JoinPlan, stats: GraphStats | None,
                         out: list[Finding]) -> None:
    path = _plan_path(plan)
    if stats is not None and stats.n_nodes > _INT32_MAX:
        out.append(Finding(
            "V104", "error", path, 0,
            f"graph has {stats.n_nodes} nodes but frontiers / CSR "
            f"indices are int32",
            "shard the graph below 2^31 nodes per device"))
    est = plan.level_est_rows
    if est and len(est) == len(plan.gao):
        for i, r in enumerate(est):
            if not np.isfinite(r) or r < 0:
                out.append(Finding(
                    "V104", "error", path, i + 1,
                    f"level {i} cardinality estimate is {r!r}",
                    "estimates must be finite and non-negative — "
                    "re-plan against current GraphStats"))
        # abstract width propagation: frontier at level i is
        # (rows_i, i+1) int32; a widths inversion (rows collapsing to 0
        # then growing) is impossible under conjunctive semantics.  The
        # cost model floors estimates with sub-row epsilons on sparse
        # inputs, so only a *material* (>= 1 row) reappearance fires.
        for i in range(1, len(est)):
            if est[i - 1] == 0 and est[i] >= 1:
                out.append(Finding(
                    "V104", "error", path, i + 1,
                    f"estimated frontier grows {est[i - 1]} -> {est[i]} "
                    f"across level {i}: rows cannot reappear after an "
                    f"empty frontier",
                    "the estimate tuple is inconsistent; re-plan"))


def _check_layouts(plan: JoinPlan, stats: GraphStats | None, gdb,
                   out: list[Finding]) -> None:
    path = _plan_path(plan)
    wants_bitset = [i for i, m in enumerate(plan.level_layouts)
                    if m in ("bitset", "mixed")]
    if not wants_bitset:
        return
    if stats is not None and (stats.n_hubs <= 0 or stats.bitset_words <= 0):
        out.append(Finding(
            "V105", "error", path, wants_bitset[0] + 1,
            f"level(s) {wants_bitset} want a bitset layout but the graph "
            f"stats carry no hub metadata (n_hubs={stats.n_hubs if stats else 0}, "
            f"bitset_words={stats.bitset_words if stats else 0})",
            "plan against GraphStats.of(a HybridGraphDB), or force "
            "array layouts"))
        return
    layout = getattr(gdb, "layout", None) if gdb is not None else None
    if gdb is not None and layout is None:
        out.append(Finding(
            "V105", "error", path, wants_bitset[0] + 1,
            f"level(s) {wants_bitset} want a bitset layout but the "
            f"executing db carries no HybridLayout",
            "execute on the HybridGraphDB the plan was costed for"))
        return
    if layout is not None and stats is not None:
        if int(layout.n_words) != stats.bitset_words \
                or int(layout.n_hubs) != stats.n_hubs:
            out.append(Finding(
                "V105", "error", path, wants_bitset[0] + 1,
                f"plan stats say n_hubs={stats.n_hubs}/"
                f"bitset_words={stats.bitset_words} but the executing "
                f"layout has n_hubs={int(layout.n_hubs)}/"
                f"n_words={int(layout.n_words)}",
                "the plan was costed against a different layout; "
                "re-plan (stats fingerprints must match)"))
    if stats is not None and stats.n_hubs > 0 \
            and stats.bitset_words * 32 < stats.n_nodes:
        out.append(Finding(
            "V105", "error", path, 0,
            f"bitset rows span {stats.bitset_words * 32} vertex slots "
            f"< {stats.n_nodes} nodes — membership tests would read "
            f"out of range",
            "bitset_words must be ceil(n_nodes / 32)"))
    # a bitset level the executor cannot use (needs >= 2 bound edge
    # endpoints to intersect against) silently falls back to arrays
    for i in wants_bitset:
        if i < len(plan.levels) and len(plan.levels[i].edge_sources) < 2:
            out.append(Finding(
                "V105", "warning", path, i + 1,
                f"level {i} is marked {plan.level_layouts[i]!r} but has "
                f"{len(plan.levels[i].edge_sources)} bound edge "
                f"source(s) — the executor needs >= 2 to intersect "
                f"bitsets and will fall back to arrays",
                "cosmetic: the planner should mark such levels 'array'"))


def _is_renumbered(gdb) -> bool:
    """True when the db's id space is a non-identity permutation of the
    loaded one (``HybridGraphDB.build(renumber=False)`` keeps ``order``
    as the identity, which is *not* renumbered)."""
    order = getattr(gdb, "order", None)
    if order is None:
        return False
    order = np.asarray(order)
    return bool((order != np.arange(order.shape[0])).any())


def _check_renumbering(plan: JoinPlan, stats: GraphStats | None, gdb,
                       out: list[Finding]) -> None:
    if not plan.query.filters or gdb is None:
        return
    if not _is_renumbered(gdb):
        return
    if filters_quotient_automorphism(plan.query):
        return                                  # counts invariant: safe
    path = _plan_path(plan)
    current = stats.fingerprint() if stats is not None else ""
    if plan.stats_fingerprint and current \
            and plan.stats_fingerprint != current:
        out.append(Finding(
            "V106", "error", path, 0,
            "plan with non-automorphism order filters (id-slicing, e.g. "
            "a 4-cycle chain) was costed against a different graph but "
            "is executing on a renumbered db — counts are not "
            "renumbering-invariant, so this cross-db reuse is unsound",
            "re-plan against GraphStats.of(this db), or build the db "
            "with renumber=False"))
    else:
        out.append(Finding(
            "V106", "warning", path, 0,
            "non-automorphism order filters evaluate in the renumbered "
            "id space on this HybridGraphDB — counts are only "
            "comparable between engines on this same db",
            "see the HybridGraphDB caveat; renumber=False restores "
            "original-id semantics"))


def _check_callback(plan: JoinPlan, out: list[Finding]) -> None:
    cb = plan.level_callback
    if cb is None:
        return
    path = _plan_path(plan)
    if not callable(cb):
        out.append(Finding(
            "V108", "error", path, 0,
            f"level_callback of type {type(cb).__name__} is not callable",
            "the protocol is callback(level, frontier, mult)"))
        return
    try:
        sig = inspect.signature(cb)
    except (TypeError, ValueError):
        sig = None
    if sig is not None:
        try:
            sig.bind(0, None, None)
        except TypeError:
            out.append(Finding(
                "V108", "error", path, 0,
                f"level_callback{sig} cannot accept the (level, "
                f"frontier, mult) protocol arguments",
                "accept three positional arguments (or *args)"))
    captured = _captured_device_arrays(cb)
    if captured:
        out.append(Finding(
            "V108", "error", path, 0,
            f"level_callback captures device array(s) via "
            f"{captured} — unserializable into a PlanSnapshot and pins "
            f"device buffers across suspend/resume",
            "close over host numpy copies (np.asarray) instead"))


def _check_output_mode(plan: JoinPlan, out: list[Finding]) -> None:
    path = _plan_path(plan)
    if plan.output_mode not in _OUTPUT_MODES:
        out.append(Finding(
            "V109", "error", path, 0,
            f"unknown output_mode {plan.output_mode!r}",
            f"options: {_OUTPUT_MODES}"))


def verify_snapshot(snapshot, path: str = "snapshot") -> list[Finding]:
    """V110: a suspended plan's state must be host-resident and
    pickle-free serializable (``PlanSnapshot.to_bytes`` uses a json
    header + ``np.save(allow_pickle=False)``)."""
    out: list[Finding] = []
    frontier = getattr(snapshot, "frontier", None)
    mult = getattr(snapshot, "mult", None)
    for name, arr in (("frontier", frontier), ("mult", mult)):
        if arr is None:
            out.append(Finding(
                "V110", "error", path, 0,
                f"snapshot has no {name} array",
                "suspend at a level boundary with (frontier, mult)"))
        elif _is_device_array(arr):
            out.append(Finding(
                "V110", "error", path, 0,
                f"snapshot {name} is a device array "
                f"({type(arr).__module__}.{type(arr).__name__})",
                "np.asarray() state before snapshotting — snapshots "
                "must not pin device buffers"))
        elif isinstance(arr, np.ndarray) and arr.dtype == object:
            out.append(Finding(
                "V110", "error", path, 0,
                f"snapshot {name} has dtype=object — cannot serialize "
                f"with allow_pickle=False",
                "snapshots carry numeric dtypes only"))
    level = getattr(snapshot, "level", None)
    if level is not None and (not isinstance(level, (int, np.integer))
                              or level < 0):
        out.append(Finding(
            "V110", "error", path, 0,
            f"snapshot level {level!r} is not a non-negative int",
            "record the next GAO level to run"))
    return out


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def verify_plan(plan: JoinPlan, stats: GraphStats | None = None,
                gdb=None, *,
                recompile_budget: int = DEFAULT_RECOMPILE_BUDGET,
                n_devices: int = 1,
                paging_configs: int | None = 2) -> list[Finding]:
    """Run every verifier rule over ``plan``; returns all findings.

    ``stats`` defaults to ``GraphStats.of(gdb)`` when a db is given.
    Pure host-side interpretation — never dispatches device work.
    """
    if stats is None and gdb is not None:
        stats = GraphStats.of(gdb)
    out: list[Finding] = []
    _check_gao(plan, out)
    _check_alignment(plan, out)
    _check_dense_levels(plan, out)
    _check_frontier_flow(plan, stats, out)
    _check_layouts(plan, stats, gdb, out)
    _check_renumbering(plan, stats, gdb, out)
    _check_callback(plan, out)
    _check_output_mode(plan, out)
    audit = audit_recompilation(plan, stats, budget=recompile_budget,
                                n_devices=n_devices,
                                paging_configs=paging_configs)
    out.extend(audit.findings(_plan_path(plan)))
    return out


# verification is a pure function of (plan, stats fingerprint) apart
# from the callback (mutable, compare=False) — memoize the structural
# part so the per-request cost in the serving path is a dict lookup.
_VERIFY_CACHE: OrderedDict[tuple, tuple] = OrderedDict()
_VERIFY_CACHE_CAP = 256
# GraphStats per db identity; (weakref, stats) guards id() reuse
_STATS_CACHE: dict[int, tuple] = {}


def _stats_of(gdb) -> tuple[GraphStats, bool]:
    """Memoized ``(GraphStats.of(gdb), renumbered?)`` per db identity.

    ``GraphDB`` is an unhashable dataclass (eq without frozen), so the
    memo keys on ``id()`` with a stored weakref guarding against id
    reuse after collection."""
    key = id(gdb)
    hit = _STATS_CACHE.get(key)
    if hit is not None and hit[0]() is gdb:
        return hit[1], hit[2]
    stats = GraphStats.of(gdb)
    renum = _is_renumbered(gdb)
    if len(_STATS_CACHE) > 64:
        _STATS_CACHE.clear()
    try:
        ref = weakref.ref(gdb)
    except TypeError:
        def ref(g=gdb):
            return g
    _STATS_CACHE[key] = (ref, stats, renum)
    return stats, renum


def verify_for_execution(plan: JoinPlan, gdb,
                         recompile_budget: int = DEFAULT_RECOMPILE_BUDGET
                         ) -> list[Finding]:
    """Enforcement wrapper used by ``engine`` / the query server.

    Returns the findings (for surfacing) and raises
    :class:`PlanVerificationError` on any error-severity finding.
    Structural results are memoized on ``(plan, stats fingerprint,
    renumbered?, budget)``; the callback rule (the one non-hashable
    field) re-runs each call.
    """
    stats, renumbered = _stats_of(gdb)
    key = (plan, stats.fingerprint(), renumbered,
           getattr(gdb, "layout", None) is not None, recompile_budget)
    try:
        cached = _VERIFY_CACHE.get(key)
    except TypeError:           # unhashable query payloads: skip memo
        cached = None
        key = None
    if cached is None:
        base = plan if plan.level_callback is None \
            else plan.with_level_callback(None)
        cached = tuple(verify_plan(base, stats, gdb,
                                   recompile_budget=recompile_budget))
        if key is not None:
            _VERIFY_CACHE[key] = cached
            while len(_VERIFY_CACHE) > _VERIFY_CACHE_CAP:
                _VERIFY_CACHE.popitem(last=False)
    findings = list(cached)
    if plan.level_callback is not None:
        _check_callback(plan, findings)
    errors = [f for f in findings if f.severity == "error"]
    if errors:
        raise PlanVerificationError(errors)
    return findings
