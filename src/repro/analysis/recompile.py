"""Static jit-recompilation budget auditor.

XLA recompiles once per distinct ``(static args, input shapes)`` cache
key, and a compile costs orders of magnitude more than a dispatch — an
engine whose shape space is unbounded will "win" every microbenchmark
and then compile forever in serving.  The executors bound their shape
spaces deliberately:

* **interior levels** (``VLFTJ._run``) pad partial chunks to the next
  power of two with a floor of 8, so per static-arg combo the chunk
  kernel sees at most ``log2(chunk_rows / 8) + 1`` distinct row counts;
* the **final level** AOT cache (``VLFTJ._final_level_call``, keyed on
  ``(frontier.shape, count_only)``) sees the fixed counting window
  (``chunk_rows`` rows), one expansion cap per paging configuration
  (``ResultCursor`` pads chunks to ``min(chunk_rows, page_rows)``), and
  the dense-final-level single-row probe;
* **spmd** execution (``dist.sharded_join``) pads frontier rows to a
  multiple of the shard count before the pow2 chunking, which cannot
  *add* post-padding shapes but does compile each kernel once per device
  mesh.

This module re-derives that arithmetic from the *plan*, before any
device work: :func:`audit_recompilation` enumerates the distinct cache
keys a plan can generate and fails it (finding ``V107``) when the count
is unbounded or exceeds ``budget``.  The static count is an upper bound
by construction — every modeled key is a shape the executor *may*
request, so :func:`check_runtime` can assert ``DeviceProfile`` observed
compiles ≤ static total after any run, which is how the model itself is
kept honest (``tests/test_analysis.py``).
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.plan import GraphStats, JoinPlan, executor_geometry
from .findings import Finding

#: default cap on statically-enumerated compile cache keys per plan.  A
#: 7-level vlftj plan with mixed layouts lands around 4e2 keys; only a
#: pathological geometry (or an unbounded paging dimension) crosses this.
DEFAULT_RECOMPILE_BUDGET = 1024

#: interior-level kernel variants the executor may bucket rows into:
#: tile-probe and bsearch-probe always; +1 bitset-probe when the level's
#: layout is 'bitset' or 'mixed'.
_BASE_MODES = 2


@dataclass(frozen=True)
class RecompileAudit:
    """Statically-enumerated compile-key census of one plan.

    ``per_level`` holds ``(label, keys)`` per GAO level (vectorized
    engines only), ``final_level`` the AOT final-level cache keys,
    ``spmd`` the per-device replication surcharge, ``total`` their sum.
    ``unbounded`` lists reasons the key space has no static bound (any
    entry ⇒ the audit fails regardless of ``budget``).
    """

    engine: str
    per_level: tuple[tuple[str, int], ...]
    final_level: int
    spmd: int
    total: int
    budget: int
    chunk_shapes: int
    unbounded: tuple[str, ...] = ()

    @property
    def within_budget(self) -> bool:
        return not self.unbounded and self.total <= self.budget

    def findings(self, path: str = "plan") -> list[Finding]:
        out = []
        for reason in self.unbounded:
            out.append(Finding(
                rule="V107", severity="error", path=path, line=0,
                message=f"unbounded jit cache-key space: {reason}",
                hint="bound every shape dimension (pow2 chunk padding, "
                     "fixed paging configs) before execution"))
        if not self.unbounded and self.total > self.budget:
            out.append(Finding(
                rule="V107", severity="error", path=path, line=0,
                message=f"plan can generate {self.total} distinct compile "
                        f"cache keys > budget {self.budget}",
                hint="shrink chunk_rows / level count, or raise "
                     "recompile_budget if the cost is intended"))
        return out


def chunk_shape_count(chunk_rows: int) -> int:
    """Distinct post-padding row counts one static-arg combo can see.

    ``VLFTJ._run`` pads a partial chunk of ``r`` rows to
    ``min(chunk_rows, max(8, pow2ceil(r)))`` — the reachable set is
    ``{8, 16, ..., pow2 <= chunk_rows} ∪ {chunk_rows}``.
    """
    if chunk_rows <= 8:
        return 1
    n = (chunk_rows // 8).bit_length()      # pow2 rungs from 8 up
    if chunk_rows & (chunk_rows - 1):       # non-pow2 cap adds itself
        n += 1
    return n


def audit_recompilation(plan: JoinPlan, stats: GraphStats | None = None,
                        *, chunk_rows: int = 8192,
                        elem_budget: int = 1 << 22,
                        n_devices: int = 1,
                        paging_configs: int | None = 2,
                        budget: int = DEFAULT_RECOMPILE_BUDGET
                        ) -> RecompileAudit:
    """Enumerate the distinct compiled-shape cache keys ``plan`` can hit.

    ``paging_configs`` is the number of distinct ``page_rows`` values the
    caller will stream with (each adds one final-level expansion cap to
    the AOT cache); pass ``None`` to declare it caller-controlled per
    request, which makes the key space **unbounded** and fails the audit.
    The count deliberately over-approximates (every modeled key is
    *reachable*, not necessarily reached), so it upper-bounds the
    runtime ``DeviceProfile.jit['compiles']``.
    """
    unbounded: list[str] = []
    if plan.engine in ("lftj_ref", "minesweeper_ref", "binary"):
        # host-side reference engines: no jit cache at all
        return RecompileAudit(plan.engine, (), 0, 0, 0, budget, 0)

    if stats is not None:
        _, chunk = executor_geometry(stats.max_degree, chunk_rows,
                                     elem_budget)
    else:
        chunk = chunk_rows
    if chunk < 1:
        unbounded.append(f"chunk_rows={chunk} (< 1: no chunking bound)")
        chunk = 1
    shapes = chunk_shape_count(chunk)

    per_level: list[tuple[str, int]] = []
    final = 0
    if plan.engine in ("vlftj", "hybrid"):
        levels = plan.levels
        gao = plan.gao
        if plan.engine == "hybrid" and plan.decomposition is not None:
            # the seeded core LFTJ is the device side of a hybrid plan;
            # the tree half is SpMV-shaped (counted below with
            # yannakakis arithmetic)
            from ..core.plan import compile_levels
            gao = plan.decomposition.core_gao
            try:
                levels = compile_levels(plan.decomposition.core_query, gao)
            except ValueError:
                levels = ()
        layouts = plan.level_layouts or ("array",) * len(gao)
        for i in range(max(0, len(gao) - 1)):
            modes = _BASE_MODES
            if i < len(layouts) and layouts[i] in ("bitset", "mixed"):
                modes += 1
            # static-arg combos (probe modes) x padded row shapes x
            # count_only specialization of the shared expand kernel
            keys = modes * shapes * 2
            label = gao[i] if i < len(gao) else f"level{i}"
            per_level.append((label, keys))
        if gao:
            # final-level AOT cache (VLFTJ._final_level_call): keyed on
            # (frontier rows, count_only).  Rows come from the counting
            # window (chunk), one expansion cap per paging config, and
            # the dense final level's single-row probes.
            if paging_configs is None:
                unbounded.append(
                    "paging_configs=None: every distinct page_rows adds "
                    "a final-level AOT key")
                caps = 0
            else:
                caps = max(0, int(paging_configs))
            final = 2 * (2 + caps)
    if plan.engine in ("yannakakis", "hybrid"):
        # SpMV tree passes: shapes fixed by the graph (n_nodes), one
        # up+down compile pair per tree edge, bounded by the variable
        # count.  Small constant per level; never near the budget.
        n_vars = len(plan.query.variables)
        per_level.append(("spmv-tree", 2 * max(1, n_vars)))

    per_level_total = sum(k for _, k in per_level)
    spmd = 0
    if n_devices > 1:
        # sharded execution pads rows to a multiple of n_devices *before*
        # pow2 chunking (dist.sharded_join), so it adds no new
        # post-padding shapes — but each device mesh compiles its own
        # executable of every key.
        spmd = (per_level_total + final) * (n_devices - 1)
    total = per_level_total + final + spmd
    return RecompileAudit(plan.engine, tuple(per_level), final, spmd,
                          total, budget, shapes, tuple(unbounded))


def check_runtime(audit: RecompileAudit, profile,
                  path: str = "plan") -> Finding | None:
    """Cross-check the static bound against an executed profile.

    ``profile`` is a :class:`repro.obs.DeviceProfile` (or anything with a
    ``jit['compiles']`` counter).  Returns a finding when the runtime
    observed **more** compiles than the static enumeration admits — i.e.
    the auditor's model of the executors has drifted — else ``None``.
    """
    observed = int(getattr(profile, "jit", {}).get("compiles", 0))
    if audit.unbounded:
        return None             # no static bound to compare against
    if observed > audit.total:
        return Finding(
            rule="V107", severity="error", path=path, line=0,
            message=f"runtime observed {observed} jit compiles > static "
                    f"bound {audit.total} — the audit model has drifted "
                    f"from the executors",
            hint="update analysis/recompile.py to match the executor's "
                 "shape geometry")
    return None
