"""Shared layer primitives: norms, RoPE, activations, initializers.

Explicit dtypes throughout (x64 is enabled package-wide for the join
engines; model math stays bf16/f32 by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, stddev: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            * stddev).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array | None = None,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return lambda x, p: rmsnorm(x, p["scale"])
    if kind == "layernorm":
        return lambda x, p: layernorm(x, p["scale"], p.get("bias"))
    raise ValueError(kind)


def act_fn(kind: str):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu,
            "relu": jax.nn.relu}[kind]


# -- rotary position embedding ----------------------------------------------

def rope_frequencies(d_rot: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32)
                            / d_rot))


def apply_rope(x: jax.Array, positions: jax.Array, rot_frac: float = 1.0,
               theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding on the leading ``rot_frac`` of head dims.

    x: (..., T, n_heads, d_head); positions: (..., T).
    ``rot_frac=0.5`` is ChatGLM's 2D-RoPE convention (rotary on half the
    head dims, identity on the rest).
    """
    d_head = x.shape[-1]
    d_rot = int(d_head * rot_frac)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    freqs = rope_frequencies(d_rot, theta)                   # (d_rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, d/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., T, 1, :)
    sin = jnp.sin(ang)[..., None, :]
    x1 = xr[..., 0::2].astype(jnp.float32)
    x2 = xr[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rot = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1)


def cross_entropy_from_logits(logits: jax.Array, labels: jax.Array,
                              vocab: int) -> jax.Array:
    """Per-token CE without materializing a one-hot (fused iota compare)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    lbl = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    return lse - lbl
