"""Mixture-of-Experts FFN with explicit shard_map parallelism.

Two sharding modes, chosen per config by expert-count divisibility:

  * ``ep``: experts sharded over the ``model`` axis (moonshot: 64 experts /
    16 shards = 4 per shard).  Routing/top-k is computed redundantly per
    model shard (cheap); each shard dispatches only its own experts'
    tokens into a capacity-bounded (E_loc, C, d) buffer via sort-based
    (MegaBlocks-style) dispatch; outputs are ``psum``-combined over the
    model axis — the same d-wide all-reduce a dense TP FFN pays.
  * ``tp``: experts replicated, expert FFN width sharded over ``model``
    (granite: 40 experts don't divide 16; d_ff=512 shards to 32).  The
    down-projection contracts the sharded width, so the same final psum
    applies.

The sort-based dispatch (argsort by expert, position-in-expert via
prefix offsets, capacity drop) is the token-permutation machinery the
vectorized join engine uses for frontier expansion — scatter/gather with
static shapes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import act_fn, normal_init


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    shard_mode: str = "ep"          # "ep" | "tp"
    n_shared_experts: int = 0       # always-on shared experts (DeepSeek/Kimi)


def init_moe_params(key, d_model: int, cfg: MoEConfig, n_layers: int,
                    dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e, ff = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": normal_init(ks[0], (n_layers, d_model, e), dtype=jnp.float32),
        "w_gate": normal_init(ks[1], (n_layers, e, d_model, ff), dtype=dtype),
        "w_up": normal_init(ks[2], (n_layers, e, d_model, ff), dtype=dtype),
        "w_down": normal_init(ks[3], (n_layers, e, ff, d_model), dtype=dtype),
    }
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["sh_gate"] = normal_init(kk[0], (n_layers, d_model, sff), dtype=dtype)
        p["sh_up"] = normal_init(kk[1], (n_layers, d_model, sff), dtype=dtype)
        p["sh_down"] = normal_init(kk[2], (n_layers, sff, d_model), dtype=dtype)
    return p


def moe_param_specs(cfg: MoEConfig, fsdp: bool = False):
    """PartitionSpecs for the stacked (L, ...) MoE params."""
    dp = "data" if fsdp else None
    if cfg.shard_mode == "ep":
        w = P(None, "model", dp, None)
        wd = P(None, "model", None, dp)
    else:
        w = P(None, None, dp, "model")
        wd = P(None, None, "model", dp)
    specs = {"router": P(None, None, None), "w_gate": w, "w_up": w,
             "w_down": wd}
    if cfg.n_shared_experts:
        specs["sh_gate"] = P(None, dp, "model")
        specs["sh_up"] = P(None, dp, "model")
        specs["sh_down"] = P(None, "model", dp)
    return specs


def _dispatch_compute(x, router, w_gate, w_up, w_down, *, cfg: MoEConfig,
                      e_off, n_total_experts: int, act: str, capacity: int):
    """Token dispatch + expert FFN for the experts [e_off, e_off+E_loc).

    x: (T, d).  Returns (partial_out (T, d), aux_loss scalar).
    """
    t, d = x.shape
    e_loc = w_gate.shape[0]
    k = cfg.top_k
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_vals, idx = jax.lax.top_k(logits, k)                 # (T, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    # load-balance aux (computed on the full router; identical per shard)
    frac = jnp.zeros(n_total_experts, jnp.float32)
    onehot_top1 = jax.nn.one_hot(idx[:, 0], n_total_experts,
                                 dtype=jnp.float32)
    frac = onehot_top1.mean(axis=0)
    aux = n_total_experts * jnp.sum(frac * probs.mean(axis=0))

    eflat = idx.reshape(-1)                                   # (T*k,)
    tflat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    gflat = gates.reshape(-1)
    order = jnp.argsort(eflat, stable=True)
    se, st, sg = eflat[order], tflat[order], gflat[order]
    starts = jnp.searchsorted(se, jnp.arange(n_total_experts,
                                             dtype=se.dtype))
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    local = (se >= e_off) & (se < e_off + e_loc) & (pos < capacity)
    slot_e = jnp.where(local, se - e_off, 0).astype(jnp.int32)
    slot_c = jnp.where(local, pos, 0).astype(jnp.int32)
    xg = jnp.where(local[:, None], x[st], 0).astype(x.dtype)
    buf = jnp.zeros((e_loc, capacity, d), x.dtype)
    buf = buf.at[slot_e, slot_c].add(xg)
    h = jnp.einsum("ecd,edf->ecf", buf, w_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up,
                   preferred_element_type=jnp.float32)
    h = (act_fn(act)(h) * u).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w_down,
                   preferred_element_type=jnp.float32)        # (E_loc,C,d)
    contrib = y[slot_e, slot_c] * jnp.where(local, sg, 0.0)[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[st].add(contrib)
    return out, aux


def moe_ffn(x, params_layer, cfg: MoEConfig, mesh, *, act: str = "silu",
            dtype=jnp.bfloat16):
    """x: (B, S, d) batch-sharded over (pod, data).  Returns (y, aux)."""
    dataxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b, s, d = x.shape
    t_local = (b * s) // _axes_size(mesh, dataxes)
    capacity = int(cfg.capacity_factor * t_local * cfg.top_k
                   / cfg.n_experts) + 1
    if cfg.shard_mode == "ep":
        wspec = P("model", None, None)
        wdspec = P("model", None, None)
    else:
        wspec = P(None, None, "model")
        wdspec = P(None, "model", None)

    def f(x_loc, router, wg, wu, wd):
        tl = x_loc.shape[0] * x_loc.shape[1]
        xf = x_loc.reshape(tl, d)
        if cfg.shard_mode == "ep":
            e_loc = wg.shape[0]
            e_off = jax.lax.axis_index("model") * e_loc
        else:
            e_off = 0
        out, aux = _dispatch_compute(
            xf, router, wg, wu, wd, cfg=cfg, e_off=e_off,
            n_total_experts=cfg.n_experts, act=act, capacity=capacity)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, "model")
        for ax in dataxes:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(x_loc.shape).astype(dtype), aux

    # jax.shard_map exists on every supported jax: repro/__init__ bridges
    # the pre-0.6 experimental spelling (check_rep -> check_vma)
    y, aux = jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(dataxes, None, None), P(), wspec, wspec, wdspec),
        out_specs=(P(dataxes, None, None), P()),
        check_vma=False,
    )(x, params_layer["router"], params_layer["w_gate"],
      params_layer["w_up"], params_layer["w_down"])

    if cfg.n_shared_experts:
        g = act_fn(act)(jnp.einsum(
            "bsd,df->bsf", x, params_layer["sh_gate"],
            preferred_element_type=jnp.float32))
        u = jnp.einsum("bsd,df->bsf", x, params_layer["sh_up"],
                       preferred_element_type=jnp.float32)
        sh = jnp.einsum("bsf,fd->bsd", (g * u).astype(x.dtype),
                        params_layer["sh_down"],
                        preferred_element_type=jnp.float32)
        y = y + sh.astype(y.dtype)
    return y, aux


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
