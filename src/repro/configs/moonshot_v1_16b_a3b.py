"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d_model=2048
16H (kv=16) vocab=163840, MoE 64 experts top-6 (d_ff_expert=1408) + 2
shared experts (Kimi/Moonlight convention).  EP sharding: 64/16 = 4
experts per model shard."""
import jax.numpy as jnp

from ..layers.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .common import LMArch

ARCH = LMArch(
    arch_id="moonshot-v1-16b-a3b",
    cfg=TransformerConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab_size=163840, rope_frac=1.0,
        act="silu", norm="rmsnorm", tie_embeddings=True,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                      shard_mode="ep", n_shared_experts=2),
        dtype=jnp.bfloat16, remat=True, loss_seq_chunk=512),
    microbatches=2,
)
