"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim=10,
CIN 200-200-200, MLP 400-400.  Embedding tables: 1M rows/field
(Criteo-scale), row-sharded over the model axis."""
from ..models.xdeepfm import XDeepFMConfig
from .common import RecsysArch

ARCH = RecsysArch(
    arch_id="xdeepfm",
    cfg=XDeepFMConfig(
        n_sparse=39, embed_dim=10, vocab_per_field=1_000_000,
        cin_layers=(200, 200, 200), mlp_dims=(400, 400)),
)
