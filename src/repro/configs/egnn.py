"""egnn [arXiv:2102.09844]: 4 layers, d_hidden=64, E(n)-equivariant."""
from ..models.gnn.egnn import EGNNConfig, egnn_loss, init_egnn
from .common import GNNArch

ARCH = GNNArch(
    arch_id="egnn",
    make_cfg=lambda d_in, n_cls: EGNNConfig(
        n_layers=4, d_hidden=64, d_in=d_in),
    init_fn=init_egnn,
    loss_fn=egnn_loss,
    needs_coords=True,
)
