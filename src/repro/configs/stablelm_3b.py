"""stablelm-3b [hf:stabilityai/stablelm-2; dims per assignment]:
32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
StableLM-2 conventions: LayerNorm, partial rotary (25%), SiLU-gated MLP.
"""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .common import LMArch

ARCH = LMArch(
    arch_id="stablelm-3b",
    cfg=TransformerConfig(
        name="stablelm-3b", n_layers=32, d_model=2560, n_heads=32,
        n_kv_heads=32, d_ff=6912, vocab_size=50304, rope_frac=0.25,
        act="silu", norm="layernorm", tie_embeddings=True,
        dtype=jnp.bfloat16, remat=True, loss_seq_chunk=512),
    microbatches=1,
)
