"""gatedgcn [arXiv:2003.00982]: 16 layers, d_hidden=70, gated aggregator."""

from ..models.gnn.gatedgcn import (GatedGCNConfig, gatedgcn_loss,
                                   init_gatedgcn)
from .common import GNNArch

ARCH = GNNArch(
    arch_id="gatedgcn",
    make_cfg=lambda d_in, n_cls: GatedGCNConfig(
        n_layers=16, d_hidden=70, d_in=d_in, n_classes=n_cls),
    init_fn=init_gatedgcn,
    loss_fn=gatedgcn_loss,
    scan_layers=True,
)
