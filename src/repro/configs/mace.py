"""mace [arXiv:2206.07697]: 2 layers, 128 channels, l_max=2,
correlation order 3, 8 RBF, E(3)-equivariant ACE product basis."""
from ..models.gnn.mace import MACEConfig, init_mace, mace_loss
from .common import GNNArch

ARCH = GNNArch(
    arch_id="mace",
    make_cfg=lambda d_in, n_cls: MACEConfig(
        n_layers=2, d_hidden=128, l_max=2, correlation=3, n_rbf=8,
        d_in=d_in),
    init_fn=init_mace,
    loss_fn=mace_loss,
    needs_coords=True,
    opt_variants={
        # §Perf iterations on the worst baseline cell (see EXPERIMENTS.md)
        "ogb_products_c1": ("ogb_products",
                            dict(a_basis_mode="loop")),
        "ogb_products_c2": ("ogb_products",
                            dict(a_basis_mode="loop", compute_bf16=True)),
        "ogb_products_c3": ("ogb_products",
                            dict(a_basis_mode="loop", compute_bf16=True,
                                 couple_chunks=16)),
        "ogb_products_c4": ("ogb_products",
                            dict(a_basis_mode="loop", shard_couple=True),
                            dict(pad_nodes=True)),
        "ogb_products_c6": ("ogb_products",
                            dict(a_basis_mode="loop", shard_couple=True,
                                 remat=True),
                            dict(pad_nodes=True)),
        "ogb_products_c5": ("ogb_products",
                            dict(a_basis_mode="loop", shard_couple=True,
                                 remat=True),
                            dict(pad_nodes=True)),
    },
)
