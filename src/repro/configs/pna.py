"""pna [arXiv:2004.05718]: 4 layers, d_hidden=75,
aggregators mean-max-min-std x scalers id-amp-atten."""
from ..models.gnn.pna import PNAConfig, init_pna, pna_loss
from .common import GNNArch

ARCH = GNNArch(
    arch_id="pna",
    make_cfg=lambda d_in, n_cls: PNAConfig(
        n_layers=4, d_hidden=75, d_in=d_in, n_classes=n_cls),
    init_fn=init_pna,
    loss_fn=pna_loss,
    scan_layers=True,
)
