"""granite-moe-3b-a800m [hf:ibm-granite]: 32L d_model=1536 24H (GQA kv=8)
vocab=49155, MoE 40 experts top-8 (d_ff_expert=512).

40 experts do not divide a 16-way model axis -> TP sharding of the expert
FFN width instead of EP (DESIGN.md §4)."""
import jax.numpy as jnp

from ..layers.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .common import LMArch

ARCH = LMArch(
    arch_id="granite-moe-3b-a800m",
    cfg=TransformerConfig(
        name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
        n_kv_heads=8, d_ff=512, vocab_size=49155, rope_frac=1.0,
        act="silu", norm="rmsnorm", tie_embeddings=True,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                      shard_mode="tp"),
        dtype=jnp.bfloat16, remat=True, loss_seq_chunk=512),
    microbatches=1,
)
