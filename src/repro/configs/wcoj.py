"""The paper's engine as a dry-run architecture: worst-case-optimal join
steps at pod scale.

Shapes (graph scales mirror §5.1's largest datasets):
  * ``triangle_frontier`` — one vectorized-LFTJ expansion level of the
    3-clique on an Orkut-scale CSR (117M directed edges), frontier sharded
    over (pod, data);
  * ``path_spmv`` — one #Minesweeper counting message (SpMV) on a
    LiveJournal-scale graph, edges sharded;
  * ``fourclique_check`` — the check-heavy level (two membership probes
    per candidate) of the 4-clique.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.vlftj import _expand_level
from .common import Cell, named, sds, _dataxes

WCOJ_SHAPES = {
    "triangle_frontier": dict(kind="join", n_nodes=3_072_441,
                              n_edges=234_370_166, frontier=1 << 20,
                              width=512, n_bound=2, n_probe=1),
    "path_spmv": dict(kind="spmv", n_nodes=4_847_571,
                      n_edges=137_987_546),
    "fourclique_check": dict(kind="join", n_nodes=3_072_441,
                             n_edges=234_370_166, frontier=1 << 19,
                             width=512, n_bound=3, n_probe=2),
    # §Perf hillclimb variants (beyond-paper; baselines above unchanged)
    "triangle_frontier_tile": dict(
        kind="join", n_nodes=3_072_441, n_edges=234_370_166,
        frontier=1 << 20, width=512, n_bound=2, n_probe=1,
        variant="tile_bucketed", tile_frac=0.9375, check_width=512),
    "fourclique_check_tile": dict(
        kind="join", n_nodes=3_072_441, n_edges=234_370_166,
        frontier=1 << 19, width=512, n_bound=3, n_probe=2,
        variant="tile_bucketed", tile_frac=0.9375, check_width=512),
    "triangle_frontier_rot": dict(
        kind="join", n_nodes=3_072_441, n_edges=234_370_166,
        frontier=1 << 20, width=512, n_bound=2, n_probe=1,
        variant="rotate"),
    "triangle_frontier_rot2l": dict(
        kind="join", n_nodes=3_072_441, n_edges=234_370_166,
        frontier=1 << 20, width=512, n_bound=2, n_probe=1,
        variant="rotate2l", stride=128),
    "fourclique_check_rot2l": dict(
        kind="join", n_nodes=3_072_441, n_edges=234_370_166,
        frontier=1 << 19, width=512, n_bound=3, n_probe=2,
        variant="rotate2l", stride=128),
    # A4: + frontier sharded over the FULL mesh (the model axis has no
    # MXU work in a join, but its HBM bandwidth is real)
    "triangle_frontier_opt": dict(
        kind="join", n_nodes=3_072_441, n_edges=234_370_166,
        frontier=1 << 20, width=512, n_bound=2, n_probe=1,
        variant="rotate2l", stride=128, full_mesh=True),
    "fourclique_check_opt": dict(
        kind="join", n_nodes=3_072_441, n_edges=234_370_166,
        frontier=1 << 19, width=512, n_bound=3, n_probe=2,
        variant="rotate2l", stride=128, full_mesh=True),
}


@dataclass
class WCOJArch:
    arch_id: str = "wcoj"
    shapes: dict = field(default_factory=lambda: dict(WCOJ_SHAPES))

    family = "wcoj"

    def cell(self, shape_name: str, mesh) -> Cell:
        sh = self.shapes[shape_name]
        dax = _dataxes(mesh)
        if sh.get("full_mesh"):
            dax = tuple(mesh.axis_names)  # joins use every axis' HBM
        if sh["kind"] == "spmv":
            n = sh["n_nodes"]
            e = -(-sh["n_edges"] // 512) * 512  # pad to shard boundary

            def spmv(indices, src_ids, c):
                part = jax.ops.segment_sum(c[indices], src_ids,
                                           num_segments=n)
                return part

            args = (sds((e,), jnp.int32), sds((e,), jnp.int32),
                    sds((n,), jnp.int64))
            in_sh = named(mesh, (P(dax), P(dax), P()))
            return Cell(self.arch_id, shape_name, "forward", spmv, args,
                        in_shardings=in_sh,
                        out_shardings=named(mesh, P()),
                        model_flops=2.0 * e,
                        note="counting message pass (#MS Idea 8)")
        n, e = sh["n_nodes"], sh["n_edges"]
        c, w, nb = sh["frontier"], sh["width"], sh["n_bound"]
        n_iter = 18  # ceil(log2(max_deg ~ 100k)) + margin
        probe_cols = tuple(range(nb))  # all bound vars adjacent via edges
        variant = sh.get("variant", "bsearch")

        if variant == "tile_bucketed":
            # §Perf: degree-bucketed membership — most rows (tile_frac,
            # per the power-law degree CDF) gather their check segment
            # once and dense-compare on the VPU (the Pallas kernel's
            # schedule); only the heavy tail binary-searches.
            ct = int(c * sh["tile_frac"]) // 512 * 512
            cw = sh["check_width"]

            def join_step(indptr, indices, frontier, mult):
                base = dict(probe_cols=probe_cols, n_unary=0,
                            lower_cols=(nb - 1,), upper_cols=(),
                            width=w, n_iter=n_iter, count_only=True,
                            needs_degree=False, unroll=True)
                c1 = _expand_level(
                    indptr, indices, (), frontier[:ct], mult[:ct],
                    jnp.ones((ct,), bool), check_mode="tile",
                    check_width=cw, **base)
                c2 = _expand_level(
                    indptr, indices, (), frontier[ct:], mult[ct:],
                    jnp.ones((c - ct,), bool), **base)
                return c1.sum() + c2.sum()
        elif variant in ("rotate", "rotate2l"):
            # A2: only P-1 non-probe membership checks (rotated from the
            # per-row argmin probe).  A3 (+"2l"): two-level search — most
            # rounds hit the 128x smaller summary array.
            two_level = variant == "rotate2l"
            stride = sh.get("stride", 128)
            kw2 = {}
            if two_level:
                import math as _math
                kw2 = dict(
                    check_mode="bsearch2", summary_stride=stride,
                    n_iter2=int(_math.ceil(_math.log2(2 * stride + 2)))
                    + 1)
                n1 = int(_math.ceil(_math.log2(131072 // stride))) + 1

            def join_step(indptr, indices, frontier, mult, summary=None):
                counts = _expand_level(
                    indptr, indices, (), frontier, mult,
                    jnp.ones((frontier.shape[0],), bool),
                    probe_cols=probe_cols, n_unary=0,
                    lower_cols=(nb - 1,), upper_cols=(), width=w,
                    n_iter=(n1 if two_level else n_iter),
                    count_only=True, needs_degree=False,
                    unroll=True, rotate_checks=True,
                    summary=summary, **kw2)
                return counts.sum()

            if two_level:
                args = (sds((n + 1,), jnp.int32), sds((e,), jnp.int32),
                        sds((c, nb), jnp.int32), sds((c,), jnp.int64),
                        sds((e // stride,), jnp.int32))
                in_sh = named(mesh, (P(), P(), P(dax, None), P(dax), P()))
                flops = c * w * (sh["n_probe"] * 20 * 4 + 8)
                return Cell(self.arch_id, shape_name, "forward",
                            join_step, args, in_shardings=in_sh,
                            out_shardings=named(mesh, P()),
                            model_flops=float(flops),
                            note="vLFTJ level, rotated checks + "
                                 "2-level search")
        else:
            def join_step(indptr, indices, frontier, mult):
                counts = _expand_level(
                    indptr, indices, (), frontier, mult,
                    jnp.ones((frontier.shape[0],), bool),
                    probe_cols=probe_cols, n_unary=0,
                    lower_cols=(nb - 1,), upper_cols=(), width=w,
                    n_iter=n_iter, count_only=True, needs_degree=False,
                    unroll=True)  # straight-line search: honest cost
                return counts.sum()

        args = (sds((n + 1,), jnp.int32), sds((e,), jnp.int32),
                sds((c, nb), jnp.int32), sds((c,), jnp.int64))
        in_sh = named(mesh, (P(), P(), P(dax, None), P(dax)))
        # per candidate: n_probe bsearches x n_iter compares + filters
        flops = c * w * (sh["n_probe"] * n_iter * 4 + 8)
        return Cell(self.arch_id, shape_name, "forward", join_step, args,
                    in_shardings=in_sh, out_shardings=named(mesh, P()),
                    model_flops=float(flops),
                    note="vectorized LFTJ expansion level")

    def smoke(self):
        from ..core import GraphDB, get_query, vlftj_count, lftj_count
        from ..graphs import powerlaw_cluster
        g = powerlaw_cluster(200, 4, seed=0)
        gdb = GraphDB(g, {})
        c = vlftj_count(get_query("3-clique"), gdb)
        ref = lftj_count(get_query("3-clique"), gdb.to_database())
        assert c == ref
        return {"triangles": c}
