"""command-r-plus-104b [hf:CohereForAI]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — no-bias, tied embeddings.

100B-class sharding: FSDP (params/opt sharded over data too),
sequence-parallel residual stream, microbatched grad accumulation,
sequence-chunked LM head (see DESIGN.md §5)."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .common import LMArch

ARCH = LMArch(
    arch_id="command-r-plus-104b",
    cfg=TransformerConfig(
        name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
        n_kv_heads=8, d_ff=33792, vocab_size=256000, rope_frac=1.0,
        act="silu", norm="layernorm", use_bias=False, tie_embeddings=True,
        dtype=jnp.bfloat16, remat=True, fsdp=True, seq_shard=True,
        loss_seq_chunk=512),
    microbatches=8,
    opt_variants={
        # §Perf iterations (EXPERIMENTS.md): B1 drops the explicit q
        # head-shard constraint that triggers SPMD involuntary full
        # rematerialization; B2 donates params+opt (state aliasing);
        # B3 halves the microbatch count (FSDP weight all-gathers are
        # paid per microbatch x layer).
        "train_4k_b1": ("train_4k", dict(attn_head_shard=False)),
        "train_4k_b2": ("train_4k", dict(attn_head_shard=False),
                        dict(donate=True)),
        "train_4k_b3": ("train_4k", dict(attn_head_shard=False),
                        dict(donate=True, microbatches=4)),
    },
)
