"""chatglm3-6b [arXiv:2406.12793]: 28L d_model=4096 32H (GQA kv=2)
d_ff=13696 vocab=65024 — 2D RoPE (rotary on half the head dims), GQA.
KV heads (2) cannot shard a 16-way model axis: replicated (DESIGN.md)."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .common import LMArch

ARCH = LMArch(
    arch_id="chatglm3-6b",
    cfg=TransformerConfig(
        name="chatglm3-6b", n_layers=28, d_model=4096, n_heads=32,
        n_kv_heads=2, d_ff=13696, vocab_size=65024, rope_frac=0.5,
        act="silu", norm="rmsnorm", tie_embeddings=False,
        dtype=jnp.bfloat16, remat=True, loss_seq_chunk=512),
    microbatches=1,
)
