"""Architecture registry: ``--arch <id>`` resolves here."""
from .chatglm3_6b import ARCH as _chatglm3
from .command_r_plus_104b import ARCH as _commandr
from .egnn import ARCH as _egnn
from .gatedgcn import ARCH as _gatedgcn
from .granite_moe_3b_a800m import ARCH as _granite
from .mace import ARCH as _mace
from .moonshot_v1_16b_a3b import ARCH as _moonshot
from .pna import ARCH as _pna
from .stablelm_3b import ARCH as _stablelm
from .wcoj import WCOJArch
from .xdeepfm import ARCH as _xdeepfm

ARCHS = {
    a.arch_id: a for a in [
        _stablelm, _chatglm3, _commandr, _moonshot, _granite,
        _gatedgcn, _egnn, _pna, _mace, _xdeepfm, WCOJArch(),
    ]
}


def get_arch(arch_id: str):
    return ARCHS[arch_id]
