"""Arch-spec machinery: every assigned architecture is a selectable config
(``--arch <id>``) exposing, per input shape, a dry-run *cell*: the jit-able
step function + abstract args (ShapeDtypeStruct, zero allocation) +
in/out shardings for the production mesh.

Families: lm (train/prefill/decode), gnn (full-graph & sampled train),
recsys (train / online / bulk / retrieval), wcoj (the paper's engine).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import transformer as tfm
from ..models.gnn import data as gnn_data
from ..models import xdeepfm as xdf
from ..train.optimizer import OptimizerConfig, init_opt_state
from ..train.loop import make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


@dataclass
class Cell:
    arch: str
    shape_name: str
    kind: str                      # train | prefill | decode | forward
    fn: Callable
    args: tuple
    in_shardings: Any = None
    out_shardings: Any = None
    note: str = ""
    skip: str | None = None       # reason when the cell is n/a
    model_flops: float = 0.0      # 6·N·D (or family equivalent)
    donate: tuple = ()            # argnums donated (state in == state out)
    # cost probes: XLA's cost_analysis counts a lax.scan body ONCE, so
    # scanned-layer models expose probe cells at n_layers=1,2; the dry-run
    # extrapolates cost(L) = c1 + (L-1)·(c2-c1) (exact: cost is linear in
    # L) while memory/compile stats come from the real full program.
    probe_builder: Callable[[int], "Cell"] | None = None
    n_scan: int = 0


def named(mesh, spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _dataxes(mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


@dataclass
class LMArch:
    arch_id: str
    cfg: tfm.TransformerConfig
    microbatches: int = 1
    full_attention: bool = True    # -> long_500k skipped
    shapes: dict = field(default_factory=lambda: dict(LM_SHAPES))
    # §Perf variants: shape name -> (base shape, cfg overrides, extras)
    # extras: microbatches=..., donate=True
    opt_variants: dict = field(default_factory=dict)

    family = "lm"

    def __post_init__(self):
        for name, spec in self.opt_variants.items():
            self.shapes[name] = dict(self.shapes[spec[0]], base=spec[0])

    def reduced_cfg(self) -> tfm.TransformerConfig:
        moe = self.cfg.moe
        if moe is not None:
            moe = replace(moe, n_experts=8, top_k=min(2, moe.top_k),
                          d_ff_expert=64,
                          n_shared_experts=min(1, moe.n_shared_experts))
        return replace(
            self.cfg, n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(4, self.cfg.n_kv_heads)),
            d_head=16, d_ff=128, vocab_size=512, moe=moe,
            dtype=jnp.float32, fsdp=False, seq_shard=False,
            loss_seq_chunk=0, max_cache_len=64)

    def _abstract_params(self, cfg):
        return jax.eval_shape(
            lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))

    def cell(self, shape_name: str, mesh) -> Cell:
        cfg = self.cfg
        micro = self.microbatches
        extras = {}
        if shape_name in self.opt_variants:
            spec = self.opt_variants[shape_name]
            cfg = replace(cfg, **spec[1])
            extras = spec[2] if len(spec) > 2 else {}
            micro = extras.get("microbatches", micro)
        c = self._cell_inner(shape_name, mesh, cfg, micro)
        if extras.get("donate") and c.skip is None:
            c.donate = (0, 1) if c.kind == "train" else (1,)
        if c.skip is None and cfg.n_layers > 2:
            # probes unroll BOTH loops (layers=1,2; all microbatches) so
            # per-microbatch collectives are counted
            c.probe_builder = lambda n: self._cell_inner(
                shape_name, mesh,
                replace(cfg, n_layers=n, loss_seq_chunk=0), micro,
                unroll_micro=True)
            c.n_scan = cfg.n_layers
        return c

    def _cell_inner(self, shape_name: str, mesh, cfg,
                    microbatches: int, unroll_micro: bool = False) -> Cell:
        sh = self.shapes[shape_name]
        if shape_name == "long_500k" and self.full_attention:
            return Cell(self.arch_id, shape_name, sh["kind"], None, (),
                        skip="pure full-attention arch: 500k decode needs "
                             "sub-quadratic attention (see DESIGN.md)")
        seq, batch = sh["seq"], sh["batch"]
        pspecs = tfm.param_specs(cfg)
        params = self._abstract_params(cfg)
        psh = named(mesh, pspecs)
        dax = _dataxes(mesh)
        mf = 6.0 * cfg.n_active_params * batch * seq
        if sh["kind"] == "train":
            opt = jax.eval_shape(init_opt_state, params)
            opt_sh = named(mesh, {
                "m": pspecs, "v": pspecs, "step": P()})
            batch_abs = {"tokens": sds((batch, seq), jnp.int32),
                         "labels": sds((batch, seq), jnp.int32)}
            bsh = named(mesh, {"tokens": P(dax, None),
                               "labels": P(dax, None)})
            ocfg = OptimizerConfig()
            step = make_train_step(
                lambda p, b: tfm.loss_fn(p, b, cfg, mesh), ocfg,
                microbatches, unroll_micro=unroll_micro)
            return Cell(self.arch_id, shape_name, "train", step,
                        (params, opt, batch_abs),
                        in_shardings=(psh, opt_sh, bsh),
                        out_shardings=(psh, opt_sh, None),
                        model_flops=mf)
        if sh["kind"] == "prefill":
            toks = sds((batch, seq), jnp.int32)
            csp = tfm.cache_specs(cfg, mesh)
            fn = lambda p, t: tfm.prefill(p, t, cfg, mesh, max_len=seq)
            out_sh = (named(mesh, csp),
                      named(mesh, P(dax, None, "model")))
            return Cell(self.arch_id, shape_name, "prefill", fn,
                        (params, toks),
                        in_shardings=(psh, named(mesh, P(dax, None))),
                        out_shardings=out_sh,
                        model_flops=2.0 * cfg.n_active_params * batch * seq)
        # decode: one new token against a seq-length cache
        csp = tfm.cache_specs(cfg, mesh)
        cache = {
            "k": sds((cfg.n_layers, batch, cfg.n_kv_heads, seq,
                      cfg.head_dim), cfg.dtype),
            "v": sds((cfg.n_layers, batch, cfg.n_kv_heads, seq,
                      cfg.head_dim), cfg.dtype),
            "len": sds((), jnp.int32),
        }
        toks = sds((batch, 1), jnp.int32)
        fn = lambda p, c, t: tfm.decode_step(p, c, t, cfg, mesh)
        return Cell(self.arch_id, shape_name, "decode", fn,
                    (params, cache, toks),
                    in_shardings=(psh, named(mesh, csp),
                                  named(mesh, P(dax, None))),
                    out_shardings=(named(mesh, P(dax, None, "model")),
                                   named(mesh, csp)),
                    model_flops=2.0 * cfg.n_active_params * batch)

    def smoke(self):
        cfg = self.reduced_cfg()
        p = tfm.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        loss, grads = jax.value_and_grad(
            lambda pp: tfm.loss_fn(pp, batch, cfg))(p)
        assert np.isfinite(float(loss)), self.arch_id
        for g in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(g)).all(), self.arch_id
        cache, logits = tfm.prefill(p, toks, cfg, max_len=32)
        assert logits.shape == (2, 1, cfg.vocab_size)
        lg, c2 = tfm.decode_step(p, cache, toks[:, :1], cfg)
        assert lg.shape == (2, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(lg)).all()
        return {"loss": float(loss)}


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433),
    "minibatch_lg": dict(kind="train", n_nodes=232965, n_edges=114615892,
                         batch_nodes=1024, fanouts=(15, 10), d_feat=602),
    "ogb_products": dict(kind="train", n_nodes=2449029, n_edges=61859140,
                         d_feat=100),
    "molecule": dict(kind="train", n_nodes=30, n_edges=64, batch=128,
                     d_feat=16),
}


@dataclass
class GNNArch:
    arch_id: str
    make_cfg: Callable[[int, int], Any]   # (d_in, n_classes) -> cfg
    init_fn: Callable
    loss_fn: Callable                     # (params, GraphBatch, cfg)
    needs_coords: bool = False
    scan_layers: bool = False             # model scans layers -> cost probe
    shapes: dict = field(default_factory=lambda: dict(GNN_SHAPES))
    # §Perf variants: extra shape name -> (base shape, cfg overrides)
    opt_variants: dict = field(default_factory=dict)

    family = "gnn"

    def __post_init__(self):
        for name, spec in self.opt_variants.items():
            extra = spec[2] if len(spec) > 2 else {}
            self.shapes[name] = dict(self.shapes[spec[0]], base=spec[0],
                                     **extra)

    def _batch_abs(self, shape_name):
        sh = self.shapes[shape_name]
        if shape_name == "minibatch_lg":
            b, f1, f2 = sh["batch_nodes"], *sh["fanouts"]
            n = b + b * f1 + b * f1 * f2
            e = 2 * (b * f1 + b * f1 * f2)
            n_graphs = 1
        elif shape_name == "molecule":
            n = sh["n_nodes"] * sh["batch"]
            e = 2 * sh["n_edges"] * sh["batch"]
            n_graphs = sh["batch"]
        else:
            n, e = sh["n_nodes"], 2 * sh["n_edges"]
            n_graphs = 1
        # edge arrays shard over (pod, data): pad to the 512 = lcm(32, 16)
        # boundary (dummy self-loops on the sink node, as pad_graph does)
        e = -(-e // 512) * 512
        if sh.get("pad_nodes"):  # node-sharded variants need divisibility
            n = -(-n // 512) * 512
        d = sh["d_feat"]
        batch = {
            "src": sds((e,), jnp.int32),
            "dst": sds((e,), jnp.int32),
            "node_feat": sds((n, d), jnp.float32),
            "labels": sds((n,), jnp.int32),
        }
        if self.needs_coords:
            batch["coords"] = sds((n, 3), jnp.float32)
            batch["graph_id"] = sds((n,), jnp.int32)
        return batch, n, e, n_graphs, d

    def _to_graph(self, batch, n, n_graphs):
        return gnn_data.GraphBatch(
            src=batch["src"], dst=batch["dst"], n_nodes=n,
            node_feat=batch["node_feat"], labels=batch["labels"],
            coords=batch.get("coords"), graph_id=batch.get("graph_id"),
            n_graphs=n_graphs)

    def cell(self, shape_name: str, mesh) -> Cell:
        cfg0 = self.make_cfg(self.shapes[shape_name]["d_feat"], 16)
        if shape_name in self.opt_variants:
            cfg0 = replace(cfg0, **self.opt_variants[shape_name][1])
        c = self._cell_inner(shape_name, mesh, cfg0)
        if self.scan_layers and getattr(cfg0, "n_layers", 0) > 2:
            c.probe_builder = lambda nl: self._cell_inner(
                shape_name, mesh, replace(cfg0, n_layers=nl))
            c.n_scan = cfg0.n_layers
        return c

    def _cell_inner(self, shape_name: str, mesh, cfg) -> Cell:
        batch_abs, n, e, n_graphs, d = self._batch_abs(shape_name)
        params = jax.eval_shape(
            lambda: self.init_fn(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(init_opt_state, params)
        dax = _dataxes(mesh)
        edge_spec = P(dax)
        bsp = {k: (edge_spec if k in ("src", "dst") else P())
               for k in batch_abs}
        ocfg = OptimizerConfig()
        step = make_train_step(
            lambda p, b: self.loss_fn(p, self._to_graph(b, n, n_graphs),
                                      cfg), ocfg)
        rep = jax.tree.map(lambda _: P(), params)
        osh = {"m": rep, "v": rep, "step": P()}
        # message FLOPs estimate: edges x d x d per layer x 3 passes (fwd+bwd)
        layers = getattr(cfg, "n_layers", 2)
        dh = getattr(cfg, "d_hidden", 64)
        mf = 6.0 * e * dh * dh * layers
        return Cell(self.arch_id, shape_name, "train", step,
                    (params, opt, batch_abs),
                    in_shardings=(named(mesh, rep), named(mesh, osh),
                                  named(mesh, bsp)),
                    out_shardings=(named(mesh, rep), named(mesh, osh),
                                   None),
                    model_flops=mf)

    def smoke(self):
        g = gnn_data.random_graph_batch(
            64, 256, 16, seed=0, coords=True, n_graphs=4, n_classes=16)
        cfg = self.make_cfg(16, 16)
        p = self.init_fn(jax.random.PRNGKey(0), cfg)
        loss, grads = jax.value_and_grad(
            lambda pp: self.loss_fn(pp, g, cfg))(p)
        assert np.isfinite(float(loss)), self.arch_id
        for gr in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(gr)).all(), self.arch_id
        return {"loss": float(loss)}


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="forward", batch=512),
    "serve_bulk": dict(kind="forward", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000),
}


@dataclass
class RecsysArch:
    arch_id: str
    cfg: xdf.XDeepFMConfig
    shapes: dict = field(default_factory=lambda: dict(RECSYS_SHAPES))

    family = "recsys"

    def reduced_cfg(self):
        return replace(self.cfg, vocab_per_field=1000,
                       cin_layers=(16, 16), mlp_dims=(32, 32))

    def cell(self, shape_name: str, mesh) -> Cell:
        sh = self.shapes[shape_name]
        cfg = self.cfg
        params = jax.eval_shape(
            lambda: xdf.init_xdeepfm(jax.random.PRNGKey(0), cfg))
        pspec = jax.tree.map(lambda _: P(), params)
        pspec["embed"] = P("model", None)      # row-sharded table
        pspec["linear"] = P("model", None)
        psh = named(mesh, pspec)
        dax = _dataxes(mesh)
        f = cfg.n_sparse
        d = cfg.embed_dim
        cin_fl = sum(cfg.cin_layers) * f * d * 200  # rough per-sample
        if sh["kind"] == "train":
            b = sh["batch"]
            batch_abs = {"ids": sds((b, f), jnp.int32),
                         "labels": sds((b,), jnp.int32)}
            opt = jax.eval_shape(init_opt_state, params)
            osh = named(mesh, {"m": pspec, "v": pspec, "step": P()})
            step = make_train_step(
                lambda p, bb: xdf.xdeepfm_loss(p, bb, cfg),
                OptimizerConfig())
            return Cell(self.arch_id, shape_name, "train", step,
                        (params, opt, batch_abs),
                        in_shardings=(psh, osh,
                                      named(mesh, {"ids": P(dax, None),
                                                   "labels": P(dax)})),
                        out_shardings=(psh, osh, None),
                        model_flops=6.0 * sh["batch"] * cin_fl)
        if sh["kind"] == "forward":
            b = sh["batch"]
            ids = sds((b, f), jnp.int32)
            fn = lambda p, i: xdf.xdeepfm_forward(p, i, cfg)
            return Cell(self.arch_id, shape_name, "forward", fn,
                        (params, ids),
                        in_shardings=(psh, named(mesh, P(dax, None))),
                        out_shardings=named(mesh, P(dax)),
                        model_flops=2.0 * b * cin_fl)
        # retrieval: 1 query x 1M candidates
        nc = sh["n_candidates"]
        fn = lambda p, q, c: xdf.retrieval_scores(p, q, c, cfg)
        return Cell(self.arch_id, shape_name, "retrieval", fn,
                    (params, sds((1, f), jnp.int32),
                     sds((nc,), jnp.int32)),
                    in_shardings=(psh, named(mesh, P(None, None)),
                                  named(mesh, P(dax))),
                    out_shardings=named(mesh, P(dax)),
                    model_flops=2.0 * nc * d)

    def smoke(self):
        cfg = self.reduced_cfg()
        p = xdf.init_xdeepfm(jax.random.PRNGKey(0), cfg)
        ids = jax.random.randint(jax.random.PRNGKey(1), (32, cfg.n_sparse),
                                 0, cfg.vocab_per_field)
        batch = {"ids": ids,
                 "labels": jnp.zeros((32,), jnp.int32)}
        loss, grads = jax.value_and_grad(
            lambda pp: xdf.xdeepfm_loss(pp, batch, cfg))(p)
        assert np.isfinite(float(loss))
        s = xdf.retrieval_scores(p, ids[:1], jnp.arange(100), cfg)
        assert np.isfinite(np.asarray(s)).all()
        return {"loss": float(loss)}
