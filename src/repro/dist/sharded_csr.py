"""Row-partitioned CSR: joins over graphs too large to replicate.

``spmd_join_step`` replicates the whole CSR on every device — fine until
the graph outgrows a device's HBM.  :class:`ShardedGraphDB` splits the
node domain into ``n_shards`` contiguous, edge-balanced ranges; shard
``s`` stores only its own rows (a local ``indptr`` rebased to 0 plus the
matching ``indices`` slice) and an owner map (the range ``bounds``) says
which shard serves any vertex.

Two executions consume the layout:

* :func:`sharded_count` — the host-level reference driver.  A full
  vectorized-LFTJ level loop in which *every* adjacency access goes
  through :meth:`ShardedGraphDB.gather_segments` /
  :meth:`~ShardedGraphDB.degrees_of`, i.e. only per-shard arrays are
  ever touched and cross-shard traffic is metered in
  ``ShardedGraphDB.exchange`` — the oracle the parity tests compare
  against the replicated engines on every tier-1 query shape.
* :func:`spmd_sharded_join_step` — the device-level SPMD expansion.
  Each device holds one shard's block; per level the frontier's probe
  and check adjacencies are collected during an ``n_shards``-hop
  ``ppermute`` ring rotation of the CSR blocks (the same ring wiring as
  ``dist.overlap.ring_all_reduce`` — :func:`~repro.dist.overlap
  .ring_schedule`), membership checks run as dense tile compares against
  the gathered segments, and one ``psum`` folds the counts.  Peak memory
  per device is one CSR shard (plus the in-flight neighbor block), not
  the whole graph.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from ..core.plan import GraphStats, JoinPlan, compile_levels
from ..core.query import Query
from ..graphs.csr import CSRGraph, degrees_from_indptr
from .overlap import ring_schedule


class ShardedGraphDB:
    """Row-partitioned CSR + replicated unary sets.

    Shard ``s`` owns the contiguous node range ``[bounds[s],
    bounds[s+1])``, chosen so shard *edge* counts balance (a degree-sorted
    split would balance better under extreme skew but break the
    contiguous owner map the device exchange needs).  Unary predicates
    stay replicated — they are node bitmaps, small next to the adjacency.

    ``exchange`` meters the traffic a real deployment would put on the
    interconnect: ``gathers`` counts vectorized gather rounds (each maps
    to one ring rotation on devices) and ``values`` the adjacency
    entries shipped.
    """

    def __init__(self, csr: CSRGraph, n_shards: int,
                 unary: dict[str, np.ndarray] | None = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        self.n_nodes = csr.n_nodes
        self.n_edges = csr.n_edges
        targets = np.linspace(0, csr.indices.shape[0], n_shards + 1)
        bounds = np.searchsorted(csr.indptr, targets[1:-1], side="left")
        self.bounds = np.concatenate(
            [[0], np.maximum.accumulate(bounds), [csr.n_nodes]]
        ).astype(np.int64)
        self.local_indptr: list[np.ndarray] = []
        self.local_indices: list[np.ndarray] = []
        for s in range(n_shards):
            lo, hi = self.bounds[s], self.bounds[s + 1]
            iptr = csr.indptr[lo:hi + 1] - csr.indptr[lo]
            self.local_indptr.append(iptr.astype(np.int64))
            self.local_indices.append(
                csr.indices[csr.indptr[lo]:csr.indptr[hi]].astype(np.int64))
        self.unary = {k: np.asarray(v) for k, v in (unary or {}).items()}
        self.exchange = {"gathers": 0, "values": 0}

    # -- owner map -----------------------------------------------------------
    def owner_of(self, values: np.ndarray) -> np.ndarray:
        """Shard id owning each vertex."""
        v = np.asarray(values, dtype=np.int64)
        return np.searchsorted(self.bounds, v, side="right") - 1

    @property
    def shard_sizes(self) -> list[tuple[int, int]]:
        """Per-shard (nodes, edges) — the replication this layout avoids."""
        return [(int(self.bounds[s + 1] - self.bounds[s]),
                 int(self.local_indices[s].shape[0]))
                for s in range(self.n_shards)]

    # -- sharded accessors (all adjacency IO goes through these) -------------
    def degrees_of(self, values: np.ndarray) -> np.ndarray:
        """Degree lookup via each vertex's owning shard."""
        v = np.asarray(values, dtype=np.int64).ravel()
        owner = self.owner_of(v)
        deg = np.zeros(v.shape[0], dtype=np.int64)
        for s in range(self.n_shards):
            m = owner == s
            if not m.any():
                continue
            li = v[m] - self.bounds[s]
            iptr = self.local_indptr[s]
            deg[m] = iptr[li + 1] - iptr[li]
        self.exchange["gathers"] += 1
        return deg.reshape(np.asarray(values).shape)

    def gather_segments(self, values: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Adjacency of each vertex, row-major flattened.

        Returns ``(deg (R,), flat (deg.sum(),), reps (deg.sum(),))``:
        segment ``i`` occupies ``flat[offs[i]:offs[i+1]]`` (sorted, since
        shard slices preserve CSR order) and ``reps`` maps flat entries
        back to rows.  Host stand-in for one ring rotation: each shard
        contributes exactly the rows it owns.
        """
        v = np.asarray(values, dtype=np.int64).ravel()
        owner = self.owner_of(v)
        deg = np.zeros(v.shape[0], dtype=np.int64)
        starts = np.zeros(v.shape[0], dtype=np.int64)
        for s in range(self.n_shards):
            m = owner == s
            if not m.any():
                continue
            li = v[m] - self.bounds[s]
            iptr = self.local_indptr[s]
            starts[m] = iptr[li]
            deg[m] = iptr[li + 1] - iptr[li]
        total = int(deg.sum())
        flat = np.empty(total, dtype=np.int64)
        offs = np.concatenate([[0], np.cumsum(deg)])
        reps = np.repeat(np.arange(v.shape[0]), deg)
        pos = np.arange(total) - np.repeat(offs[:-1], deg)
        src = starts[reps] + pos
        own = owner[reps]
        for s in range(self.n_shards):
            m = own == s
            if m.any():
                flat[m] = self.local_indices[s][src[m]]
        self.exchange["gathers"] += 1
        self.exchange["values"] += total
        return deg, flat, reps

    # -- planner / device bridges --------------------------------------------
    def graph_stats(self) -> GraphStats:
        """Planner stats from shard metadata alone (no reassembly)."""
        max_deg = max((int(degrees_from_indptr(iptr).max(initial=0))
                       for iptr in self.local_indptr), default=0)
        n = max(1, self.n_nodes)
        return GraphStats(
            n_nodes=self.n_nodes, n_edges=self.n_edges,
            max_degree=max_deg, avg_degree=self.n_edges / n,
            unary_sizes=tuple(sorted(
                (name, int(len(ids))) for name, ids in self.unary.items())))

    def replicated(self) -> CSRGraph:
        """Reassembled full CSR — for parity tests only."""
        indptr = [np.zeros(1, dtype=np.int64)]
        off = 0
        for s in range(self.n_shards):
            indptr.append(self.local_indptr[s][1:] + off)
            off += int(self.local_indices[s].shape[0])
        return CSRGraph(indptr=np.concatenate(indptr),
                        indices=np.concatenate(self.local_indices)
                        if self.local_indices else np.zeros(0, np.int64),
                        n_nodes=self.n_nodes)

    def device_blocks(self) -> dict:
        """Uniformly padded per-shard blocks for the SPMD ring step.

        ``indptr`` (S, Ln+1) is end-padded with its last value (padding
        nodes read as degree 0); ``indices`` (S, Le) is zero-padded.
        """
        ln = max(self.bounds[s + 1] - self.bounds[s]
                 for s in range(self.n_shards))
        le = max(1, max((idx.shape[0] for idx in self.local_indices),
                        default=1))
        indptr = np.zeros((self.n_shards, ln + 1), dtype=np.int32)
        indices = np.zeros((self.n_shards, le), dtype=np.int32)
        for s in range(self.n_shards):
            iptr = self.local_indptr[s]
            indptr[s, :iptr.shape[0]] = iptr
            indptr[s, iptr.shape[0]:] = iptr[-1]
            idx = self.local_indices[s]
            indices[s, :idx.shape[0]] = idx
        return {"indptr": indptr, "indices": indices,
                "bounds": self.bounds.astype(np.int32)}


def _segment_member(deg_s, flat_s, reps_s, cand, cand_rows,
                    n_nodes: int) -> np.ndarray:
    """Membership of ``cand`` (row ``cand_rows``) in per-row sorted
    segments, via one global searchsorted over row-disjoint keys."""
    keys_seg = reps_s * n_nodes + flat_s          # globally ascending
    keys_c = cand_rows * n_nodes + cand
    idx = np.searchsorted(keys_seg, keys_c)
    ok = idx < keys_seg.shape[0]
    found = np.zeros(cand.shape[0], dtype=bool)
    found[ok] = keys_seg[idx[ok]] == keys_c[ok]
    return found


def sharded_count(query: Query, sgdb: ShardedGraphDB,
                  plan: JoinPlan | None = None,
                  chunk_rows: int = 8192) -> int:
    """Full WCOJ count touching the CSR only through shard-local arrays.

    Mirrors the vectorized-LFTJ level semantics (min-degree probe,
    membership checks, unary bitmaps, ``<`` filters, degree pruning) with
    every adjacency read routed through the sharded accessors, so its
    result equals the replicated engines' exactly while
    ``sgdb.exchange`` records the cross-shard traffic.
    """
    if plan is None:
        from ..core.planner import plan_query
        plan = plan_query(query, sgdb.graph_stats(), engine="vlftj")
    levels = plan.levels or compile_levels(query, plan.gao)
    n = sgdb.n_nodes
    bitmap: dict[str, np.ndarray] = {}
    for name, ids in sgdb.unary.items():
        bm = np.zeros(n, dtype=bool)
        bm[ids[ids < n]] = True
        bitmap[name] = bm

    def domain(lp) -> np.ndarray:
        if lp.unary:
            base = min((sgdb.unary[u] for u in lp.unary), key=len)
            vals = np.unique(np.asarray(base, dtype=np.int64))
            vals = vals[vals < n]
        else:
            vals = np.arange(n, dtype=np.int64)
        for u in lp.unary:
            vals = vals[bitmap[u][vals]]
        if lp.needs_degree:
            vals = vals[sgdb.degrees_of(vals) > 0]
        return vals

    k = len(levels)
    # trace hook: per-level exchange deltas (gathers / adjacency values
    # shipped) become 'exchange' events on the active trace — pure host
    # counter reads, mirroring what a real interconnect would carry
    from ..obs import current_trace
    tr = current_trace()

    def note_level(level: int, rows: int, g0: int, v0: int) -> None:
        if tr is None:
            return
        dg = sgdb.exchange["gathers"] - g0
        dv = sgdb.exchange["values"] - v0
        tr.level(level, obs_rows=rows,
                 var=plan.gao[level] if level < len(plan.gao) else None,
                 est_rows=(plan.level_est_rows[level]
                           if level < len(plan.level_est_rows) else None))
        tr.event("exchange", level=level, gathers=dg, values=dv,
                 bytes=dv * 8)

    frontier = domain(levels[0])[:, None]
    note_level(0, int(frontier.shape[0]),
               sgdb.exchange["gathers"], sgdb.exchange["values"])
    if k == 1:
        return int(frontier.shape[0])
    total = 0
    for level in range(1, k):
        g0, v0 = sgdb.exchange["gathers"], sgdb.exchange["values"]
        lp = levels[level]
        last = level == k - 1
        if frontier.shape[0] == 0:
            return total if last else 0
        if not lp.edge_sources:
            vals = domain(lp)
            if last and not lp.lower and not lp.upper:
                add = int(frontier.shape[0]) * int(vals.shape[0])
                note_level(level, total + add, g0, v0)
                return total + add
            reps = np.repeat(np.arange(frontier.shape[0]), vals.shape[0])
            cand = np.tile(vals, frontier.shape[0])
            ok = np.ones(cand.shape[0], dtype=bool)
            for col in lp.lower:
                ok &= cand > frontier[reps, col]
            for col in lp.upper:
                ok &= cand < frontier[reps, col]
            if last:
                note_level(level, total + int(ok.sum()), g0, v0)
                return total + int(ok.sum())
            frontier = np.concatenate(
                [frontier[reps[ok]], cand[ok][:, None]], axis=1)
            note_level(level, int(frontier.shape[0]), g0, v0)
            continue
        srcs = list(lp.edge_sources)
        out_parts: list[np.ndarray] = []
        for s0 in range(0, frontier.shape[0], chunk_rows):
            chunk = frontier[s0:s0 + chunk_rows]
            xs = chunk[:, srcs]                              # (C, P)
            deg = sgdb.degrees_of(xs)
            p = np.argmin(deg, axis=1)
            probe = np.take_along_axis(xs, p[:, None], axis=1)[:, 0]
            dstar, cand, reps = sgdb.gather_segments(probe)
            keep = np.ones(cand.shape[0], dtype=bool)
            for ci in range(len(srcs)):
                # gather check segments only for rows whose probe is a
                # DIFFERENT column — the probe column's adjacency is the
                # candidate set itself, already shipped (and its rows'
                # membership is trivially true)
                need_rows = np.flatnonzero(p != ci)
                if need_rows.size == 0:
                    continue
                seg = sgdb.gather_segments(xs[need_rows, ci])
                mask_c = (p != ci)[reps]
                comp = np.searchsorted(need_rows, reps[mask_c])
                keep[mask_c] &= _segment_member(*seg, cand[mask_c],
                                                comp, n)
            for u in lp.unary:
                keep &= bitmap[u][cand]
            for col in lp.lower:
                keep &= cand > chunk[reps, col]
            for col in lp.upper:
                keep &= cand < chunk[reps, col]
            if lp.needs_degree:
                keep &= sgdb.degrees_of(cand) > 0
            if last:
                total += int(keep.sum())
            else:
                out_parts.append(np.concatenate(
                    [chunk[reps[keep]], cand[keep][:, None]], axis=1))
        if last:
            note_level(level, total, g0, v0)
            return total
        frontier = (np.concatenate(out_parts, axis=0) if out_parts
                    else np.zeros((0, frontier.shape[1] + 1), np.int64))
        note_level(level, int(frontier.shape[0]), g0, v0)
    return total


# ---------------------------------------------------------------------------
# device-level SPMD ring step
# ---------------------------------------------------------------------------

def spmd_sharded_join_step(mesh, level_kw: dict, sgdb: ShardedGraphDB,
                           axis_names=None):
    """Sharded-CSR counterpart of :func:`~repro.dist.sharded_join
    .spmd_join_step`: one expansion level over ``mesh`` with **no CSR
    replication**.

    Each device holds one shard's padded ``(indptr, indices)`` block
    (``ShardedGraphDB.device_blocks``).  The frontier is row-sharded as
    usual; probe/check adjacency that lives on other shards is collected
    while the CSR blocks rotate around a ``ppermute`` ring (the
    :func:`~repro.dist.overlap.ring_schedule` wiring — after hop ``s``
    device ``me`` holds shard ``(me - s) % S``'s block, so ``S`` hops see
    every row).  Membership checks binary-search the gathered, per-row
    sorted segment tiles.  The returned function maps ``(frontier,
    mult)`` to the global weighted count — frontiers of any length (the
    wrapper pads to the shard multiple and zeroes the padding's
    ``mult``).  ``sgdb.n_shards`` must equal the ring size, and unary
    bitmaps are not supported (pre-filter the frontier; the replicated
    step has the same contract).
    """
    axes = tuple(mesh.axis_names) if axis_names is None else tuple(axis_names)
    if len(axes) != 1:
        raise ValueError("the sharded-CSR ring rotates over exactly one "
                         "mesh axis; pass axis_names=('data',)")
    axis = axes[0]
    n_dev = int(mesh.shape[axis])
    if sgdb.n_shards != n_dev:
        raise ValueError(f"graph is sharded {sgdb.n_shards} ways but the "
                         f"mesh axis {axis!r} has {n_dev} devices")
    if level_kw.get("n_unary", 0):
        raise ValueError("unary bitmaps are replicated; pre-filter the "
                         "frontier instead")
    blocks = sgdb.device_blocks()
    bounds = jnp.asarray(blocks["bounds"])
    probe_cols = tuple(level_kw["probe_cols"])
    lower_cols = tuple(level_kw.get("lower_cols", ()))
    upper_cols = tuple(level_kw.get("upper_cols", ()))
    width = int(level_kw["width"])
    needs_degree = bool(level_kw.get("needs_degree", False))
    n_iter = int(math.ceil(math.log2(max(2, width)))) + 1
    sentinel = np.int32(sgdb.n_nodes)    # > any vertex id

    def ring_deg_tiles(xs, iptr, idx, me, perm, want_tiles: bool):
        """Rotate the CSR blocks; collect degree (and segment tiles) for
        every vertex in ``xs``, whichever shard owns it."""
        ln = iptr.shape[0] - 1
        le = idx.shape[0]
        j = jnp.arange(width, dtype=jnp.int32)
        degs = jnp.zeros(xs.shape, jnp.int32)
        tiles = (jnp.full(xs.shape + (width,), sentinel, jnp.int32)
                 if want_tiles else None)
        cur_iptr, cur_idx = iptr, idx
        for s in range(sgdb.n_shards):
            sid = (me - s) % sgdb.n_shards
            lo, hi = bounds[sid], bounds[sid + 1]
            mine = (xs >= lo) & (xs < hi)
            li = jnp.clip(xs - lo, 0, max(0, ln - 1))
            st = cur_iptr[li]
            dg = cur_iptr[li + 1] - st
            degs = jnp.where(mine, dg, degs)
            if want_tiles:
                tl = cur_idx[jnp.clip(st[..., None] + j, 0, le - 1)]
                valid = j < dg[..., None]
                tl = jnp.where(valid, tl, sentinel)
                tiles = jnp.where(mine[..., None], tl, tiles)
            if s < sgdb.n_shards - 1:
                cur_iptr = jax.lax.ppermute(cur_iptr, axis, perm)
                if want_tiles:
                    cur_idx = jax.lax.ppermute(cur_idx, axis, perm)
        return degs, tiles

    def local_step(indptr_blk, indices_blk, frontier, mult):
        iptr, idx = indptr_blk[0], indices_blk[0]
        me = jax.lax.axis_index(axis)
        _, perm = ring_schedule(axis)
        xs = frontier[:, list(probe_cols)]                       # (C, P)
        degs, tiles = ring_deg_tiles(xs, iptr, idx, me, perm, True)
        p = jnp.argmin(degs, axis=1)
        cand = jnp.take_along_axis(tiles, p[:, None, None], axis=1)[:, 0]
        dstar = jnp.take_along_axis(degs, p[:, None], axis=1)
        keep = jnp.arange(width, dtype=jnp.int32)[None, :] < dstar
        for ci in range(len(probe_cols)):
            # sentinel-padded rows stay sorted: binary-search each
            # candidate in the gathered check segment
            seg = tiles[:, ci]                                   # (C, W)
            lo = jnp.zeros(cand.shape, jnp.int32)
            hi = jnp.full(cand.shape, width, jnp.int32)
            for _ in range(n_iter):
                mid = (lo + hi) // 2
                mv = jnp.take_along_axis(
                    seg, jnp.clip(mid, 0, width - 1), axis=1)
                go = mv < cand
                lo = jnp.where(go, mid + 1, lo)
                hi = jnp.where(go, hi, mid)
            at = jnp.take_along_axis(seg, jnp.clip(lo, 0, width - 1),
                                     axis=1)
            found = at == cand
            keep &= jnp.where((p == ci)[:, None], True, found)
        for col in lower_cols:
            keep &= cand > frontier[:, col][:, None]
        for col in upper_cols:
            keep &= cand < frontier[:, col][:, None]
        if needs_degree:
            # second ring pass, starting again from the home blocks
            # (ring_deg_tiles never mutates its inputs)
            degc, _ = ring_deg_tiles(jnp.clip(cand, 0, sentinel - 1),
                                     iptr, idx, me, perm, False)
            keep &= (degc > 0) & (cand < sentinel)
        counts = keep.sum(axis=1).astype(jnp.int64) * mult
        return jax.lax.psum(counts.sum(), axis)

    spec = PartitionSpec(axis)
    jitted = jax.jit(jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(spec, spec, spec, spec), out_specs=PartitionSpec(),
        check_vma=False))
    indptr_j = jnp.asarray(blocks["indptr"])
    indices_j = jnp.asarray(blocks["indices"])

    def step(frontier, mult):
        frontier = np.asarray(frontier, dtype=np.int32)
        mult = np.asarray(mult, dtype=np.int64)
        pad = (-frontier.shape[0]) % n_dev
        if pad:
            frontier = np.pad(frontier, ((0, pad), (0, 0)))
            mult = np.pad(mult, (0, pad))
        return int(jitted(indptr_j, indices_j, jnp.asarray(frontier),
                          jnp.asarray(mult)))

    step.n_shards = n_dev
    return step
