"""Collective scheduling: ring all-reduce and compute/communication overlap.

Both primitives are written to run *inside* ``shard_map`` (they use the
named-axis collectives), and both exist to keep the interconnect busy
while the VPU works:

* :func:`ring_all_reduce` — the classic bandwidth-optimal two-phase ring
  (reduce-scatter then all-gather over ``n`` chunks via ``ppermute``):
  each device sends ``2 (n-1)/n`` of the payload regardless of ``n``,
  versus ``log n`` full-payload rounds for a naive tree.
* :func:`overlapped_reduce_apply` — chunked gradient reduction pipelined
  against the parameter update: chunk ``i+1``'s ``psum`` is issued before
  chunk ``i``'s update runs, so XLA's async collectives hide the reduce
  latency behind the elementwise apply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _axis_size(axis_name) -> int:
    # psum of a Python literal constant-folds to a static int under
    # shard_map tracing — the documented way to read a named axis size.
    return jax.lax.psum(1, axis_name)


def ring_schedule(axis_name) -> tuple[int, list[tuple[int, int]]]:
    """(ring size, ppermute permutation) for a one-hop rotation.

    The single source of the ring wiring: :func:`ring_all_reduce` and the
    sharded-CSR adjacency exchange (``dist.sharded_csr``) both rotate
    payloads device ``i`` -> ``i+1`` with this permutation, so after hop
    ``s`` device ``me`` holds the block that started on ``(me - s) % n``.
    """
    n = _axis_size(axis_name)
    return n, [(i, (i + 1) % n) for i in range(n)]


def ring_all_reduce(x, axis_name):
    """Sum ``x`` across ``axis_name`` with a two-phase ppermute ring.

    The local block is split into ``n`` chunks (padded to divide); after
    ``n-1`` reduce-scatter hops device ``i`` owns the full sum of chunk
    ``(i+1) % n``, and ``n-1`` all-gather hops replicate every chunk.
    Returns the all-reduced block, same shape as ``x``, on every device.
    """
    n, perm = ring_schedule(axis_name)
    if n == 1:
        return x
    rows = x.shape[0]
    pad = (-rows) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    chunks = xp.reshape((n, (rows + pad) // n) + x.shape[1:])
    me = jax.lax.axis_index(axis_name)

    def chunk(j):
        return jnp.take(chunks, j, axis=0)

    # reduce-scatter: after step s, this device holds the partial sum of
    # chunk (me - s - 1) over devices {me - s - 1, ..., me}.
    part = chunk(me)
    for s in range(n - 1):
        part = jax.lax.ppermute(part, axis_name, perm)
        part = part + chunk((me - s - 1) % n)
    # all-gather: circulate the owned chunk (me + 1) % n around the ring.
    full = jnp.zeros_like(chunks)
    full = full.at[(me + 1) % n].set(part)
    cur = part
    for s in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        full = full.at[(me - s) % n].set(cur)
    out = full.reshape((rows + pad,) + x.shape[1:])
    return out[:rows]


def overlapped_reduce_apply(grads, params, axis_name, apply_fn,
                            n_chunks: int = 4):
    """Chunked ``psum(grads)`` pipelined against ``apply_fn``.

    Splits ``grads``/``params`` into ``n_chunks`` along axis 0 and, for
    each chunk, issues the *next* chunk's ``psum`` before applying
    ``apply_fn(param_chunk, reduced_grad_chunk)`` to the current one —
    the apply of chunk ``i`` overlaps the reduction of chunk ``i+1``.
    Returns the concatenated updated parameters.
    """
    rows = grads.shape[0]
    bounds = [(i * rows) // n_chunks for i in range(n_chunks + 1)]
    g_chunks = [grads[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
    p_chunks = [params[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
    reduced = jax.lax.psum(g_chunks[0], axis_name)
    outs = []
    for i in range(n_chunks):
        nxt = (jax.lax.psum(g_chunks[i + 1], axis_name)
               if i + 1 < n_chunks else None)
        outs.append(apply_fn(p_chunks[i], reduced))
        reduced = nxt
    return jnp.concatenate(outs, axis=0)
