"""Gradient compression: int8-quantized psum with per-device error feedback.

Each device quantizes its local contribution to symmetric int8 (scale =
``max|x| / 127``, so the wire carries 4x fewer bytes than f32), the
dequantized values are psum-averaged, and the quantization residue stays
*on the device* as error-feedback state that is re-added next round — the
EF-SGD construction, which keeps the long-run reduction unbiased even
though every single round is lossy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_psum_leaf(x, err, axis_name):
    """Mean-reduce one leaf across ``axis_name`` through int8 quantization.

    ``x`` is this device's contribution, ``err`` its carried residue from
    previous rounds (same shape, f32).  Returns ``(reduced, new_err)``:
    ``reduced`` approximates ``pmean(x)`` (replicated across the axis),
    ``new_err`` is the per-device residue ``(x + err) - dequantized``.
    """
    n = jax.lax.psum(1, axis_name)
    comp = x.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(comp)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(comp / safe), -127, 127).astype(jnp.int8)
    # the int8 payload is what crosses the wire; dequantize with the
    # sender's scalar scale before the additive reduction.
    deq = q.astype(jnp.float32) * safe
    new_err = comp - deq
    reduced = jax.lax.psum(deq, axis_name) / n
    return reduced.astype(x.dtype), new_err


def compressed_psum_tree(grads, err, axis_name):
    """``compressed_psum_leaf`` mapped over a pytree of (grad, err) pairs."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    pairs = [compressed_psum_leaf(g, e, axis_name)
             for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [p[0] for p in pairs]),
            jax.tree.unflatten(tdef, [p[1] for p in pairs]))
