"""Mid-join frontier re-balancing — handling the skew that static deals miss.

The static first-level deal (:func:`repro.core.plan.partition_first_level`)
balances *seed* cost, but worst-case-optimal joins meet their skew later:
a power-law hub discovered at level 2 multiplies every frontier row that
reaches it, and whichever shard owns those rows becomes the makespan
(Skew Strikes Back, Ngo/Ré/Rudra 2013 — worst-case optimality is won or
lost exactly here).  This module makes the deal *adaptive*:

* :func:`row_extension_costs` prices each frontier row for the next GAO
  level — the true min-degree probe adjacency length when node degrees
  are at hand, else the :class:`~repro.core.plan.GraphStats` expectation
  (``planner.estimate_extension_degree``);
* :func:`rebalance_rows` re-deals rows across shards with the same
  boustrophedon deal the first-level partitioner uses
  (:func:`~repro.core.plan.stripe_partition`), but keyed on *next-level*
  cost instead of seed degree;
* :class:`FrontierRebalancer` packages that as a
  ``JoinPlan.level_callback``: at each level boundary it measures
  per-shard cost over the contiguous row blocks an SPMD row-sharding
  assigns to devices, and past ``threshold`` (max/mean shard cost)
  reorders the frontier so the blocks balance;
* :class:`AdaptiveJoin` is the host-level, level-synchronous driver:
  every shard advances one GAO level per round behind a barrier, and at
  each boundary skewed frontiers are re-dealt before the next round —
  ``stats`` reports the static-vs-adaptive makespan the benchmark and
  the Zipf tests compare.
"""
from __future__ import annotations

import time

import numpy as np

from ..core.device_graph import GraphDB
from ..core.plan import (GraphStats, JoinPlan, LevelPlan,
                         partition_first_level, stripe_partition)
from ..core.planner import estimate_extension_degree
from ..core.query import Query
from ..core.vlftj import VLFTJ


def row_extension_costs(frontier: np.ndarray, lp: LevelPlan,
                        degrees: np.ndarray | None = None,
                        stats: GraphStats | None = None,
                        lane_cost: float = 0.0) -> np.ndarray:
    """Per-row cost of expanding ``frontier`` through level ``lp``.

    The vectorized kernel probes the *minimum-degree* bound neighbor, so
    a row's data-dependent work is that adjacency length — its expansion
    fanout (+1 for the fixed per-row work).  ``lane_cost`` adds the
    executor's *padded* per-row constant: the vectorized engine charges
    every frontier row a full ``width``-lane candidate tile whether or
    not the lanes hold live candidates, so a shard's wall-clock level
    cost is ``rows × (width + fanout)``, not ``rows × fanout`` —
    re-balancing with the executor's own width makes the re-deal track
    what the hardware actually bills.  Without degrees, falls back to
    the GraphStats expectation; without either, rows are uniform.
    """
    n = frontier.shape[0]
    if lp is None or not lp.edge_sources:
        if lp is not None and stats is not None:
            return np.full(n, lane_cost + estimate_extension_degree(
                lp, stats))
        return np.full(n, lane_cost + 1.0)
    if degrees is not None:
        deg = np.asarray(degrees)[frontier[:, list(lp.edge_sources)]]
        return lane_cost + 1.0 + deg.min(axis=1).astype(np.float64)
    if stats is not None:
        return np.full(n, lane_cost + estimate_extension_degree(lp, stats))
    return np.full(n, lane_cost + 1.0)


def cost_skew(costs) -> float:
    """max/mean shard-cost ratio — 1.0 is perfect balance."""
    costs = np.asarray(costs, dtype=np.float64)
    if costs.size == 0:
        return 1.0
    mean = costs.mean()
    if mean <= 0:
        return 1.0
    return float(costs.max() / mean)


def rebalance_rows(row_costs: np.ndarray, n_shards: int) -> list[np.ndarray]:
    """Snake-deal row indices into ``n_shards`` cost-balanced groups.

    Same deal as the static first-level partitioner — sorted by cost
    descending, dealt boustrophedon — applied to *frontier rows* instead
    of seed values, so it can run again at any level boundary."""
    return stripe_partition(row_costs, n_shards)


class FrontierRebalancer:
    """``JoinPlan.level_callback`` that re-deals skewed SPMD frontiers.

    An SPMD row-sharding (``dist.spmd_join_step``) assigns contiguous
    equal row blocks to devices; this callback measures each block's
    next-level cost at every level boundary and, past ``threshold``
    (max/mean), returns the frontier permuted by the snake deal so the
    blocks balance.  A pure permutation — no rows added or dropped — so
    it is safe under counting *and* enumeration.  ``events`` records
    ``(level, skew_before, skew_after, rows)`` per triggered re-deal.
    """

    def __init__(self, plan: JoinPlan, n_shards: int,
                 degrees: np.ndarray | None = None,
                 stats: GraphStats | None = None,
                 threshold: float = 1.5, lane_cost: float = 0.0):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.plan = plan
        self.n_shards = n_shards
        self.degrees = None if degrees is None else np.asarray(degrees)
        self.stats = stats
        self.threshold = threshold
        self.lane_cost = lane_cost
        self.events: list[dict] = []

    def _block_costs(self, row_costs: np.ndarray) -> np.ndarray:
        blocks = np.array_split(row_costs, self.n_shards)
        return np.array([b.sum() for b in blocks])

    def __call__(self, level: int, frontier: np.ndarray,
                 mult: np.ndarray):
        nxt = level + 1
        if nxt >= len(self.plan.levels) or frontier.shape[0] < self.n_shards:
            return None
        costs = row_extension_costs(frontier, self.plan.levels[nxt],
                                    self.degrees, self.stats,
                                    lane_cost=self.lane_cost)
        before = cost_skew(self._block_costs(costs))
        if before <= self.threshold:
            return None
        order = np.concatenate(rebalance_rows(costs, self.n_shards))
        after = cost_skew(self._block_costs(costs[order]))
        self.events.append({"level": level, "skew_before": before,
                            "skew_after": after,
                            "rows": int(frontier.shape[0])})
        return frontier[order], mult[order]


class AdaptiveJoin:
    """Level-synchronous sharded WCOJ with mid-join frontier re-deals.

    ``n_shards`` frontiers advance one GAO level per round behind a
    barrier (the schedule a bulk-synchronous worker fleet runs); between
    rounds, per-shard cost of the *next* level is measured and, past
    ``threshold`` skew, all frontier rows are re-dealt with the snake
    deal.  ``rebalance=False`` freezes the static first-level deal — the
    baseline the Zipf benchmark compares against.

    ``stats`` after :meth:`count`:

    * ``shards`` / ``levels`` — geometry;
    * ``shard_time`` — per-shard summed level seconds;
    * ``makespan`` — sum over levels of the slowest shard's level time
      (the barrier wall-clock a real fleet would see);
    * ``total_time`` — summed shard time (single-worker equivalent);
    * ``cost_makespan`` / ``cost_total`` — same two aggregates in the
      deterministic cost-model units (rows × estimated extension
      degree), immune to timer noise — the quantity the tests assert on;
    * ``rebalances`` — one event per triggered re-deal
      (level, skew before/after, rows moved).
    """

    def __init__(self, query: Query, gdb: GraphDB, n_shards: int = 4,
                 threshold: float = 1.5, rebalance: bool = True,
                 plan: JoinPlan | None = None, **vlftj_kw):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.executor = VLFTJ(query, gdb, plan=plan, **vlftj_kw)
        self.query = query
        self.gdb = gdb
        self.n_shards = n_shards
        self.threshold = threshold
        self.rebalance = rebalance
        seeds = self.executor._domain_values(self.executor.plan[0])
        self.parts = [p.astype(np.int32) for p in partition_first_level(
            self.executor.join_plan, seeds, gdb.csr.degrees, n_shards)]
        self.stats: dict = {"shards": n_shards,
                            "levels": len(self.executor.plan) - 1,
                            "rebalance": rebalance,
                            "threshold": threshold}

    def count(self) -> int:
        ex = self.executor
        k = len(ex.plan)
        degrees = self.gdb.csr.degrees
        lane = float(ex.width)     # the padded per-row lane constant
        frontiers = [p[:, None] for p in self.parts]
        shard_time = np.zeros(self.n_shards)
        makespan = total_time = 0.0
        cost_makespan = cost_total = 0.0
        events: list[dict] = []
        total = 0
        if k == 1:
            total = sum(int(f.shape[0]) for f in frontiers)
        for level in range(1, k):
            lp = ex.plan[level]
            last = level == k - 1
            costs = np.array(
                [row_extension_costs(f, lp, degrees, lane_cost=lane).sum()
                 for f in frontiers])
            cost_makespan += float(costs.max(initial=0.0))
            cost_total += float(costs.sum())
            level_t = np.zeros(self.n_shards)
            for s, f in enumerate(frontiers):
                if f.shape[0] == 0:
                    # keep emptied shards at the current level's width so
                    # later-level cost pricing never indexes a column the
                    # (empty) frontier doesn't have
                    if not last:
                        frontiers[s] = np.zeros((0, level + 1), np.int32)
                    continue
                t0 = time.perf_counter()
                if last:
                    total += int(ex._run(count_only=True, frontier=f,
                                         start_level=level, max_levels=k))
                else:
                    frontiers[s] = np.asarray(
                        ex._run(count_only=False, frontier=f,
                                start_level=level, max_levels=level + 1),
                        dtype=np.int32)
                level_t[s] = time.perf_counter() - t0
            shard_time += level_t
            makespan += float(level_t.max(initial=0.0))
            total_time += float(level_t.sum())
            if last or not self.rebalance:
                continue
            # level boundary: price the NEXT level per shard; re-deal on
            # skew (the static deal can never fix this — its seeds are
            # long since expanded away)
            nxt = ex.plan[level + 1]
            next_costs = [row_extension_costs(f, nxt, degrees,
                                              lane_cost=lane)
                          for f in frontiers]
            before = cost_skew([c.sum() for c in next_costs])
            if before <= self.threshold:
                continue
            all_rows = np.concatenate(
                [f for f in frontiers if f.shape[0]], axis=0)
            all_costs = np.concatenate(
                [c for c in next_costs if c.shape[0]])
            deal = rebalance_rows(all_costs, self.n_shards)
            frontiers = [all_rows[idx] for idx in deal]
            after = cost_skew([all_costs[idx].sum() for idx in deal])
            events.append({"level": level, "skew_before": before,
                           "skew_after": after,
                           "rows": int(all_rows.shape[0])})
        self.stats.update({
            "shard_time": shard_time.tolist(),
            "makespan": makespan,
            "total_time": total_time,
            "cost_makespan": cost_makespan,
            "cost_total": cost_total,
            "rebalances": events,
            "count": int(total),
        })
        return int(total)


def adaptive_count(query: Query, gdb: GraphDB, n_shards: int = 4,
                   **kw) -> int:
    return AdaptiveJoin(query, gdb, n_shards=n_shards, **kw).count()
