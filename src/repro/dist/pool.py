"""A real worker pool for the partitioned join (no more simulation).

``PartitionedJoin`` used to *simulate* its workers — parts ran
sequentially and ``makespan`` was what a pool would have seen.  This
module supplies the actual pool: one ``concurrent.futures`` worker per
alive schedule entry, each draining its owned parts **in schedule
order**, so the deterministic deal from
:func:`repro.train.stragglers.reassign_shards` is preserved exactly and
a re-run assigns every part to the same worker.

Backend selection follows payload picklability: a task whose function
and arguments survive ``pickle`` can cross a process boundary and gets a
``spawn``-context :class:`~concurrent.futures.ProcessPoolExecutor`
(``fork`` is unsafe once jax/XLA is initialized); anything closing over
device arrays or jitted state stays in threads — the join workloads are
in the second camp, and that is the right call anyway: the expensive
part of a join part runs inside XLA, which releases the GIL, so threads
give real concurrency while sharing one jit cache.
"""
from __future__ import annotations

import io
import pickle
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Callable, Sequence


class _DeviceState(Exception):
    """Raised mid-pickle when the payload holds device-resident arrays."""


def pick_backend(fn: Callable, sample_arg=None) -> str:
    """'process' when ``(fn, sample_arg)`` can *usefully* cross a process
    boundary: it pickles and carries no device-resident jax state.

    jax arrays technically pickle (as host copies), but shipping one to
    a spawned worker re-stages the buffer and pays a fresh XLA
    init + compile there — strictly worse than a thread sharing the live
    jit cache.  So device state votes 'thread' even though ``pickle``
    alone would say yes."""
    try:
        dev_types: tuple = ()
        try:
            import jax
            dev_types = (jax.Array,)
        except Exception:       # pragma: no cover - jax is a core dep
            pass

        class _Probe(pickle.Pickler):
            def reducer_override(self, obj):
                if dev_types and isinstance(obj, dev_types):
                    raise _DeviceState
                return NotImplemented

        _Probe(io.BytesIO(), protocol=5).dump((fn, sample_arg))
        return "process"
    except Exception:
        return "thread"


def _drain(fn: Callable, owned: list[int], parts: Sequence) -> list[tuple]:
    """Run one worker's parts in schedule order; (pid, result, seconds)."""
    out = []
    for pid in owned:
        t0 = time.perf_counter()
        res = fn(parts[pid])
        out.append((pid, res, time.perf_counter() - t0))
    return out


class WorkerPool:
    """Deterministic-schedule pool over ``concurrent.futures``.

    ``schedule`` maps worker id -> owned part ids (the
    ``reassign_shards`` output — dead workers simply have no entry).
    :meth:`run` executes ``fn(parts[pid])`` for every scheduled part,
    one concurrent worker per schedule entry, and returns
    ``(part_results, part_time, wall_time, backend)`` where
    ``part_time`` holds each part's own execution seconds (the quantity
    the makespan stats aggregate — pool overhead shows up in
    ``wall_time``, not in the schedule accounting) and ``backend`` is
    what actually ran ('sequential' whenever <= 1 worker is alive, no
    matter what was requested).

    ``backend``: 'thread', 'process', 'sequential', or 'auto' (decide
    per :func:`pick_backend` on the first scheduled part).
    """

    def __init__(self, schedule: dict[int, list[int]],
                 backend: str = "auto"):
        if backend not in ("auto", "thread", "process", "sequential"):
            raise ValueError(f"unknown pool backend {backend!r}")
        self.schedule = {w: list(o) for w, o in schedule.items()}
        self.backend = backend

    def run(self, fn: Callable, parts: Sequence
            ) -> tuple[dict[int, object], dict[int, float], float, str]:
        n_parts = len(parts)
        workers = [(w, [p for p in owned if p < n_parts])
                   for w, owned in sorted(self.schedule.items())]
        workers = [(w, owned) for w, owned in workers if owned]
        backend = self.backend
        if backend == "auto":
            first = workers[0][1][0] if workers else None
            backend = (pick_backend(fn, parts[first])
                       if first is not None else "thread")
        # resolve the device profile in the *calling* thread: pool
        # workers run in other threads/processes and contextvars do not
        # cross that boundary, so per-worker spans are recorded here
        # from the drain timings the pool returns anyway
        from ..obs.profile import current_profile
        prof = current_profile()
        t0 = time.perf_counter()
        results: dict[int, object] = {}
        part_time: dict[int, float] = {}
        if backend == "sequential" or len(workers) <= 1:
            # <=1 alive worker: no pool exists, report what actually ran
            for _w, owned in workers:
                for pid, res, dt in _drain(fn, owned, parts):
                    results[pid] = res
                    part_time[pid] = dt
            self._observe(part_time, workers, "sequential", prof)
            return results, part_time, time.perf_counter() - t0, "sequential"
        pool_cls = (ProcessPoolExecutor if backend == "process"
                    else ThreadPoolExecutor)
        kw = {}
        if backend == "process":
            import multiprocessing as mp
            kw["mp_context"] = mp.get_context("spawn")
        with pool_cls(max_workers=len(workers), **kw) as pool:
            futs = {pool.submit(_drain, fn, owned, parts): w
                    for w, owned in workers}
            for fut in futs:
                for pid, res, dt in fut.result():
                    results[pid] = res
                    part_time[pid] = dt
        self._observe(part_time, workers, backend, prof)
        return results, part_time, time.perf_counter() - t0, backend

    @staticmethod
    def _observe(part_time: dict[int, float],
                 workers: list[tuple[int, list[int]]], backend: str,
                 prof=None) -> None:
        """Record per-worker makespans into the process metrics registry
        (and, when a device profile is active, per-worker spans)."""
        from ..obs import get_registry
        hist = get_registry().histogram("pool_worker_seconds",
                                        backend=backend)
        for w, owned in workers:
            seconds = sum(part_time.get(p, 0.0) for p in owned)
            hist.observe(seconds)
            if prof is not None:
                prof.record_worker(w, backend, seconds)
