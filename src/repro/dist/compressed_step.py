"""Compressed data-parallel train step: DP gradients over an int8 wire.

``make_compressed_train_step`` is the distributed twin of
``train.loop.make_train_step``: the batch is row-sharded over the data
axis, every device back-propagates its shard, and the gradient exchange
runs through :func:`repro.dist.compression.compressed_psum_leaf` — int8
payloads with per-device error feedback — before the same AdamW update
(``train.optimizer.adamw_update``) runs replicated on every device.

The error-feedback state has a leading data-shard axis (device ``i``
owns row ``i``); it is *soft* state: checkpointing it is optional, and
after an elastic restart under a different shard count
:func:`resize_compressed_state` re-deals the residues so the carried
mean — the only quantity the psum-mean consumes — is preserved.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..train.optimizer import OptimizerConfig, adamw_update
from .compression import compressed_psum_tree


def init_compressed_state(params, mesh=None, axis_name: str = "data"):
    """Zero error-feedback residues: one f32 copy of each param leaf per
    data shard (leading axis = shard count, from ``mesh`` when given,
    else every addressable device)."""
    if mesh is not None:
        n = int(mesh.shape[axis_name])
    else:
        n = jax.device_count()
    return jax.tree.map(
        lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)


def resize_compressed_state(err, n_shards: int):
    """Elastic re-deal of error-feedback state to ``n_shards`` devices.

    Every new shard receives the old *mean* residue, so the axis-mean
    (what ``compressed_psum_leaf`` folds into the next reduction) is
    unchanged across the resize and no accumulated correction is lost.
    """
    return jax.tree.map(
        lambda e: jnp.repeat(e.mean(axis=0, keepdims=True), n_shards,
                             axis=0), err)


def make_dp_train_step(loss_fn, opt_cfg: OptimizerConfig, mesh,
                       axis_name: str = "data"):
    """Uncompressed data-parallel twin (f32 pmean wire) — the fair
    baseline when benchmarking :func:`make_compressed_train_step`.
    Returns jitted ``step(params, opt_state, batch)``."""

    def local_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = jax.tree.map(
            lambda g: jax.lax.pmean(g.astype(jnp.float32), axis_name),
            grads)
        loss = jax.lax.pmean(loss, axis_name)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return jax.jit(jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(), P(axis_name)),
        out_specs=(P(), P(), P()), check_vma=False))


def make_compressed_train_step(loss_fn, opt_cfg: OptimizerConfig, mesh,
                               axis_name: str = "data"):
    """loss_fn(params, batch) -> scalar.  Returns jitted
    ``step(params, opt_state, err, batch) -> (params, opt_state, err,
    metrics)`` with the batch sharded over ``axis_name`` and gradients
    exchanged via int8 compressed psum with error feedback."""

    def local_step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        local_err = jax.tree.map(lambda e: e[0], err)
        grads, new_err = compressed_psum_tree(grads, local_err, axis_name)
        loss = jax.lax.pmean(loss, axis_name)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        metrics = {"loss": loss, **om}
        new_err = jax.tree.map(lambda e: e[None], new_err)
        return params, opt_state, new_err, metrics

    return jax.jit(jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(), P(axis_name), P(axis_name)),
        out_specs=(P(), P(), P(axis_name), P()),
        check_vma=False))
