"""Sharded worst-case-optimal join execution.

Two granularities of parallelism, matching the paper's evaluation setup:

* :func:`spmd_join_step` / :func:`spmd_spmv_step` — device-level SPMD.
  The frontier (or edge list) is row-sharded over a jax mesh; every
  device runs the *same* jitted expansion level (``vlftj._expand_level``,
  reused verbatim — the kernel never learns it is distributed) against a
  replicated CSR, and a single ``psum`` folds the per-shard counts.
  Binding-space sharding means no shuffle: a partial binding's whole
  subtree lives on the shard that owns the seed row.

* :class:`PartitionedJoin` — host-level static over-partitioning (the
  granularity factor).  The first GAO level's domain is dealt into
  ``n_workers x granularity`` cost-balanced parts
  (:func:`repro.core.plan.partition_first_level`); parts go to workers
  with the same deterministic deal as
  :func:`repro.train.stragglers.reassign_shards`, so a dead worker's
  parts can be re-dealt without recomputing anything.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.device_graph import GraphDB
from ..core.plan import JoinPlan, partition_first_level
from ..core.query import Query
from ..core.vlftj import VLFTJ, _expand_level
from ..train.stragglers import reassign_shards
from .pool import WorkerPool


def spmd_join_step(mesh, level_kw: dict, axis_names=None,
                   plan: JoinPlan | None = None):
    """Build a sharded expansion-level counter over ``mesh``.

    ``level_kw`` holds the static kernel arguments of
    ``vlftj._expand_level`` (probe_cols, lower_cols, width, n_iter, ...).
    The returned function maps ``(indptr, indices, frontier, mult)`` to
    the global weighted count: CSR replicated, frontier/mult row-sharded
    over every mesh axis in ``axis_names`` (default: all axes — a join
    has no MXU work for a model axis, but its HBM bandwidth is real, see
    ``configs/wcoj.py``).

    Frontiers of any length are accepted: the wrapper pads rows to the
    shard-count multiple and zeroes the padding's ``mult`` itself (the
    kernel's ``counts * mult`` weighting nullifies padded rows) — callers
    used to pre-pad by hand, and a wrong hand-zeroed ``mult`` silently
    miscounted.  When ``plan`` carries a
    :attr:`~repro.core.plan.JoinPlan.level_callback`
    (``dist.rebalance.FrontierRebalancer``), the callback runs on the
    host frontier first, so a skew-triggered re-deal can reorder rows
    into cost-balanced device blocks before the sharded dispatch.
    """
    axes = tuple(mesh.axis_names) if axis_names is None else tuple(axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= int(mesh.shape[a])
    kw = dict(level_kw)
    kw.setdefault("count_only", True)

    def local_step(indptr, indices, frontier, mult):
        row_valid = jnp.ones((frontier.shape[0],), bool)
        counts = _expand_level(indptr, indices, (), frontier, mult,
                               row_valid, **kw)
        return jax.lax.psum(counts.sum(), axes)

    jitted = jax.jit(jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P(), P(axes), P(axes)),
        out_specs=P(), check_vma=False))

    callback = getattr(plan, "level_callback", None)

    def step(indptr, indices, frontier, mult):
        if callback is not None:
            fr, ml = np.asarray(frontier), np.asarray(mult)
            # callback convention (VLFTJ._run): `level` is the level
            # just expanded, so its frontier has level+1 bound columns
            # and the callback prices levels[level+1] — the level this
            # step is about to dispatch
            upd = callback(fr.shape[1] - 1, fr, ml)
            if upd is not None:
                frontier, mult = upd
        rows = int(frontier.shape[0])
        pad = (-rows) % n_shards
        if pad:
            fr = np.zeros((rows + pad, frontier.shape[1]), dtype=np.int32)
            fr[:rows] = np.asarray(frontier)
            ml = np.zeros(rows + pad, dtype=np.int64)
            ml[:rows] = np.asarray(mult)
            frontier, mult = fr, ml
        return jitted(indptr, indices, jnp.asarray(frontier),
                      jnp.asarray(mult))

    step.n_shards = n_shards
    return step


def spmd_spmv_step(mesh, n_nodes: int, axis_names=None):
    """Edge-sharded counting SpMV (the #Minesweeper message pass, Idea 8).

    The returned function maps ``(indices, src_ids, c)`` to
    ``y[v] = sum_{(v,u) in E} c[u]``: edges (``indices``/``src_ids``)
    row-sharded, the count vector ``c`` replicated, per-shard
    segment-sums psum-folded into the replicated output.  Edge rows must
    divide the shard count (trim or pad to the shard boundary).
    """
    axes = tuple(mesh.axis_names) if axis_names is None else tuple(axis_names)

    def local_step(indices, src_ids, c):
        part = jax.ops.segment_sum(c[indices], src_ids,
                                   num_segments=n_nodes)
        return jax.lax.psum(part, axes)

    return jax.jit(jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(P(axes), P(axes), P()),
        out_specs=P(), check_vma=False))


class PartitionedJoin:
    """Granularity-factor partitioned WCOJ (host-level work splitting).

    Splits the first GAO level's seed domain into
    ``n_workers * granularity`` cost-balanced parts and runs each part as
    a seeded count on the shared :class:`~repro.core.vlftj.VLFTJ`
    executor.  Parts are dealt to workers statically (part ``p`` to
    worker ``p % n_workers``; with ``dead`` workers, survivors pick up
    the orphaned parts via the same deterministic re-deal the training
    loop uses) and execute on a real concurrent pool
    (:class:`~repro.dist.pool.WorkerPool`) — one worker per alive
    schedule entry, each draining its owned parts in schedule order.
    ``backend='auto'`` selects process vs thread by payload picklability;
    the seeded-count task closes over the jitted executor, so it lands on
    threads, where the XLA compute releases the GIL and the jit cache is
    shared.  ``backend='sequential'`` restores the old single-thread walk
    (the equality baseline in the tests).

    ``stats`` after :meth:`count`:

    * ``parts`` — number of parts (``n_workers * granularity``);
    * ``part_sizes`` — seeds per part (balanced to within one);
    * ``part_time`` / ``part_counts`` — per-part seconds and counts;
    * ``worker_time`` — per-worker summed part time (len ``n_workers``;
      dead workers stay at 0.0);
    * ``makespan`` — max worker time, ``<= total_time`` always;
    * ``total_time`` — summed part time (single-worker equivalent);
    * ``backend`` / ``wall_time`` — what the pool actually ran on, and
      the concurrent wall-clock (incl. pool overhead; compare with
      ``makespan``, which aggregates pure part seconds).
    """

    def __init__(self, query: Query, gdb: GraphDB, n_workers: int = 4,
                 granularity: int = 2, plan: JoinPlan | None = None,
                 dead: frozenset[int] | set[int] = frozenset(),
                 backend: str = "auto", **vlftj_kw):
        if n_workers < 1 or granularity < 1:
            raise ValueError("n_workers and granularity must be >= 1")
        self.executor = VLFTJ(query, gdb, plan=plan, **vlftj_kw)
        self.query = query
        self.gdb = gdb
        self.n_workers = n_workers
        self.granularity = granularity
        self.n_parts = n_workers * granularity
        seeds = self.executor._domain_values(self.executor.plan[0])
        self.parts = partition_first_level(
            self.executor.join_plan, seeds, gdb.csr.degrees, self.n_parts)
        self.schedule = reassign_shards(n_workers, set(dead), granularity)
        self.backend = backend
        self.stats: dict = {
            "parts": self.n_parts,
            "part_sizes": [int(p.shape[0]) for p in self.parts],
        }

    def _count_part(self, seeds: np.ndarray) -> int:
        return self.executor.seeded_count(
            seeds.astype(np.int32), np.ones(seeds.shape[0], dtype=np.int64))

    def count(self) -> int:
        # warm the jitted level kernels once before fanning out: the
        # first part otherwise compiles while every other worker blocks
        # on the same compile lock, charging compilation to one part's
        # time and skewing the makespan accounting
        if self.parts and self.backend != "sequential":
            warm = max(self.parts, key=lambda p: p.shape[0])
            self._count_part(warm[:1])
        pool = WorkerPool(self.schedule, backend=self.backend)
        results, ptime, wall, backend = pool.run(self._count_part,
                                                 self.parts)
        part_time = np.zeros(self.n_parts)
        part_counts = np.zeros(self.n_parts, dtype=np.int64)
        for pid, c in results.items():
            part_counts[pid] = c
            part_time[pid] = ptime[pid]
        worker_time = [0.0] * self.n_workers
        for worker, owned in self.schedule.items():
            worker_time[worker] = float(part_time[owned].sum())
        self.stats.update({
            "part_time": part_time.tolist(),
            "part_counts": part_counts.tolist(),
            "worker_time": worker_time,
            "makespan": max(worker_time),
            "total_time": float(part_time.sum()),
            "backend": backend,
            "wall_time": wall,
        })
        return int(part_counts.sum())

    def pages(self, page_rows: int = 1024) -> Iterator[np.ndarray]:
        """Stream the join's output as fixed-size pages in global
        GAO-lexicographic order.

        Each part gets its own bounded-memory
        :class:`~repro.results.ResultCursor` (the shared executor seeded
        with the part's first-level values).  The parts partition the
        first GAO variable's *domain*, so streams interleave only at
        first-column granularity: the part holding the globally smallest
        head row owns every row up to the next part's head value, and
        whole runs splice over with one ``searchsorted`` — the merge a
        scatter-gather coordinator would run over real workers' page
        responses, with no per-row Python work."""
        from ..results.cursor import ResultCursor

        k = len(self.executor.gao)
        streams: list[list] = []      # [head buffer, cursor] per live part
        for p in self.parts:
            if p.shape[0] == 0:
                continue
            cur = ResultCursor(self.executor, page_rows=page_rows,
                               seeds=p.astype(np.int32))
            page = cur.next_page()
            if page is not None:
                streams.append([page, cur])
        out: list[np.ndarray] = []
        buffered = 0
        while streams:
            i = min(range(len(streams)),
                    key=lambda j: tuple(streams[j][0][0]))
            buf, cur = streams[i]
            others = [streams[j][0][0, 0]
                      for j in range(len(streams)) if j != i]
            if others:
                # first-column values are disjoint across parts, so the
                # run boundary is where the next part's head value starts
                cut = int(np.searchsorted(buf[:, 0], min(others),
                                          side="left"))
            else:
                cut = buf.shape[0]
            take, rest = buf[:cut], buf[cut:]
            if rest.shape[0]:
                streams[i][0] = rest
            else:
                nxt = cur.next_page()
                if nxt is None:
                    streams.pop(i)
                else:
                    streams[i][0] = nxt
            out.append(take)
            buffered += take.shape[0]
            while buffered >= page_rows:
                cat = np.concatenate(out) if len(out) > 1 else out[0]
                yield cat[:page_rows]
                cat = cat[page_rows:]
                out = [cat] if cat.shape[0] else []
                buffered = int(cat.shape[0])
        if buffered:
            yield (np.concatenate(out)
                   if len(out) > 1 else out[0]).reshape(-1, k)

    def enumerate(self, limit: int | None = None, page_rows: int = 1024):
        """All output tuples as a :class:`~repro.results.ResultSet` —
        columns in the plan's GAO order, rows lex-sorted (``limit``
        truncates after the ordering), produced by merging the
        per-part page streams of :meth:`pages`."""
        from ..results.result_set import ResultSet

        out: list[np.ndarray] = []
        taken = 0
        for page in self.pages(page_rows=page_rows):
            out.append(page)
            taken += page.shape[0]
            if limit is not None and taken >= limit:
                break
        rows = (np.concatenate(out, axis=0) if out
                else np.zeros((0, len(self.executor.gao)), dtype=np.int64))
        return ResultSet(self.executor.gao,
                         rows if limit is None else rows[:limit])
