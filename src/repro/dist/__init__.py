"""Distributed execution: sharded WCOJ, collective overlap, compression.

The paper's evaluation runs worst-case-optimal joins across parallel
workers; EmptyHeaded-style systems get their order-of-magnitude wins from
partitioned execution of the same plans.  This package is that layer for
the reproduction, in two complementary halves:

* **SPMD device sharding** (``sharded_join``): one jitted expansion level
  or counting SpMV running identically on every device of a jax mesh via
  ``shard_map``, frontier/edge rows sharded, one ``psum`` per step.
* **Host work partitioning** (``sharded_join.PartitionedJoin``): the
  paper's granularity-factor over-partitioning — the first GAO level's
  seed domain is dealt into ``n_workers x granularity`` cost-balanced
  parts, scheduled statically, and executed on a real
  ``concurrent.futures`` pool (``pool.WorkerPool`` — process vs thread
  by payload picklability), so a straggling worker delays at most one
  small part (see ``train.stragglers`` for the re-deal policy).
* **Adaptive skew handling** (``rebalance``): per-shard frontier cost is
  re-measured at every GAO level boundary and, past a skew threshold,
  frontier rows are re-dealt with the same snake deal the first-level
  partitioner uses — a power-law hub discovered mid-join no longer pins
  one worker (``AdaptiveJoin``, ``FrontierRebalancer``).
* **Sharded CSR** (``sharded_csr.ShardedGraphDB``): a row-partitioned
  graph for joins too large to replicate per device; remote adjacency
  arrives over the same ``ppermute`` ring the all-reduce uses.

``overlap`` and ``compression`` serve the training side of the repo: a
ring all-reduce, chunked reduce/apply overlap, and int8-quantized psum
with per-device error feedback, wired into a data-parallel train step by
``compressed_step``.
"""
from . import (compressed_step, compression, overlap, pool, rebalance,
               sharded_csr, sharded_join)
from .compressed_step import (init_compressed_state,
                              make_compressed_train_step,
                              make_dp_train_step, resize_compressed_state)
from .compression import compressed_psum_leaf, compressed_psum_tree
from .overlap import overlapped_reduce_apply, ring_all_reduce, ring_schedule
from .pool import WorkerPool, pick_backend
from .rebalance import AdaptiveJoin, FrontierRebalancer, adaptive_count
from .sharded_csr import (ShardedGraphDB, sharded_count,
                          spmd_sharded_join_step)
from .sharded_join import PartitionedJoin, spmd_join_step, spmd_spmv_step

__all__ = [
    "compressed_step", "compression", "overlap", "pool", "rebalance",
    "sharded_csr", "sharded_join",
    "init_compressed_state", "make_compressed_train_step",
    "make_dp_train_step", "resize_compressed_state", "compressed_psum_leaf",
    "compressed_psum_tree", "overlapped_reduce_apply", "ring_all_reduce",
    "ring_schedule", "WorkerPool", "pick_backend", "AdaptiveJoin",
    "FrontierRebalancer", "adaptive_count", "ShardedGraphDB",
    "sharded_count", "spmd_sharded_join_step",
    "PartitionedJoin", "spmd_join_step", "spmd_spmv_step",
]
