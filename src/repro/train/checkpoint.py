"""Fault-tolerant sharded checkpointing.

Design (1000-node posture):
  * a checkpoint is a *logical* pytree: a manifest (JSON: tree structure,
    leaf shapes/dtypes, step, content hashes) + one ``.npy`` blob per leaf
    chunk.  Restore never needs the saving topology — leaves are
    reassembled and resharded under whatever mesh the restarted job has
    (elastic restart).
  * writes are atomic: blobs+manifest land in ``<dir>/.tmp-<step>`` and a
    single ``os.replace`` publishes ``step-<n>``; a crashed writer leaves
    no half-checkpoint.
  * saves run on a background thread (async) so the train loop never
    blocks on I/O; ``wait()`` joins before the next save.
  * ``latest_step`` scans for the newest *complete* checkpoint (manifest
    hash-verified), so restart skips torn writes.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        paths, leaves, _ = _flatten_with_paths(tree)
        host_leaves = [np.asarray(l) for l in leaves]

        def _write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            final = os.path.join(self.dir, f"step-{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": []}
            for i, (p, arr) in enumerate(zip(paths, host_leaves)):
                fname = f"leaf-{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                with open(os.path.join(tmp, fname), "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()[:16]
                manifest["leaves"].append(
                    {"path": p, "file": fname, "shape": list(arr.shape),
                     "dtype": str(arr.dtype), "sha": digest})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        for s in reversed(self.steps()):
            if self.verify(s):
                return s
        return None

    def verify(self, step: int) -> bool:
        d = os.path.join(self.dir, f"step-{step:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            for leaf in manifest["leaves"]:
                with open(os.path.join(d, leaf["file"]), "rb") as f:
                    if hashlib.sha256(f.read()).hexdigest()[:16] != leaf["sha"]:
                        return False
            return True
        except (OSError, json.JSONDecodeError, KeyError):
            return False

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Rebuild the pytree of ``like``'s structure; if ``shardings`` is
        given (pytree of NamedSharding), leaves are placed sharded —
        works across any device count (elastic restore)."""
        d = os.path.join(self.dir, f"step-{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _flatten_with_paths(like)
        by_path = {l["path"]: l for l in manifest["leaves"]}
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for p, leaf, sh in zip(paths, leaves, shard_leaves):
            info = by_path[p]
            arr = np.load(os.path.join(d, info["file"]))
            arr = arr.astype(info["dtype"])
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                           if hasattr(leaf, "dtype") else arr)
        return jax.tree.unflatten(treedef, out)
