"""Training loop: jitted step (grad-accum scan + AdamW), checkpointing,
failure recovery, metrics.

Fault tolerance: ``run`` wraps each step; on crash the loop can be
restarted with ``resume="auto"`` and continues from the newest verified
checkpoint (data pipeline is a pure function of step, so no batches are
lost or doubled).  The optimizer update runs inside the same jit as the
backward pass, so the dry-run lowers the full production step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .checkpoint import CheckpointManager
from .optimizer import OptimizerConfig, adamw_update, init_opt_state


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    microbatches: int = 1, unroll_micro: bool = False):
    """loss_fn(params, batch) -> scalar.  Returns jit-able
    step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``unroll_micro`` unrolls the grad-accumulation loop (used by the
    dry-run cost probes: XLA cost analysis counts a scan body once, which
    would hide per-microbatch collective traffic)."""

    def step(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
                return acc, loss

            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            if unroll_micro:
                gsum = zero
                losses = []
                for i in range(microbatches):
                    mb = jax.tree.map(lambda x: x[i], mbs)
                    gsum, l = micro(gsum, mb)
                    losses.append(l)
                losses = jnp.stack(losses)
            else:
                gsum, losses = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return step


@dataclass
class Trainer:
    loss_fn: Callable                 # (params, batch) -> scalar
    params: Any
    opt_cfg: OptimizerConfig
    get_batch: Callable               # (step) -> batch pytree
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    microbatches: int = 1
    keep: int = 3

    def __post_init__(self):
        self.opt_state = init_opt_state(self.params)
        self.step_fn = jax.jit(make_train_step(
            self.loss_fn, self.opt_cfg, self.microbatches))
        self.ckpt = (CheckpointManager(self.ckpt_dir, keep=self.keep)
                     if self.ckpt_dir else None)
        self.start_step = 0
        self.history: list[dict] = []

    def maybe_resume(self) -> int:
        if self.ckpt is None:
            return 0
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0
        state = self.ckpt.restore(
            latest, {"params": self.params, "opt": self.opt_state})
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.start_step = latest
        return latest

    def run(self, n_steps: int, log_every: int = 10,
            resume: str = "auto") -> list[dict]:
        if resume == "auto":
            self.maybe_resume()
        t0 = time.time()
        for step in range(self.start_step, self.start_step + n_steps):
            batch = self.get_batch(step)
            batch = jax.tree.map(jnp.asarray, batch)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            if (step + 1) % log_every == 0 or step == self.start_step:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                m["wall"] = time.time() - t0
                self.history.append(m)
            if self.ckpt and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": self.params,
                                          "opt": self.opt_state})
        if self.ckpt:
            self.ckpt.wait()
        return self.history
