"""Straggler detection & mitigation policy.

At pod scale the engine-level mitigation is *static over-partitioning*
(the paper's granularity factor, `dist/sharded_join.py`); the training
loop adds (1) per-step wall-time tracking with robust outlier detection
and (2) a deterministic work-reassignment plan: because every batch is a
pure function of (step, shard) (`data/pipeline.py`), shards of a detected
straggler can be re-dealt to healthy workers without data loss — the
restarted worker replays nothing and double-computes nothing.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class StepTimeTracker:
    """Rolling robust z-score over step wall-times."""

    window: int = 50
    threshold: float = 3.0   # MAD multiples
    times: deque = field(default_factory=lambda: deque(maxlen=200))

    def record(self, seconds: float) -> bool:
        """Record one step; True if this step is a straggler event."""
        hist = sorted(self.times)[-self.window:] if self.times else []
        self.times.append(seconds)
        if len(hist) < 10:
            return False
        med = hist[len(hist) // 2]
        mad = sorted(abs(t - med) for t in hist)[len(hist) // 2]
        return seconds > med + self.threshold * max(mad, 0.05 * med)

    @property
    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]


def reassign_shards(n_shards: int, dead: set[int],
                    granularity: int = 1) -> dict[int, list[int]]:
    """Deterministic plan: every worker w owns shards {w, w+W, ...} of the
    over-partitioned space; dead workers' shards are round-robin re-dealt
    to survivors.  Returns worker -> owned shard list."""
    alive = [w for w in range(n_shards) if w not in dead]
    if not alive:
        raise RuntimeError("no workers alive")
    total = n_shards * granularity
    plan: dict[int, list[int]] = {w: [] for w in alive}
    for part in range(total):
        owner = part % n_shards
        if owner in dead:
            owner = alive[part % len(alive)]
        plan.setdefault(owner, []).append(part)
    return plan
