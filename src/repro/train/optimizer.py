"""AdamW + cosine schedule + global-norm clipping (no optax dependency).

States mirror param shapes and inherit param shardings (FSDP-friendly);
all moments are f32 regardless of param dtype.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step, cfg: OptimizerConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_at(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
