"""Batched graph-pattern query serving — the paper's workload as a service.

The RDBMS story of the paper is interactive: clients submit pattern
queries (with per-request node samples / selectivities) against a resident
graph.  ``QueryServer`` keeps the device-resident CSR trie warm and now
serves through the plan/execute split (``core.plan`` / ``core.planner``):

  * every request is planned once into a :class:`~repro.core.plan.JoinPlan`
    and executed via ``core.engine.execute``;
  * plans are memoized in an LRU :class:`~repro.core.planner.PlanCache`
    keyed by (query structure, stats fingerprint), so repeated pattern
    shapes skip planning entirely — ``plan_cache_info()`` exposes the
    hit/miss counters;
  * ``execute_many`` groups same-plan requests so the vectorized LFTJ's
    jitted level kernels (whose static shapes depend only on the plan)
    amortize compilation across the group;
  * graphs at or above ``dist_edge_threshold`` directed edges route
    their ``vlftj`` plans through
    :class:`repro.dist.sharded_join.PartitionedJoin` (granularity-factor
    work splitting; the result's engine label gains ``+partitioned`` and
    ``last_dist_stats`` exposes the partition makespan).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from ..core import GraphDB, GraphStats, JoinPlan, PlanCache, execute, \
    get_query
from ..graphs import CSRGraph, node_sample


@dataclass
class QueryRequest:
    query_name: str
    selectivity: float | None = None   # regenerate v1/v2 samples at 1/s
    seed: int = 0
    engine: str = "auto"


@dataclass
class QueryResult:
    request: QueryRequest
    count: int
    engine: str
    latency_s: float
    plan: JoinPlan | None = None
    plan_cached: bool = False


class QueryServer:
    def __init__(self, csr: CSRGraph, default_selectivity: float = 10.0,
                 plan_cache_size: int = 256,
                 dist_edge_threshold: int | None = 1 << 22,
                 dist_workers: int = 4, dist_granularity: int = 2):
        self.csr = csr
        self.default_selectivity = default_selectivity
        self._warm: dict = {}
        self._stats: dict = {}
        self.plan_cache = PlanCache(maxsize=plan_cache_size)
        # graphs at or above dist_edge_threshold directed edges run their
        # vlftj plans through dist.PartitionedJoin (granularity-factor
        # over-partitioning); None disables the route entirely.
        self.dist_edge_threshold = dist_edge_threshold
        self.dist_workers = dist_workers
        self.dist_granularity = dist_granularity
        self.last_dist_stats: dict | None = None
        self._dist_joins: dict = {}

    def _routes_to_dist(self, plan: JoinPlan, gdb: GraphDB) -> bool:
        return (self.dist_edge_threshold is not None
                and plan.engine == "vlftj"
                and gdb.csr.n_edges >= self.dist_edge_threshold)

    def _execute_plan(self, plan: JoinPlan, gdb: GraphDB,
                      req: QueryRequest) -> tuple[int, str]:
        """(count, engine label); large graphs take the partitioned path."""
        if self._routes_to_dist(plan, gdb):
            from ..dist.sharded_join import PartitionedJoin
            # memoize per (plan, graph): the seed-domain sort and the
            # part schedule amortize over same-plan request groups just
            # like the jitted level kernels do
            key = (plan, id(gdb))
            pj = self._dist_joins.get(key)
            if pj is None:
                pj = PartitionedJoin(get_query(req.query_name), gdb,
                                     n_workers=self.dist_workers,
                                     granularity=self.dist_granularity,
                                     plan=plan)
                self._dist_joins[key] = pj
            c = pj.count()
            self.last_dist_stats = pj.stats
            return c, plan.engine + "+partitioned"
        return execute(plan, gdb), plan.engine

    def _gdb_for(self, selectivity: float, seed: int) -> GraphDB:
        key = (round(selectivity, 6), seed)
        if key not in self._warm:
            unary = {f"v{i}": node_sample(self.csr.n_nodes, selectivity,
                                          seed=seed * 7 + i)
                     for i in range(1, 5)}
            self._warm[key] = GraphDB(self.csr, unary)
        return self._warm[key]

    def _stats_for(self, gdb: GraphDB) -> GraphStats:
        key = id(gdb)
        if key not in self._stats:
            self._stats[key] = GraphStats.of(gdb)
        return self._stats[key]

    def _plan_for(self, req: QueryRequest, gdb: GraphDB
                  ) -> tuple[JoinPlan, bool]:
        """(plan, was_cache_hit) for one request."""
        q = get_query(req.query_name)
        stats = self._stats_for(gdb)
        hits_before = self.plan_cache.hits
        plan = self.plan_cache.get_or_plan(q, stats, req.engine)
        return plan, self.plan_cache.hits > hits_before

    def plan_cache_info(self) -> dict:
        return {"hits": self.plan_cache.hits,
                "misses": self.plan_cache.misses,
                "size": len(self.plan_cache)}

    def execute(self, req: QueryRequest) -> QueryResult:
        sel = req.selectivity or self.default_selectivity
        gdb = self._gdb_for(sel, req.seed)
        t0 = time.time()
        plan, cached = self._plan_for(req, gdb)
        c, label = self._execute_plan(plan, gdb, req)
        return QueryResult(req, c, label, time.time() - t0,
                           plan=plan, plan_cached=cached)

    def execute_batch(self, reqs: list[QueryRequest]) -> list[QueryResult]:
        # group by (selectivity, seed) so the device graph stays warm
        order = sorted(range(len(reqs)),
                       key=lambda i: (reqs[i].selectivity or 0,
                                      reqs[i].seed))
        results: list[QueryResult | None] = [None] * len(reqs)
        for i in order:
            results[i] = self.execute(reqs[i])
        return results  # type: ignore

    def execute_many(self, reqs: list[QueryRequest]) -> list[QueryResult]:
        """Plan-grouped batched execution.

        Requests are planned first (warming the plan cache), then grouped
        by (plan, graph) and executed group-by-group: consecutive
        executions of the same plan reuse the jitted level kernels —
        their static shapes are a function of the plan alone — so one
        cold compile amortizes over the whole group, and the device
        graph stays warm within a group.
        """
        prepared = []   # (index, plan, cached, gdb, plan_s)
        for i, req in enumerate(reqs):
            sel = req.selectivity or self.default_selectivity
            gdb = self._gdb_for(sel, req.seed)
            t0 = time.time()
            plan, cached = self._plan_for(req, gdb)
            prepared.append((i, plan, cached, gdb, time.time() - t0))
        # same-plan requests become adjacent; ties keep graph groups warm
        groups: dict[tuple, list] = {}
        for item in prepared:
            groups.setdefault((item[1], id(item[3])), []).append(item)
        results: list[QueryResult | None] = [None] * len(reqs)
        for (_plan, _gid), items in groups.items():
            for i, plan, cached, gdb, plan_s in items:
                t0 = time.time()
                c, label = self._execute_plan(plan, gdb, reqs[i])
                # latency_s matches execute(): planning share + execution
                results[i] = QueryResult(
                    reqs[i], c, label, plan_s + time.time() - t0,
                    plan=plan, plan_cached=cached)
        return results  # type: ignore
