"""Batched graph-pattern query serving — the paper's workload as a service.

The RDBMS story of the paper is interactive: clients submit pattern
queries (with per-request node samples / selectivities) against a resident
graph.  ``QueryServer`` keeps the device-resident CSR trie warm and now
serves through the plan/execute split (``core.plan`` / ``core.planner``):

  * every request is planned once into a :class:`~repro.core.plan.JoinPlan`
    and executed via ``core.engine.execute``;
  * plans are memoized in an LRU :class:`~repro.core.planner.PlanCache`
    keyed by (query structure, stats fingerprint), so repeated pattern
    shapes skip planning entirely — ``plan_cache_info()`` exposes the
    hit/miss counters;
  * ``execute_many`` groups same-plan requests so the vectorized LFTJ's
    jitted level kernels (whose static shapes depend only on the plan)
    amortize compilation across the group;
  * graphs at or above ``dist_edge_threshold`` directed edges route
    their ``vlftj`` plans through
    :class:`repro.dist.sharded_join.PartitionedJoin` (granularity-factor
    work splitting; the result's engine label gains ``+partitioned`` and
    ``last_dist_stats`` exposes the partition makespan);
  * requests with ``limit=`` (or a continuation ``cursor=``) return
    *rows*, not counts: the server opens a bounded-memory
    :class:`~repro.results.ResultCursor` (``core.engine.stream`` — plans
    resolve with ``output='rows'`` through the same plan cache, so
    same-plan grouping is preserved), hands back one page plus an opaque
    ``next_cursor`` token, and resumes the cursor on the next request
    without re-planning or re-executing the prefix.  Dist-routed rows
    requests stream ``PartitionedJoin.pages`` (per-part cursors merged
    in GAO order).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from ..core import GraphDB, GraphStats, JoinPlan, PlanCache, execute, \
    get_query
from ..core import engine as engine_mod
from ..graphs import CSRGraph, node_sample
from ..results import ResultCursor


@dataclass
class QueryRequest:
    query_name: str
    selectivity: float | None = None   # regenerate v1/v2 samples at 1/s
    seed: int = 0
    engine: str = "auto"
    # enumeration: limit= asks for (up to) that many rows; cursor= resumes
    # a previous response's next_cursor token (limit then sizes the page)
    limit: int | None = None
    cursor: str | None = None

    @property
    def wants_rows(self) -> bool:
        return self.limit is not None or self.cursor is not None


@dataclass
class QueryResult:
    request: QueryRequest
    count: int
    engine: str
    latency_s: float
    plan: JoinPlan | None = None
    plan_cached: bool = False
    # enumeration responses: one page of output tuples (count = page
    # rows), its column order, and the continuation token (None when the
    # result set is exhausted)
    rows: np.ndarray | None = None
    row_vars: tuple[str, ...] | None = None
    next_cursor: str | None = field(default=None)


class QueryServer:
    def __init__(self, csr: CSRGraph, default_selectivity: float = 10.0,
                 plan_cache_size: int = 256,
                 dist_edge_threshold: int | None = 1 << 22,
                 dist_workers: int = 4, dist_granularity: int = 2,
                 page_rows: int = 1024, max_open_cursors: int = 64):
        self.csr = csr
        self.default_selectivity = default_selectivity
        self._warm: dict = {}
        self._stats: dict = {}
        self.plan_cache = PlanCache(maxsize=plan_cache_size)
        # graphs at or above dist_edge_threshold directed edges run their
        # vlftj plans through dist.PartitionedJoin (granularity-factor
        # over-partitioning); None disables the route entirely.
        self.dist_edge_threshold = dist_edge_threshold
        self.dist_workers = dist_workers
        self.dist_granularity = dist_granularity
        self.last_dist_stats: dict | None = None
        self._dist_joins: dict = {}
        # open enumeration cursors: token -> (cursor, engine label, plan),
        # LRU-capped at max_open_cursors so abandoned paginations (a
        # client that never follows next_cursor) cannot accumulate
        # frontier arrays for the life of the server.  _closed remembers
        # *why* a token is gone ('evicted' vs 'exhausted') so the resume
        # error can tell a client whether restarting pagination would
        # help — an evicted stream is restartable, an exhausted one was
        # fully delivered (bounded: tokens are monotonic, keep the tail)
        self.page_rows = page_rows
        self.max_open_cursors = max_open_cursors
        self._cursors: "OrderedDict[str, tuple[ResultCursor, str, JoinPlan]]" \
            = OrderedDict()
        self._closed: "OrderedDict[str, str]" = OrderedDict()
        self._cursor_seq = 0

    def _close_cursor(self, token: str, reason: str) -> None:
        self._cursors.pop(token, None)
        self._closed[token] = reason
        while len(self._closed) > 4 * self.max_open_cursors:
            self._closed.popitem(last=False)

    def _routes_to_dist(self, plan: JoinPlan, gdb: GraphDB) -> bool:
        return (self.dist_edge_threshold is not None
                and plan.engine == "vlftj"
                and gdb.csr.n_edges >= self.dist_edge_threshold)

    def _dist_join_for(self, plan: JoinPlan, gdb: GraphDB,
                       req: QueryRequest):
        """Memoized per (plan, graph): the seed-domain sort and the part
        schedule amortize over same-plan request groups just like the
        jitted level kernels do."""
        from ..dist.sharded_join import PartitionedJoin
        # count and rows plans for one query differ only in output_mode,
        # which the partition layer never reads — share one instance
        key = (plan.query.atoms, plan.query.filters, plan.gao, id(gdb))
        pj = self._dist_joins.get(key)
        if pj is None:
            pj = PartitionedJoin(get_query(req.query_name), gdb,
                                 n_workers=self.dist_workers,
                                 granularity=self.dist_granularity,
                                 plan=plan)
            self._dist_joins[key] = pj
        return pj

    def _execute_plan(self, plan: JoinPlan, gdb: GraphDB,
                      req: QueryRequest) -> tuple[int, str]:
        """(count, engine label); large graphs take the partitioned path."""
        if self._routes_to_dist(plan, gdb):
            pj = self._dist_join_for(plan, gdb, req)
            c = pj.count()
            self.last_dist_stats = pj.stats
            return c, plan.engine + "+partitioned"
        return execute(plan, gdb), plan.engine

    def _gdb_for(self, selectivity: float, seed: int) -> GraphDB:
        key = (round(selectivity, 6), seed)
        if key not in self._warm:
            unary = {f"v{i}": node_sample(self.csr.n_nodes, selectivity,
                                          seed=seed * 7 + i)
                     for i in range(1, 5)}
            self._warm[key] = GraphDB(self.csr, unary)
        return self._warm[key]

    def _stats_for(self, gdb: GraphDB) -> GraphStats:
        key = id(gdb)
        if key not in self._stats:
            self._stats[key] = GraphStats.of(gdb)
        return self._stats[key]

    def _plan_for(self, req: QueryRequest, gdb: GraphDB,
                  output: str = "count") -> tuple[JoinPlan, bool]:
        """(plan, was_cache_hit) for one request."""
        q = get_query(req.query_name)
        stats = self._stats_for(gdb)
        hits_before = self.plan_cache.hits
        plan = self.plan_cache.get_or_plan(q, stats, req.engine,
                                           output=output)
        return plan, self.plan_cache.hits > hits_before

    def plan_cache_info(self) -> dict:
        return {"hits": self.plan_cache.hits,
                "misses": self.plan_cache.misses,
                "size": len(self.plan_cache)}

    # -- enumeration / pagination -------------------------------------------
    def _open_cursor(self, plan: JoinPlan, gdb: GraphDB,
                     req: QueryRequest) -> tuple[ResultCursor, str]:
        """(cursor, engine label); large graphs stream the merged
        per-part pages of the partitioned join."""
        q = get_query(req.query_name)
        if self._routes_to_dist(plan, gdb):
            pj = self._dist_join_for(plan, gdb, req)
            cur = ResultCursor.from_blocks(
                pj.executor.gao, pj.pages(page_rows=self.page_rows),
                page_rows=self.page_rows)
            return cur, plan.engine + "+partitioned"
        return engine_mod.stream(q, gdb, plan=plan,
                                 page_rows=self.page_rows), plan.engine

    def _rows_result(self, req: QueryRequest, cur: ResultCursor,
                     label: str, plan: JoinPlan | None, cached: bool,
                     token: str | None, t0: float) -> QueryResult:
        page = cur.take(req.limit if req.limit is not None
                        else self.page_rows)
        if cur.exhausted:
            if token is not None:
                self._close_cursor(token, "exhausted")
            token = None
        elif token is None:
            self._cursor_seq += 1
            token = f"cur-{self._cursor_seq}"
            self._cursors[token] = (cur, label, plan)
            while len(self._cursors) > self.max_open_cursors:
                self._close_cursor(next(iter(self._cursors)), "evicted")
        else:
            self._cursors.move_to_end(token)
        return QueryResult(req, int(page.shape[0]), label,
                           time.time() - t0, plan=plan, plan_cached=cached,
                           rows=page, row_vars=cur.vars, next_cursor=token)

    def execute(self, req: QueryRequest) -> QueryResult:
        t0 = time.time()
        if req.cursor is not None:
            try:
                cur, label, plan = self._cursors[req.cursor]
            except KeyError:
                reason = self._closed.get(req.cursor)
                if reason == "evicted":
                    raise ValueError(
                        f"evicted cursor {req.cursor!r}: the server keeps "
                        f"at most {self.max_open_cursors} open cursors and "
                        "this one aged out — restart pagination from the "
                        "first page") from None
                if reason == "exhausted":
                    raise ValueError(
                        f"exhausted cursor {req.cursor!r}: the result set "
                        "was fully delivered; do not restart") from None
                raise ValueError(
                    f"unknown cursor {req.cursor!r}") from None
            return self._rows_result(req, cur, label, plan, True,
                                     req.cursor, t0)
        sel = req.selectivity or self.default_selectivity
        gdb = self._gdb_for(sel, req.seed)
        if req.wants_rows:
            plan, cached = self._plan_for(req, gdb, output="rows")
            cur, label = self._open_cursor(plan, gdb, req)
            return self._rows_result(req, cur, label, plan, cached,
                                     None, t0)
        plan, cached = self._plan_for(req, gdb)
        c, label = self._execute_plan(plan, gdb, req)
        return QueryResult(req, c, label, time.time() - t0,
                           plan=plan, plan_cached=cached)

    def execute_batch(self, reqs: list[QueryRequest]) -> list[QueryResult]:
        # group by (selectivity, seed) so the device graph stays warm
        order = sorted(range(len(reqs)),
                       key=lambda i: (reqs[i].selectivity or 0,
                                      reqs[i].seed))
        results: list[QueryResult | None] = [None] * len(reqs)
        for i in order:
            results[i] = self.execute(reqs[i])
        return results  # type: ignore

    def execute_many(self, reqs: list[QueryRequest]) -> list[QueryResult]:
        """Plan-grouped batched execution.

        Requests are planned first (warming the plan cache), then grouped
        by (plan, graph) and executed group-by-group: consecutive
        executions of the same plan reuse the jitted level kernels —
        their static shapes are a function of the plan alone — so one
        cold compile amortizes over the whole group, and the device
        graph stays warm within a group.  Enumeration requests
        (``limit=``) plan with ``output='rows'`` and group the same way;
        cursor continuations already hold their machinery and run
        directly.
        """
        prepared = []   # (index, plan, cached, gdb, plan_s)
        results: list[QueryResult | None] = [None] * len(reqs)
        for i, req in enumerate(reqs):
            if req.cursor is not None:
                results[i] = self.execute(req)
                continue
            sel = req.selectivity or self.default_selectivity
            gdb = self._gdb_for(sel, req.seed)
            t0 = time.time()
            plan, cached = self._plan_for(
                req, gdb, output="rows" if req.wants_rows else "count")
            prepared.append((i, plan, cached, gdb, time.time() - t0))
        # same-plan requests become adjacent; ties keep graph groups warm
        groups: dict[tuple, list] = {}
        for item in prepared:
            groups.setdefault((item[1], id(item[3])), []).append(item)
        for (_plan, _gid), items in groups.items():
            for i, plan, cached, gdb, plan_s in items:
                t0 = time.time()
                if reqs[i].wants_rows:
                    cur, label = self._open_cursor(plan, gdb, reqs[i])
                    results[i] = self._rows_result(
                        reqs[i], cur, label, plan, cached, None,
                        t0 - plan_s)
                    continue
                c, label = self._execute_plan(plan, gdb, reqs[i])
                # latency_s matches execute(): planning share + execution
                results[i] = QueryResult(
                    reqs[i], c, label, plan_s + time.time() - t0,
                    plan=plan, plan_cached=cached)
        return results  # type: ignore
