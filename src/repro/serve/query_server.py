"""Batched graph-pattern query serving — the paper's workload as a service.

The RDBMS story of the paper is interactive: clients submit pattern
queries (with per-request node samples / selectivities) against a resident
graph.  ``QueryServer`` keeps the device-resident CSR trie warm, routes
each request to the winning engine (auto heuristic from the benchmark
summary: Minesweeper-analogue for acyclic, hybrid for lollipops, LFTJ for
cyclic), executes batches of requests, and reports per-request latency —
the serving analogue of Table 6/7.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core import GraphDB, count as engine_count, get_query, pick_engine
from ..graphs import CSRGraph, node_sample


@dataclass
class QueryRequest:
    query_name: str
    selectivity: float | None = None   # regenerate v1/v2 samples at 1/s
    seed: int = 0
    engine: str = "auto"


@dataclass
class QueryResult:
    request: QueryRequest
    count: int
    engine: str
    latency_s: float


class QueryServer:
    def __init__(self, csr: CSRGraph, default_selectivity: float = 10.0):
        self.csr = csr
        self.default_selectivity = default_selectivity
        self._warm: dict = {}

    def _gdb_for(self, selectivity: float, seed: int) -> GraphDB:
        key = (round(selectivity, 6), seed)
        if key not in self._warm:
            unary = {f"v{i}": node_sample(self.csr.n_nodes, selectivity,
                                          seed=seed * 7 + i)
                     for i in range(1, 5)}
            self._warm[key] = GraphDB(self.csr, unary)
        return self._warm[key]

    def execute(self, req: QueryRequest) -> QueryResult:
        q = get_query(req.query_name)
        sel = req.selectivity or self.default_selectivity
        gdb = self._gdb_for(sel, req.seed)
        engine = req.engine if req.engine != "auto" else pick_engine(q)
        t0 = time.time()
        c = engine_count(q, gdb, engine=engine)
        return QueryResult(req, c, engine, time.time() - t0)

    def execute_batch(self, reqs: list[QueryRequest]) -> list[QueryResult]:
        # group by (selectivity, seed) so the device graph stays warm
        order = sorted(range(len(reqs)),
                       key=lambda i: (reqs[i].selectivity or 0,
                                      reqs[i].seed))
        results: list[QueryResult | None] = [None] * len(reqs)
        for i in order:
            results[i] = self.execute(reqs[i])
        return results  # type: ignore
