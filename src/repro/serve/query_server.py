"""Batched graph-pattern query serving — the paper's workload as a service.

The RDBMS story of the paper is interactive: clients submit pattern
queries (with per-request node samples / selectivities) against a resident
graph.  ``QueryServer`` keeps the device-resident CSR trie warm and now
serves through the plan/execute split (``core.plan`` / ``core.planner``):

  * every request is planned once into a :class:`~repro.core.plan.JoinPlan`
    and executed via ``core.engine.execute``;
  * plans are memoized in an LRU :class:`~repro.core.planner.PlanCache`
    keyed by (query structure, stats fingerprint), so repeated pattern
    shapes skip planning entirely — ``plan_cache_info()`` exposes the
    hit/miss counters;
  * ``execute_many`` groups same-plan requests so the vectorized LFTJ's
    jitted level kernels (whose static shapes depend only on the plan)
    amortize compilation across the group;
  * graphs at or above ``dist_edge_threshold`` directed edges route
    their ``vlftj`` plans through
    :class:`repro.dist.sharded_join.PartitionedJoin` (granularity-factor
    work splitting; the result's engine label gains ``+partitioned`` and
    ``last_dist_stats`` exposes the partition makespan);
  * requests with ``limit=`` (or a continuation ``cursor=``) return
    *rows*, not counts: the server opens a bounded-memory
    :class:`~repro.results.ResultCursor` (``core.engine.stream`` — plans
    resolve with ``output='rows'`` through the same plan cache, so
    same-plan grouping is preserved), hands back one page plus an opaque
    ``next_cursor`` token, and resumes the cursor on the next request
    without re-planning or re-executing the prefix.  Dist-routed rows
    requests stream ``PartitionedJoin.pages`` (per-part cursors merged
    in GAO order).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from ..core import GraphDB, GraphStats, JoinPlan, PlanCache, get_query
from ..core import engine as engine_mod
from ..graphs import CSRGraph, node_sample
from ..obs import DeviceProfile, MetricsRegistry, QueryTrace, \
    get_registry, normalize_engine_stats
from ..results import ResultCursor


@dataclass
class QueryRequest:
    """One client request against the resident graph.

    ``query_name`` picks a paper pattern (``repro.core.PAPER_QUERIES``);
    ``selectivity``/``seed`` regenerate the per-request unary samples;
    ``engine`` pins a physical operator (default: planner's choice).
    ``limit`` turns the request into enumeration (one page of up to
    ``limit`` rows) and ``cursor`` resumes a previous response's
    ``next_cursor`` token.  ``tenant`` names the quota bucket the
    preemptive scheduler (``repro.serve.scheduler``) meters admission
    and parked-frontier bytes against; the plain ``execute`` path
    ignores it.
    """

    query_name: str
    selectivity: float | None = None   # regenerate v1/v2 samples at 1/s
    seed: int = 0
    engine: str = "auto"
    # enumeration: limit= asks for (up to) that many rows; cursor= resumes
    # a previous response's next_cursor token (limit then sizes the page)
    limit: int | None = None
    cursor: str | None = None
    tenant: str = "default"
    #: record a :class:`repro.obs.QueryTrace` for this request — per-level
    #: est/obs cardinality, scheduler and exchange events — returned as
    #: ``QueryResult.trace``.  Off by default: a disabled tracer costs
    #: nothing (``tests/test_obs.py`` guards zero extra device dispatches).
    trace: bool = False
    #: record a :class:`repro.obs.DeviceProfile` for this request — jit
    #: compile/call counts + compile wall, per-kernel wall breakdown,
    #: memory watermarks — returned as ``QueryResult.profile`` and
    #: published into the server's metrics registry.  Off by default with
    #: the same zero-device-dispatch guarantee (``tests/test_profile.py``).
    profile: bool = False

    @property
    def wants_rows(self) -> bool:
        return self.limit is not None or self.cursor is not None


@dataclass
class QueryResult:
    """One response: the count (or page-row count), the engine label
    that actually ran, and observability in ``stats`` — always the
    server's ``plan_cache`` hit/miss counters and cursor-registry state
    (open cursors + closed-token reason tallies); direct (unscheduled)
    count responses add ``stats["engine"]``, the unified per-engine
    schema
    (:data:`repro.obs.ENGINE_REQUIRED_KEYS` — rows expanded, kernel
    dispatches, jit calls/compiles, per-level rows/wall/paths, with the
    engine's native counters under ``raw``); scheduled results add the
    scheduling counters (``quanta``/``preemptions``/``restarts``/
    ``rows_expanded``/``quantum_rows_initial``/``quantum_rows_final``/
    ``vclock_*``).  The full key namespace is documented in
    ``docs/OBSERVABILITY.md``."""

    request: QueryRequest
    count: int
    engine: str
    latency_s: float
    plan: JoinPlan | None = None
    plan_cached: bool = False
    # enumeration responses: one page of output tuples (count = page
    # rows), its column order, and the continuation token (None when the
    # result set is exhausted)
    rows: np.ndarray | None = None
    row_vars: tuple[str, ...] | None = None
    next_cursor: str | None = field(default=None)
    stats: dict = field(default_factory=dict)
    #: the request's :class:`repro.obs.QueryTrace` when ``req.trace`` was
    #: set (export with ``trace.to_jsonl()``); None otherwise.
    trace: QueryTrace | None = None
    #: the request's :class:`repro.obs.DeviceProfile` when ``req.profile``
    #: was set (export with ``profile.to_dict()``); None otherwise.
    profile: DeviceProfile | None = None


class QueryServer:
    def __init__(self, csr: CSRGraph, default_selectivity: float = 10.0,
                 plan_cache_size: int = 256,
                 dist_edge_threshold: int | None = 1 << 22,
                 dist_workers: int = 4, dist_granularity: int = 2,
                 page_rows: int = 1024, max_open_cursors: int = 64,
                 metrics: MetricsRegistry | None = None,
                 request_log: str | None = None):
        self.csr = csr
        # structured request log: one JSON line per execute() call —
        # trace_id, query, tenant, engine, count, latency, status — with
        # the same trace_id stamped into the request's QueryTrace /
        # DeviceProfile meta for correlation (schema:
        # docs/OBSERVABILITY.md).  None disables logging entirely.
        self.request_log = request_log
        self._log_lock = threading.Lock()
        self._request_seq = 0
        # process metrics: plan-cache traffic, cursor closes by reason,
        # scheduler quanta, pool makespans — one registry, snapshotted by
        # metrics().  Default: the process-wide registry; pass a private
        # MetricsRegistry for isolation.
        self.metrics_registry = metrics if metrics is not None \
            else get_registry()
        self.default_selectivity = default_selectivity
        self._warm: dict = {}
        self._stats: dict = {}
        self.plan_cache = PlanCache(maxsize=plan_cache_size)
        # graphs at or above dist_edge_threshold directed edges run their
        # vlftj plans through dist.PartitionedJoin (granularity-factor
        # over-partitioning); None disables the route entirely.
        self.dist_edge_threshold = dist_edge_threshold
        self.dist_workers = dist_workers
        self.dist_granularity = dist_granularity
        self.last_dist_stats: dict | None = None
        self._dist_joins: dict = {}
        # open enumeration cursors: token -> (cursor, engine label, plan),
        # LRU-capped at max_open_cursors so abandoned paginations (a
        # client that never follows next_cursor) cannot accumulate
        # frontier arrays for the life of the server.  _closed remembers
        # *why* a token is gone ('evicted' vs 'exhausted') so the resume
        # error can tell a client whether restarting pagination would
        # help — an evicted stream is restartable, an exhausted one was
        # fully delivered (bounded: tokens are monotonic, keep the tail)
        self.page_rows = page_rows
        self.max_open_cursors = max_open_cursors
        self._cursors: "OrderedDict[str, tuple[ResultCursor, str, JoinPlan]]" \
            = OrderedDict()
        self._closed: "OrderedDict[str, str]" = OrderedDict()
        self._close_reasons: dict[str, int] = {}
        self._cursor_seq = 0

    def _close_cursor(self, token: str, reason: str) -> None:
        """Drop a registry entry, remembering *why* (``'exhausted'`` |
        ``'evicted'`` | ``'quota'``) for the resume-error message and
        the ``cursor_info()`` tallies."""
        self._cursors.pop(token, None)
        self._closed[token] = reason
        self._close_reasons[reason] = self._close_reasons.get(reason, 0) + 1
        self.metrics_registry.counter("server_cursor_closed",
                                      reason=reason).inc()
        while len(self._closed) > 4 * self.max_open_cursors:
            self._closed.popitem(last=False)

    def _register_cursor(self, payload, label: str, plan: JoinPlan | None,
                         token: str | None = None) -> str:
        """Park a payload (pagination cursor or a scheduler
        :class:`~repro.serve.scheduler.PlanSnapshot`) in the LRU
        registry; the oldest entries are evicted past
        ``max_open_cursors`` with reason ``'evicted'``."""
        if token is None:
            self._cursor_seq += 1
            token = f"cur-{self._cursor_seq}"
        self._cursors[token] = (payload, label, plan)
        self._cursors.move_to_end(token)
        while len(self._cursors) > self.max_open_cursors:
            self._close_cursor(next(iter(self._cursors)), "evicted")
        return token

    def cursor_info(self) -> dict:
        """Registry observability: open-entry count and closed-token
        reason tallies — surfaced in every ``QueryResult.stats``."""
        return {"open": len(self._cursors),
                "closed": dict(self._close_reasons)}

    def _result_stats(self, engine_stats: dict | None = None) -> dict:
        out = {"plan_cache": self.plan_cache_info(),
               "cursors": self.cursor_info()}
        if engine_stats is not None:
            out["engine"] = engine_stats
        return out

    def metrics(self) -> dict:
        """Snapshot of the server's :class:`~repro.obs.MetricsRegistry`:
        every counter/gauge/histogram series as ``"name{labels}" ->
        value`` (the full catalog is docs/OBSERVABILITY.md).  Level
        gauges (open cursors, plan-cache size) are refreshed here, so a
        snapshot is always current."""
        reg = self.metrics_registry
        reg.gauge("server_open_cursors").set(len(self._cursors))
        reg.gauge("server_plan_cache_size").set(len(self.plan_cache))
        reg.counter("server_metrics_snapshots").inc()
        return reg.snapshot()

    # -- request log ---------------------------------------------------------
    def _next_trace_id(self) -> str:
        with self._log_lock:
            self._request_seq += 1
            return f"req-{self._request_seq}"

    def _log_request(self, trace_id: str, req: QueryRequest,
                     t0: float, result: QueryResult | None = None,
                     error: Exception | None = None) -> None:
        """Append one JSON line to the structured request log.

        The line carries the generated ``trace_id`` — the same id
        stamped into the request's trace/profile meta — so a log entry
        joins to its exported trace artifact.  No-op when the server has
        no ``request_log``.
        """
        if self.request_log is None:
            return
        rec = {"ts": round(time.time(), 3), "trace_id": trace_id,
               "query": req.query_name, "tenant": req.tenant,
               "status": "ok" if error is None else "error",
               "latency_s": round((result.latency_s if result is not None
                                   else time.time() - t0), 6),
               "engine": (result.engine if result is not None
                          else req.engine)}
        if result is not None:
            rec["count"] = result.count
            rec["plan_cached"] = bool(result.plan_cached)
            if result.next_cursor is not None:
                rec["next_cursor"] = result.next_cursor
            rec["traced"] = result.trace is not None
            if result.profile is not None:
                prof = result.profile
                rec["profile"] = {
                    "jit_compiles": prof.jit["compiles"],
                    "jit_calls": prof.jit["calls"],
                    "compile_wall_s": round(prof.jit["compile_wall_s"], 6),
                    "peak_live_bytes": prof.memory["peak_live_bytes"]}
        if error is not None:
            rec["error"] = f"{type(error).__name__}: {error}"
        self.metrics_registry.counter("server_requests",
                                      status=rec["status"]).inc()
        line = json.dumps(rec)
        with self._log_lock:
            with open(self.request_log, "a") as f:
                f.write(line + "\n")

    def _routes_to_dist(self, plan: JoinPlan, gdb: GraphDB) -> bool:
        return (self.dist_edge_threshold is not None
                and plan.engine == "vlftj"
                and gdb.csr.n_edges >= self.dist_edge_threshold)

    def _dist_join_for(self, plan: JoinPlan, gdb: GraphDB,
                       req: QueryRequest):
        """Memoized per (plan, graph): the seed-domain sort and the part
        schedule amortize over same-plan request groups just like the
        jitted level kernels do."""
        from ..dist.sharded_join import PartitionedJoin
        # count and rows plans for one query differ only in output_mode,
        # which the partition layer never reads — share one instance
        key = (plan.query.atoms, plan.query.filters, plan.gao, id(gdb))
        pj = self._dist_joins.get(key)
        if pj is None:
            pj = PartitionedJoin(get_query(req.query_name), gdb,
                                 n_workers=self.dist_workers,
                                 granularity=self.dist_granularity,
                                 plan=plan)
            self._dist_joins[key] = pj
        return pj

    def _execute_plan(self, plan: JoinPlan, gdb: GraphDB,
                      req: QueryRequest) -> tuple[int, str, dict]:
        """(count, engine label, normalized engine stats); large graphs
        take the partitioned path."""
        if self._routes_to_dist(plan, gdb):
            pj = self._dist_join_for(plan, gdb, req)
            c = pj.count()
            self.last_dist_stats = pj.stats
            label = plan.engine + "+partitioned"
            return c, label, normalize_engine_stats(label, pj.stats)
        c, stats = engine_mod.execute_stats(plan, gdb)
        return c, plan.engine, stats

    def _gdb_for(self, selectivity: float, seed: int) -> GraphDB:
        key = (round(selectivity, 6), seed)
        if key not in self._warm:
            unary = {f"v{i}": node_sample(self.csr.n_nodes, selectivity,
                                          seed=seed * 7 + i)
                     for i in range(1, 5)}
            self._warm[key] = GraphDB(self.csr, unary)
        return self._warm[key]

    def _stats_for(self, gdb: GraphDB) -> GraphStats:
        key = id(gdb)
        if key not in self._stats:
            self._stats[key] = GraphStats.of(gdb)
        return self._stats[key]

    def _plan_for(self, req: QueryRequest, gdb: GraphDB,
                  output: str = "count") -> tuple[JoinPlan, bool]:
        """(plan, was_cache_hit) for one request.

        Every served plan passes static verification
        (:func:`repro.analysis.verify_for_execution`) before dispatch;
        a :class:`repro.analysis.PlanVerificationError` propagates to
        the request's error result.  Verification memoizes on
        ``(plan, stats fingerprint)``, so cache hits re-verify at dict
        cost."""
        from ..analysis import verify_for_execution
        q = get_query(req.query_name)
        stats = self._stats_for(gdb)
        hits_before = self.plan_cache.hits
        plan = self.plan_cache.get_or_plan(q, stats, req.engine,
                                           output=output)
        hit = self.plan_cache.hits > hits_before
        self.metrics_registry.counter(
            "server_plan_cache", outcome="hit" if hit else "miss").inc()
        verify_for_execution(plan, gdb)
        return plan, hit

    def plan_cache_info(self) -> dict:
        return {"hits": self.plan_cache.hits,
                "misses": self.plan_cache.misses,
                "size": len(self.plan_cache)}

    # -- enumeration / pagination -------------------------------------------
    def _open_cursor(self, plan: JoinPlan, gdb: GraphDB,
                     req: QueryRequest) -> tuple[ResultCursor, str]:
        """(cursor, engine label); large graphs stream the merged
        per-part pages of the partitioned join."""
        q = get_query(req.query_name)
        if self._routes_to_dist(plan, gdb):
            pj = self._dist_join_for(plan, gdb, req)
            cur = ResultCursor.from_blocks(
                pj.executor.gao, pj.pages(page_rows=self.page_rows),
                page_rows=self.page_rows)
            return cur, plan.engine + "+partitioned"
        return engine_mod.stream(q, gdb, plan=plan,
                                 page_rows=self.page_rows), plan.engine

    def _rows_result(self, req: QueryRequest, cur: ResultCursor,
                     label: str, plan: JoinPlan | None, cached: bool,
                     token: str | None, t0: float,
                     trace_id: str | None = None) -> QueryResult:
        # per-page profile: the enumeration kernels (segment_outer)
        # dispatch inside take(), so the activation brackets it
        prof = (DeviceProfile(req.query_name, label) if req.profile
                else None)
        with contextlib.ExitStack() as stack:
            if prof is not None:
                stack.enter_context(prof.activate())
            page = cur.take(req.limit if req.limit is not None
                            else self.page_rows)
        if prof is not None:
            prof.set_meta(engine=label, tenant=req.tenant,
                          trace_id=trace_id)
            prof.publish(registry=self.metrics_registry)
        if cur.exhausted:
            if token is not None:
                self._close_cursor(token, "exhausted")
            token = None
        else:
            token = self._register_cursor(cur, label, plan, token=token)
        return QueryResult(req, int(page.shape[0]), label,
                           time.time() - t0, plan=plan, plan_cached=cached,
                           rows=page, row_vars=cur.vars, next_cursor=token,
                           stats=self._result_stats(), profile=prof)

    def execute(self, req: QueryRequest) -> QueryResult:
        """Run one request to completion (or to one cursor page).

        Args:
            req: count requests (no ``limit``/``cursor``) return the
                pattern count; ``limit=`` requests return one page of
                rows plus a ``next_cursor`` continuation token;
                ``cursor=`` requests resume a parked server-side cursor
                (``limit`` then sizes the page).

        Returns:
            A :class:`QueryResult`; ``stats`` carries the plan-cache
            counters and cursor-registry state at response time.

        Raises:
            ValueError: resuming a dead cursor token.  The message says
                why it died: ``evicted`` (LRU aged it out — restart
                pagination from the first page), ``exhausted`` (fully
                delivered — do not restart), or ``unknown`` (never
                issued, or aged out of the closed-token memory).
            KeyError: unknown ``query_name``.

        Example::

            r = server.execute(QueryRequest("3-path", limit=100))
            while r.next_cursor is not None:
                r = server.execute(QueryRequest(
                    "3-path", limit=100, cursor=r.next_cursor))

        For preemptive, fair scheduling of *concurrent* requests use
        :meth:`execute_concurrent` instead — this method runs a single
        request to completion and a heavy one will block the caller.
        """
        t0 = time.time()
        trace_id = self._next_trace_id()
        try:
            res = self._execute_impl(req, t0, trace_id)
        except Exception as e:
            self._log_request(trace_id, req, t0, error=e)
            raise
        self._log_request(trace_id, req, t0, result=res)
        return res

    def _execute_impl(self, req: QueryRequest, t0: float,
                      trace_id: str) -> QueryResult:
        if req.cursor is not None:
            try:
                cur, label, plan = self._cursors[req.cursor]
            except KeyError:
                reason = self._closed.get(req.cursor)
                if reason == "evicted":
                    raise ValueError(
                        f"evicted cursor {req.cursor!r}: the server keeps "
                        f"at most {self.max_open_cursors} open cursors and "
                        "this one aged out — restart pagination from the "
                        "first page") from None
                if reason == "exhausted":
                    raise ValueError(
                        f"exhausted cursor {req.cursor!r}: the result set "
                        "was fully delivered; do not restart") from None
                raise ValueError(
                    f"unknown cursor {req.cursor!r}") from None
            return self._rows_result(req, cur, label, plan, True,
                                     req.cursor, t0, trace_id)
        sel = req.selectivity or self.default_selectivity
        gdb = self._gdb_for(sel, req.seed)
        if req.wants_rows:
            plan, cached = self._plan_for(req, gdb, output="rows")
            cur, label = self._open_cursor(plan, gdb, req)
            return self._rows_result(req, cur, label, plan, cached,
                                     None, t0, trace_id)
        plan, cached = self._plan_for(req, gdb)
        if req.trace or req.profile:
            tr = (QueryTrace(req.query_name, plan.gao, plan.engine)
                  if req.trace else None)
            prof = (DeviceProfile(req.query_name, plan.engine)
                    if req.profile else None)
            with contextlib.ExitStack() as stack:
                if tr is not None:
                    stack.enter_context(tr.activate())
                if prof is not None:
                    stack.enter_context(prof.activate())
                c, label, estats = self._execute_plan(plan, gdb, req)
            if tr is not None:
                tr.set_meta(engine=label, tenant=req.tenant,
                            plan_cached=cached, trace_id=trace_id)
            if prof is not None:
                prof.set_meta(engine=label, tenant=req.tenant,
                              trace_id=trace_id)
                prof.publish(trace=tr, registry=self.metrics_registry)
            return QueryResult(req, c, label, time.time() - t0,
                               plan=plan, plan_cached=cached,
                               stats=self._result_stats(estats), trace=tr,
                               profile=prof)
        c, label, estats = self._execute_plan(plan, gdb, req)
        return QueryResult(req, c, label, time.time() - t0,
                           plan=plan, plan_cached=cached,
                           stats=self._result_stats(estats))

    def execute_batch(self, reqs: list[QueryRequest]) -> list[QueryResult]:
        """Run a batch sequentially, sorted by (selectivity, seed) so
        consecutive requests share a warm device graph.

        Args:
            reqs: any mix of count / enumeration / cursor requests.

        Returns:
            Results in the *original* request order (the warm-graph
            sort is internal).

        Each request still runs to completion before the next starts —
        no cross-request fairness.  Prefer :meth:`execute_many` for
        plan-grouped throughput, :meth:`execute_concurrent` for
        fairness under mixed light/heavy load.
        """
        # group by (selectivity, seed) so the device graph stays warm
        order = sorted(range(len(reqs)),
                       key=lambda i: (reqs[i].selectivity or 0,
                                      reqs[i].seed))
        results: list[QueryResult | None] = [None] * len(reqs)
        for i in order:
            results[i] = self.execute(reqs[i])
        return results  # type: ignore

    def execute_many(self, reqs: list[QueryRequest]) -> list[QueryResult]:
        """Plan-grouped batched execution (throughput-optimized).

        Requests are planned first (warming the plan cache), then grouped
        by (plan, graph) and executed group-by-group: consecutive
        executions of the same plan reuse the jitted level kernels —
        their static shapes are a function of the plan alone — so one
        cold compile amortizes over the whole group, and the device
        graph stays warm within a group.  Enumeration requests
        (``limit=``) plan with ``output='rows'`` and group the same way;
        cursor continuations already hold their machinery and run
        directly.

        Args:
            reqs: the batch; order of the returned results matches it.

        Returns:
            One :class:`QueryResult` per request; ``latency_s`` matches
            :meth:`execute` semantics (planning share + execution).

        Like :meth:`execute_batch` this optimizes *throughput*, not
        fairness — a heavy group member still runs to completion.  See
        :meth:`execute_concurrent` for quantum-sliced fairness.
        """
        prepared = []   # (index, plan, cached, gdb, plan_s)
        results: list[QueryResult | None] = [None] * len(reqs)
        for i, req in enumerate(reqs):
            if req.cursor is not None:
                results[i] = self.execute(req)
                continue
            sel = req.selectivity or self.default_selectivity
            gdb = self._gdb_for(sel, req.seed)
            t0 = time.time()
            plan, cached = self._plan_for(
                req, gdb, output="rows" if req.wants_rows else "count")
            prepared.append((i, plan, cached, gdb, time.time() - t0))
        # same-plan requests become adjacent; ties keep graph groups warm
        groups: dict[tuple, list] = {}
        for item in prepared:
            groups.setdefault((item[1], id(item[3])), []).append(item)
        for (_plan, _gid), items in groups.items():
            for i, plan, cached, gdb, plan_s in items:
                t0 = time.time()
                if reqs[i].wants_rows:
                    cur, label = self._open_cursor(plan, gdb, reqs[i])
                    results[i] = self._rows_result(
                        reqs[i], cur, label, plan, cached, None,
                        t0 - plan_s)
                    continue
                c, label, estats = self._execute_plan(plan, gdb, reqs[i])
                # latency_s matches execute(): planning share + execution
                results[i] = QueryResult(
                    reqs[i], c, label, plan_s + time.time() - t0,
                    plan=plan, plan_cached=cached,
                    stats=self._result_stats(estats))
        return results  # type: ignore

    def execute_concurrent(self, reqs: list[QueryRequest],
                           quantum_rows: int = 8192,
                           policy: str = "quantum",
                           quotas: dict | None = None,
                           collect_rows: bool = True
                           ) -> list[QueryResult]:
        """Fairness-optimized concurrent execution (preemptive).

        Admits every request into a
        :class:`~repro.serve.scheduler.QuantumScheduler` and round-robins
        quanta of ``quantum_rows`` expanded rows across them, so N small
        queries do not queue behind one heavy enumeration.  Per-tenant
        quotas (``req.tenant``) gate admission; a request rejected
        429-style comes back as a result with ``engine='rejected'`` and
        ``stats['status'] == 429`` instead of raising, so batch callers
        keep positional correspondence.

        Args:
            reqs: the concurrent batch (no ``cursor=`` continuations —
                those resume directly via :meth:`execute`).
            quantum_rows: the scheduling quantum, in expanded rows.
            policy: ``'quantum'`` (preemptive) or ``'fifo'`` (baseline).
            quotas: per-tenant ``{name: TenantQuota}`` overrides.
            collect_rows: buffer enumeration pages into results (False
                streams-and-discards, keeping memory bounded).

        Returns:
            Results in request order; scheduling stats (``quanta``,
            ``preemptions``, ``rows_expanded``, virtual clocks) ride in
            each ``QueryResult.stats``.
        """
        from .scheduler import AdmissionError, QuantumScheduler
        sched = QuantumScheduler(self, quantum_rows=quantum_rows,
                                 policy=policy, quotas=quotas)
        rejected: dict[int, QueryResult] = {}
        order: list[str] = []
        for i, req in enumerate(reqs):
            try:
                order.append(sched.submit(req, collect_rows=collect_rows))
            except AdmissionError as e:
                order.append("")
                rejected[i] = QueryResult(
                    req, 0, "rejected", 0.0,
                    stats={"status": e.status, "error": str(e)})
        sched.run()
        done = {j.token: j.result for j in sched._jobs
                if j.result is not None}
        return [rejected[i] if tok == "" else done[tok]
                for i, tok in enumerate(order)]
