"""Preemptive multi-tenant query scheduling — quantum-sliced execution.

``QueryServer.execute`` runs each request to completion, so one heavy
lollipop enumeration starves every small query queued behind it.  This
module adds SaGe-style *web preemption* on top of the engine's existing
suspend/resume machinery (``VLFTJ._run(start_level=)`` +
``JoinPlan.level_callback`` — the same level-boundary hook the
distributed rebalancer uses):

* :class:`PlanSnapshot` — the serializable suspended state of an
  in-flight plan: the partial-binding ``frontier``, its ``mult``
  multiplicities, the resume level, and (past the penultimate level)
  the final-phase tail state — rows already tallied (counts) or already
  delivered (enumeration).  ``to_bytes``/``from_bytes`` round-trip it
  without pickle.
* :class:`QuantumBudget` — a ``level_callback`` that charges every
  frontier the engine builds against a per-slice quantum measured in
  **rows expanded**, not wall time (deterministic, so fairness is
  testable), and raises :class:`Preempted` carrying a snapshot when the
  quantum is exhausted.  Suspension happens only at GAO level
  boundaries — the engine's host-visible synchronization points — so
  resume is loss-free by construction.
* :class:`QuantumScheduler` — a round-robin run queue over concurrent
  :class:`~repro.serve.query_server.QueryRequest` s: each job runs one
  quantum and either completes or parks its suspended state in the
  server's cursor registry (same LRU eviction and restart semantics as
  pagination cursors), then goes to the back of the queue.  Per-tenant
  quotas (max in-flight, max parked frontier bytes) gate admission
  429-style.

The quantum accounting unit: interior GAO levels charge the rows of
each frontier they build; the final level charges output rows as pages
stream (enumeration) or penultimate-frontier rows as count windows
tally (counting).  Both are exact, data-dependent, and reproducible
across runs — ``tests/test_scheduler.py`` asserts determinism and
row-for-row suspend/resume parity on every tier-1 query shape.
"""
from __future__ import annotations

import contextlib
import io
import json
import struct
import time
from collections import deque
from dataclasses import dataclass

import numpy as np

from ..core import VLFTJ, get_query
from ..core.plan import pow2ceil
from ..obs import DeviceProfile, QueryTrace
from ..results import ResultCursor
from .query_server import QueryRequest, QueryResult, QueryServer


# ---------------------------------------------------------------------------
# suspended state
# ---------------------------------------------------------------------------

@dataclass
class PlanSnapshot:
    """Serializable suspended state of an in-flight plan.

    ``frontier`` is the ``(rows, w)`` int32 array of partial bindings
    with ``w`` GAO columns bound; ``mult`` the ``(rows,)`` int64
    multiplicities.  ``phase`` says what the snapshot suspended:

    * ``'frontier'`` — an interior GAO level; resume feeds
      ``(frontier, mult)`` back into ``VLFTJ.advance`` /
      ``VLFTJ._run(start_level=)``;
    * ``'final'`` — the final level: ``frontier`` is the completed
      (lex-sorted) penultimate frontier, and the tail state is
      ``offset``/``partial_total`` for counting jobs or
      ``rows_emitted`` for enumeration jobs (resume via
      ``ResultCursor(frontier=..., skip_rows=rows_emitted)``).
    """

    query_name: str
    gao: tuple[str, ...]
    frontier: np.ndarray
    mult: np.ndarray
    phase: str = "frontier"    # 'frontier' | 'final'
    offset: int = 0            # final/count: frontier rows already tallied
    partial_total: int = 0     # final/count: weighted count so far
    rows_emitted: int = 0      # final/rows: output rows already delivered

    @property
    def start_level(self) -> int:
        """The GAO level execution resumes at (== bound column count)."""
        return int(self.frontier.shape[1])

    @property
    def nbytes(self) -> int:
        """Parked bytes — what the per-tenant frontier quota meters."""
        return int(self.frontier.nbytes + self.mult.nbytes)

    def to_bytes(self) -> bytes:
        """Pickle-free wire form: json header + two raw .npy arrays."""
        head = json.dumps({
            "query_name": self.query_name, "gao": list(self.gao),
            "phase": self.phase, "offset": self.offset,
            "partial_total": self.partial_total,
            "rows_emitted": self.rows_emitted,
        }).encode()
        buf = io.BytesIO()
        buf.write(struct.pack("<I", len(head)))
        buf.write(head)
        np.save(buf, np.ascontiguousarray(self.frontier, dtype=np.int32),
                allow_pickle=False)
        np.save(buf, np.ascontiguousarray(self.mult, dtype=np.int64),
                allow_pickle=False)
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, data: bytes) -> "PlanSnapshot":
        buf = io.BytesIO(data)
        (hlen,) = struct.unpack("<I", buf.read(4))
        head = json.loads(buf.read(hlen).decode())
        frontier = np.load(buf, allow_pickle=False)
        mult = np.load(buf, allow_pickle=False)
        return cls(head["query_name"], tuple(head["gao"]), frontier, mult,
                   phase=head["phase"], offset=head["offset"],
                   partial_total=head["partial_total"],
                   rows_emitted=head["rows_emitted"])


class Preempted(Exception):
    """Raised at a GAO level boundary when a quantum expires; carries
    the :class:`PlanSnapshot` that resumes the join loss-free."""

    def __init__(self, snapshot: PlanSnapshot):
        super().__init__(
            f"preempted at level {snapshot.start_level} "
            f"({snapshot.frontier.shape[0]} frontier rows)")
        self.snapshot = snapshot


class QuantumBudget:
    """``JoinPlan.level_callback`` that meters frontier rows expanded.

    Wraps (and runs first) any ``inner`` callback already on the plan —
    e.g. the distributed rebalancer — so budget accounting composes
    with adaptive execution.  ``charge`` is also called by the
    scheduler's final-phase loops, making this object the single meter
    a job's deterministic cost accumulates on (``total_rows``).
    """

    def __init__(self, quantum_rows: int | None, query_name: str,
                 gao: tuple[str, ...], inner=None):
        self.quantum_rows = quantum_rows   # None: never preempt (FIFO)
        self.query_name = query_name
        self.gao = gao
        self.inner = inner
        self.consumed = 0      # rows charged this slice
        self.total_rows = 0    # lifetime rows (the deterministic clock)

    def refill(self) -> None:
        self.consumed = 0

    def charge(self, rows: int) -> bool:
        """Add ``rows`` to the meters; True when the slice is spent."""
        self.consumed += int(rows)
        self.total_rows += int(rows)
        return (self.quantum_rows is not None
                and self.consumed >= self.quantum_rows)

    def __call__(self, level, frontier, mult):
        if self.inner is not None:
            upd = self.inner(level, frontier, mult)
            if upd is not None:
                frontier, mult = upd
        if self.charge(frontier.shape[0]):
            raise Preempted(PlanSnapshot(
                self.query_name, self.gao,
                np.asarray(frontier, dtype=np.int32),
                np.asarray(mult, dtype=np.int64)))
        return frontier, mult


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

class AdmissionError(RuntimeError):
    """429-style rejection: the tenant is over quota.  ``status`` mirrors
    the HTTP code a fronting server would return."""

    status = 429

    def __init__(self, tenant: str, reason: str):
        super().__init__(f"tenant {tenant!r} over quota: {reason}")
        self.tenant = tenant
        self.reason = reason


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission limits.

    ``max_in_flight`` caps concurrently admitted (queued or running)
    requests; ``max_frontier_bytes`` caps the bytes of suspended
    frontier state parked in the registry — the memory a preempted
    tenant is allowed to pin between quanta.
    """

    max_in_flight: int = 8
    max_frontier_bytes: int = 64 << 20


# ---------------------------------------------------------------------------
# jobs
# ---------------------------------------------------------------------------

class _Job:
    __slots__ = ("id", "token", "req", "tenant", "plan", "gdb", "label",
                 "budget", "executor", "window", "collect_rows", "pages",
                 "rows_collected", "quanta", "preemptions", "restarts",
                 "parked_nbytes", "t_submit", "vclock_submit", "result",
                 "seq", "trace", "profile", "quantum_rows_initial")

    def __init__(self, jid: int, req: QueryRequest, plan, gdb, label,
                 budget: QuantumBudget, collect_rows: bool, vclock: int):
        self.id = jid
        self.token = f"sched-{jid}"
        self.req = req
        self.tenant = req.tenant
        self.plan = plan
        self.gdb = gdb
        self.label = label
        self.budget = budget
        self.executor: VLFTJ | None = None
        self.window = 0
        self.collect_rows = collect_rows
        self.pages: list[np.ndarray] = []
        self.rows_collected = 0
        self.quanta = 0
        self.preemptions = 0
        self.restarts = 0
        self.parked_nbytes = 0
        self.t_submit = time.time()
        self.vclock_submit = vclock
        self.result: QueryResult | None = None
        # per-job trace (req.trace): preempt/resume/restart events land
        # here; the restart-backoff quantum growth is visible both as
        # events and in the result stats (quantum_rows_initial/_final)
        self.trace: QueryTrace | None = (
            QueryTrace(req.query_name, plan.gao, plan.engine)
            if req.trace else None)
        # per-job device profile (req.profile): jit compiles recorded
        # while this job runs carry a per-quantum attribution label
        # (``sched-<id>/q<k>``), set by the scheduler around each slice
        self.profile: DeviceProfile | None = (
            DeviceProfile(req.query_name, plan.engine)
            if req.profile else None)
        self.quantum_rows_initial = budget.quantum_rows


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

class QuantumScheduler:
    """Round-robin quantum scheduler over a :class:`QueryServer`.

    Args:
        server: the server whose plan cache, warm graphs, and cursor
            registry this scheduler shares.
        quantum_rows: rows expanded per scheduling slice (the quantum).
            Deterministic: the same workload preempts at the same
            boundaries on every run.
        policy: ``'quantum'`` (preemptive round-robin) or ``'fifo'``
            (run each job to completion in submission order — the
            baseline the serve benchmark compares against).
        quotas: per-tenant :class:`TenantQuota` overrides.
        default_quota: quota applied to tenants not in ``quotas``.

    Usage::

        sched = QuantumScheduler(server, quantum_rows=4096)
        sched.submit(QueryRequest("3-lollipop", limit=10**6))   # heavy
        sched.submit(QueryRequest("3-clique", tenant="b"))      # small
        results = sched.run()    # small completes long before heavy

    ``submit`` raises :class:`AdmissionError` (``status == 429``) when
    the tenant is over quota.  Suspended jobs park their state in the
    server's cursor registry under a ``sched-<n>`` token with the same
    LRU eviction semantics as pagination cursors; an evicted job
    restarts from scratch on its next quantum (and counts a restart in
    its result stats) rather than failing.
    """

    def __init__(self, server: QueryServer, quantum_rows: int = 8192,
                 policy: str = "quantum",
                 quotas: dict[str, TenantQuota] | None = None,
                 default_quota: TenantQuota | None = None):
        if policy not in ("quantum", "fifo"):
            raise ValueError(f"unknown policy {policy!r}; "
                             "options: ('quantum', 'fifo')")
        if quantum_rows < 1:
            raise ValueError("quantum_rows must be >= 1")
        self.server = server
        self.quantum_rows = quantum_rows
        self.policy = policy
        self.quotas = dict(quotas or {})
        self.default_quota = default_quota or TenantQuota()
        self._queue: deque[_Job] = deque()
        self._jobs: list[_Job] = []
        self._in_flight: dict[str, int] = {}
        self._seq = 0
        self.vclock = 0   # total rows expanded across all jobs
        self.stats = {"quanta": 0, "preemptions": 0, "restarts": 0,
                      "rejected": 0, "completed": 0, "parked_evictions": 0}

    # -- admission -----------------------------------------------------------
    def quota_for(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def _tenant_parked_bytes(self, tenant: str) -> int:
        return sum(j.parked_nbytes for j in self._jobs
                   if j.tenant == tenant and j.result is None)

    def submit(self, req: QueryRequest, collect_rows: bool = True) -> str:
        """Admit one request; returns its job token (``sched-<n>``).

        Args:
            req: the request.  ``req.tenant`` selects the quota;
                ``req.limit`` makes it an enumeration job (rows stream
                across quanta until ``limit`` rows are collected).
            collect_rows: enumeration jobs buffer their pages into the
                final result when True; False streams-and-discards
                (count delivered rows only) so a huge enumeration can
                be drained with bounded memory.

        Raises:
            AdmissionError: the tenant is at ``max_in_flight`` admitted
                requests, or its parked suspended state already exceeds
                ``max_frontier_bytes``.
            ValueError: ``req.cursor`` continuations — those resume
                server-side cursors directly via ``QueryServer.execute``
                and never enter the run queue.
        """
        if req.cursor is not None:
            raise ValueError("cursor continuations resume via "
                             "QueryServer.execute, not the scheduler")
        quota = self.quota_for(req.tenant)
        if self._in_flight.get(req.tenant, 0) >= quota.max_in_flight:
            self.stats["rejected"] += 1
            raise AdmissionError(
                req.tenant, f"max_in_flight={quota.max_in_flight} reached")
        if self._tenant_parked_bytes(req.tenant) >= quota.max_frontier_bytes:
            self.stats["rejected"] += 1
            raise AdmissionError(
                req.tenant,
                f"parked frontier bytes over "
                f"max_frontier_bytes={quota.max_frontier_bytes}")
        sel = req.selectivity or self.server.default_selectivity
        gdb = self.server._gdb_for(sel, req.seed)
        output = "rows" if req.limit is not None else "count"
        plan, _cached = self.server._plan_for(req, gdb, output=output)
        budget = QuantumBudget(
            None if self.policy == "fifo" else self.quantum_rows,
            req.query_name, plan.gao, inner=plan.level_callback)
        self._seq += 1
        job = _Job(self._seq, req, plan, gdb, plan.engine, budget,
                   collect_rows, self.vclock)
        self._jobs.append(job)
        self._queue.append(job)
        self._in_flight[req.tenant] = self._in_flight.get(req.tenant, 0) + 1
        return job.token

    # -- parking -------------------------------------------------------------
    def _park(self, job: _Job, payload) -> None:
        """Park suspended state in the server's cursor registry.

        The payload (a :class:`PlanSnapshot` or a live
        :class:`ResultCursor`) is subject to the registry's LRU cap and
        the tenant's frontier-byte quota; over-quota parking evicts the
        tenant's *other* parked jobs oldest-first (reason ``'quota'``),
        and a payload that alone exceeds the quota fails the job with a
        429-style result.
        """
        nb = payload.nbytes if isinstance(payload, PlanSnapshot) else (
            int(payload.penultimate.nbytes)
            if getattr(payload, "penultimate", None) is not None else 0)
        quota = self.quota_for(job.tenant)
        if nb > quota.max_frontier_bytes:
            self._finish_rejected(
                job, f"suspended frontier ({nb} bytes) exceeds "
                     f"max_frontier_bytes={quota.max_frontier_bytes}")
            return
        while self._tenant_parked_bytes(job.tenant) + nb \
                > quota.max_frontier_bytes:
            victim = next((j for j in self._jobs
                           if j.tenant == job.tenant and j is not job
                           and j.parked_nbytes > 0 and j.result is None),
                          None)
            if victim is None:
                break
            self.server._close_cursor(victim.token, "quota")
            victim.parked_nbytes = 0
            self.stats["parked_evictions"] += 1
        job.parked_nbytes = nb
        self.server._register_cursor(payload, job.label, job.plan,
                                     token=job.token)

    def _unpark(self, job: _Job):
        """Retrieve parked state; None means fresh start (first quantum,
        or the registry evicted the job's state — count a restart)."""
        entry = self.server._cursors.pop(job.token, None)
        if entry is not None:
            job.parked_nbytes = 0
            return entry[0]
        reason = self.server._closed.get(job.token)
        if reason in ("evicted", "quota") and job.quanta > 1:
            job.restarts += 1
            self.stats["restarts"] += 1
            job.parked_nbytes = 0
            if job.budget.quantum_rows is not None:
                # restart backoff: a registry smaller than the number of
                # concurrently-preempting jobs makes parked snapshots
                # mutually evict — restart-from-scratch forever.  Double
                # the quantum on every eviction restart so the work done
                # per restart grows geometrically and the job finishes
                # within one slice after O(log(total work)) restarts.
                job.budget.quantum_rows *= 2
            # the backoff growth is caller-visible: a restart event on
            # the job's trace plus quantum_rows_final in result stats
            self.server.metrics_registry.counter(
                "scheduler_restarts", reason=reason).inc()
            if job.trace is not None:
                job.trace.event("restart", reason=reason,
                                quantum_rows=job.budget.quantum_rows,
                                rows_lost=job.budget.total_rows)
        return None

    # -- execution -----------------------------------------------------------
    def _executor(self, job: _Job) -> VLFTJ:
        if job.executor is None:
            plan = job.plan.with_level_callback(job.budget)
            job.executor = VLFTJ(get_query(job.req.query_name), job.gdb,
                                 plan=plan)
            job.window = max(64, min(job.executor.chunk_rows,
                                     pow2ceil(self.quantum_rows)))
        return job.executor

    def _preemptible(self, job: _Job) -> bool:
        return (job.plan.engine == "vlftj"
                and not self.server._routes_to_dist(job.plan, job.gdb)
                and len(job.plan.gao) >= 2)

    def _finish(self, job: _Job, count: int,
                rows: np.ndarray | None = None,
                next_cursor: str | None = None) -> None:
        self._in_flight[job.tenant] -= 1
        self.stats["completed"] += 1
        trace = job.trace
        if trace is not None:
            if job.executor is not None:
                trace.record_engine(job.executor.stats, gao=job.plan.gao,
                                    est_rows=job.plan.level_est_rows)
                if job.req.limit is None and len(job.plan.gao):
                    # the scheduler drives the final level itself
                    # (windowed tallies), so the engine's level_rows
                    # stops at the penultimate level — close it here
                    trace.level(len(job.plan.gao) - 1, obs_rows=count)
            trace.finish(count=count, quanta=job.quanta,
                         preemptions=job.preemptions,
                         restarts=job.restarts,
                         rows_expanded=job.budget.total_rows)
        if job.profile is not None:
            job.profile.publish(trace=trace,
                                registry=self.server.metrics_registry)
        job.result = QueryResult(
            job.req, count, job.label, time.time() - job.t_submit,
            plan=job.plan, rows=rows,
            row_vars=job.plan.gao if rows is not None else None,
            next_cursor=next_cursor, trace=trace, profile=job.profile,
            stats={"quanta": job.quanta, "preemptions": job.preemptions,
                   "restarts": job.restarts,
                   "rows_expanded": job.budget.total_rows,
                   "vclock_submit": job.vclock_submit,
                   "vclock_done": self.vclock,
                   "policy": self.policy,
                   # restart-backoff visibility (doubles per eviction
                   # restart in _unpark): final == initial iff no
                   # eviction restart grew the quantum
                   "quantum_rows_initial": job.quantum_rows_initial,
                   "quantum_rows_final": job.budget.quantum_rows})

    def _finish_rejected(self, job: _Job, reason: str) -> None:
        self._in_flight[job.tenant] -= 1
        self.stats["rejected"] += 1
        job.result = QueryResult(
            job.req, 0, "rejected", time.time() - job.t_submit,
            plan=job.plan,
            stats={"status": 429, "error": reason, "quanta": job.quanta,
                   "vclock_submit": job.vclock_submit,
                   "vclock_done": self.vclock, "policy": self.policy})

    def step(self) -> bool:
        """Run one quantum of the job at the head of the run queue.

        Returns True if any job ran (False: queue empty).  The job
        either completes (its :class:`QueryResult` gains scheduling
        stats) or re-enters the queue tail with its state parked.
        """
        if not self._queue:
            return False
        job = self._queue.popleft()
        if job.result is not None:     # failed while parked (quota)
            return True
        job.quanta += 1
        self.stats["quanta"] += 1
        self.server.metrics_registry.counter("scheduler_quanta").inc()
        job.budget.refill()
        before = job.budget.total_rows
        try:
            with contextlib.ExitStack() as stack:
                if job.trace is not None:
                    stack.enter_context(job.trace.activate())
                if job.profile is not None:
                    # per-quantum compile attribution: any jit compile
                    # this slice triggers is labelled with the job and
                    # quantum that paid for it
                    stack.enter_context(job.profile.activate())
                    stack.enter_context(job.profile.attribute(
                        f"{job.token}/q{job.quanta}"))
                done = self._advance(job)
        except Preempted as p:
            job.preemptions += 1
            self.stats["preemptions"] += 1
            self.server.metrics_registry.counter(
                "scheduler_preemptions").inc()
            if job.trace is not None:
                job.trace.event(
                    "preempt", level=p.snapshot.start_level,
                    frontier_rows=int(p.snapshot.frontier.shape[0]),
                    quantum=job.quanta,
                    rows_expanded=job.budget.total_rows)
            self._park(job, p.snapshot)
            done = False
        self.vclock += job.budget.total_rows - before
        if job.result is not None:
            # completion time on the shared rows-expanded clock must
            # include this (final) quantum's own work, which is only
            # added to the vclock here, after _finish already ran
            job.result.stats["vclock_done"] = self.vclock
        if not done and job.result is None:
            self._queue.append(job)
        return True

    def run(self) -> list[QueryResult]:
        """Drain the queue; results in submission order (rejected jobs
        carry ``stats['status'] == 429``)."""
        while self.step():
            pass
        return [j.result for j in self._jobs if j.result is not None]

    # -- one quantum of one job ---------------------------------------------
    def _advance(self, job: _Job) -> bool:
        """Advance ``job`` by one quantum; True when complete."""
        state = self._unpark(job)
        if not self._preemptible(job):
            return self._run_opaque(job)
        ex = self._executor(job)
        k = len(ex.plan)
        if job.trace is not None and state is not None:
            if isinstance(state, PlanSnapshot):
                job.trace.event("resume", phase=state.phase,
                                level=state.start_level,
                                frontier_rows=int(state.frontier.shape[0]),
                                quantum=job.quanta)
            else:
                job.trace.event("resume", phase="rows", quantum=job.quanta,
                                rows_emitted=job.rows_collected)
        if job.req.limit is not None:
            return self._advance_rows(job, ex, state)
        # counting job: build the penultimate frontier (preemptible at
        # level boundaries), then tally the final level in fixed-size
        # windows so preemption points exist inside the final level too
        if state is None or (isinstance(state, PlanSnapshot)
                             and state.phase == "frontier"):
            frontier = ex.advance(
                frontier=None if state is None else state.frontier,
                mult=None if state is None else state.mult,
                max_levels=k - 1)                      # may raise Preempted
            if frontier.shape[0] == 0:
                self._finish(job, 0)
                return True
            frontier = frontier[np.lexsort(frontier.T[::-1])]
            state = PlanSnapshot(
                job.req.query_name, ex.gao,
                frontier.astype(np.int32),
                np.ones(frontier.shape[0], dtype=np.int64), phase="final")
        snap: PlanSnapshot = state
        F = snap.frontier.shape[0]
        while snap.offset < F:
            if job.budget.quantum_rows is not None \
                    and job.budget.consumed >= job.budget.quantum_rows:
                job.preemptions += 1
                self.stats["preemptions"] += 1
                self.server.metrics_registry.counter(
                    "scheduler_preemptions").inc()
                if job.trace is not None:
                    job.trace.event("preempt", level=len(ex.plan),
                                    phase="final", offset=snap.offset,
                                    quantum=job.quanta,
                                    rows_expanded=job.budget.total_rows)
                self._park(job, snap)
                return False
            real = min(job.window, F - snap.offset)
            chunk = snap.frontier[snap.offset:snap.offset + real]
            if real < job.window:
                chunk = np.pad(chunk, ((0, job.window - real), (0, 0)))
            valid = np.zeros(job.window, dtype=bool)
            valid[:real] = True
            counts = ex.last_level_counts(chunk, valid)[:real]
            m = snap.mult[snap.offset:snap.offset + real]
            snap.partial_total += int((counts * m).sum())
            snap.offset += real
            job.budget.charge(real)
        self._finish(job, snap.partial_total)
        return True

    def _advance_rows(self, job: _Job, ex: VLFTJ, state) -> bool:
        """One quantum of an enumeration job: pull pages until the
        quantum is spent, the limit is reached, or the stream ends."""
        if isinstance(state, ResultCursor):
            cur = state
        elif isinstance(state, PlanSnapshot):
            # resume from a suspended frontier; rows this job already
            # collected (e.g. before a registry eviction forced a
            # restart) are skipped so no page is delivered twice
            skip = max(job.rows_collected, state.rows_emitted)
            cur = ResultCursor(ex, page_rows=self.server.page_rows,
                               frontier=state.frontier, skip_rows=skip)
        else:
            cur = ResultCursor(ex, page_rows=self.server.page_rows,
                               skip_rows=job.rows_collected)
        limit = job.req.limit
        while True:
            want = min(self.server.page_rows, limit - job.rows_collected)
            if want <= 0:
                break
            try:
                page = cur.take(want)       # first pull may build levels
            except Preempted:
                raise                        # generator is dead; snapshot
            if page.shape[0] == 0:
                break
            job.rows_collected += int(page.shape[0])
            if job.collect_rows:
                job.pages.append(page)
            if job.budget.charge(page.shape[0]):
                break
        if job.rows_collected < limit and not cur.exhausted:
            if job.budget.quantum_rows is not None \
                    and job.budget.consumed >= job.budget.quantum_rows:
                job.preemptions += 1
                self.stats["preemptions"] += 1
                self.server.metrics_registry.counter(
                    "scheduler_preemptions").inc()
                if job.trace is not None:
                    job.trace.event("preempt", phase="rows",
                                    rows_emitted=job.rows_collected,
                                    quantum=job.quanta,
                                    rows_expanded=job.budget.total_rows)
                self._park(job, cur)
                return False
        rows = None
        next_cursor = None
        if job.collect_rows:
            rows = (np.concatenate(job.pages, axis=0) if job.pages
                    else np.zeros((0, len(ex.gao)), dtype=np.int64))
            if not cur.exhausted:
                # hand the live tail back as a normal pagination cursor:
                # the client continues via QueryServer.execute(cursor=)
                next_cursor = self.server._register_cursor(
                    cur, job.label, job.plan)
        self._finish(job, job.rows_collected, rows=rows,
                     next_cursor=next_cursor)
        return True

    def _run_opaque(self, job: _Job) -> bool:
        """Non-preemptible fallback: engines without the level-boundary
        hook (yannakakis/hybrid/refs) and dist-routed plans run to
        completion in one quantum."""
        if job.req.limit is not None:
            cur, label = self.server._open_cursor(job.plan, job.gdb,
                                                  job.req)
            job.label = label
            rows = cur.take(job.req.limit)
            next_cursor = None
            if job.collect_rows and not cur.exhausted:
                next_cursor = self.server._register_cursor(
                    cur, label, job.plan)
            self._finish(job, int(rows.shape[0]),
                         rows=rows if job.collect_rows else None,
                         next_cursor=next_cursor)
            return True
        c, label, _estats = self.server._execute_plan(job.plan, job.gdb,
                                                      job.req)
        job.label = label
        self._finish(job, c)
        return True
