from .query_server import QueryRequest, QueryResult, QueryServer

__all__ = ["QueryRequest", "QueryResult", "QueryServer"]
