from .query_server import QueryRequest, QueryServer

__all__ = ["QueryRequest", "QueryServer"]
