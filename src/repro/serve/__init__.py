from .query_server import QueryRequest, QueryResult, QueryServer
from .scheduler import (AdmissionError, PlanSnapshot, Preempted,
                        QuantumBudget, QuantumScheduler, TenantQuota)

__all__ = [
    "QueryRequest", "QueryResult", "QueryServer",
    "AdmissionError", "PlanSnapshot", "Preempted", "QuantumBudget",
    "QuantumScheduler", "TenantQuota",
]
