"""Selinger-style pairwise-join baseline (the "old dog" without new tricks).

Greedy cost-based join ordering over estimated cardinalities + materialized
sort-merge pairwise joins — the strategy of conventional engines (Postgres /
MonetDB in the paper).  On cyclic graph patterns any pairwise plan must
materialize an intermediate that can be ``Ω(√N)``-factor larger than the
output (§1), which is exactly what the cyclic-query benchmarks demonstrate:
this engine hits its intermediate cap (the analogue of the paper's
timeouts, rendered "-" in Tables 6/7) where the WCOJ engines cruise.

Vectorized in numpy; intermediates are dense integer tuple tables.
"""
from __future__ import annotations

import numpy as np

from .query import Query
from .relation import Database


class JoinBlowup(RuntimeError):
    """Raised when a materialized intermediate exceeds the cap."""

    def __init__(self, rows: int, cap: int):
        super().__init__(
            f"pairwise-join intermediate blowup: {rows} rows > cap {cap}")
        self.rows = rows
        self.cap = cap


def _exclusive_cumsum(x: np.ndarray) -> np.ndarray:
    out = np.zeros_like(x)
    np.cumsum(x[:-1], out=out[1:])
    return out


def _group_index(sorted_keys: np.ndarray):
    """(unique_keys, start, count) over a sorted 1-D key array."""
    if sorted_keys.size == 0:
        return sorted_keys[:0], np.zeros(0, np.int64), np.zeros(0, np.int64)
    change = np.empty(sorted_keys.shape[0], dtype=bool)
    change[0] = True
    change[1:] = sorted_keys[1:] != sorted_keys[:-1]
    start = np.flatnonzero(change).astype(np.int64)
    count = np.diff(np.append(start, sorted_keys.shape[0])).astype(np.int64)
    return sorted_keys[start], start, count


def _pack_key(data: np.ndarray, cols: list[int]) -> np.ndarray:
    """Pack selected columns into a single int64 key (stride encoding)."""
    if len(cols) == 1:
        return data[:, cols[0]].astype(np.int64)
    maxes = [int(data[:, c].max()) + 1 if data.shape[0] else 1 for c in cols]
    stride = 1
    for m in maxes:
        stride *= m
    if stride >= 2 ** 62:
        raise ValueError("key packing overflow")
    key = np.zeros(data.shape[0], dtype=np.int64)
    for c, m in zip(cols, maxes):
        key = key * m + data[:, c].astype(np.int64)
    return key


class _Intermediate:
    def __init__(self, vars_: tuple[str, ...], data: np.ndarray):
        self.vars = vars_
        self.data = data  # (rows, len(vars)) int64

    def __len__(self):
        return int(self.data.shape[0])


def _merge_join(left: _Intermediate, right: _Intermediate,
                cap: int) -> _Intermediate:
    shared = [v for v in left.vars if v in right.vars]
    lcols = [left.vars.index(v) for v in shared]
    rcols = [right.vars.index(v) for v in shared]
    if not shared:
        rows = len(left) * len(right)
        if rows > cap:
            raise JoinBlowup(rows, cap)
        li = np.repeat(np.arange(len(left)), len(right))
        ri = np.tile(np.arange(len(right)), len(left))
    else:
        # joint packing must use shared maxima so keys are comparable
        both_max = []
        for v in shared:
            lm = int(left.data[:, left.vars.index(v)].max()) if len(left) else 0
            rm = int(right.data[:, right.vars.index(v)].max()) if len(right) else 0
            both_max.append(max(lm, rm) + 1)

        def pack(data, cols):
            key = np.zeros(data.shape[0], dtype=np.int64)
            for c, m in zip(cols, both_max):
                key = key * m + data[:, c].astype(np.int64)
            return key

        lk = pack(left.data, lcols)
        rk = pack(right.data, rcols)
        lo = np.argsort(lk, kind="stable")
        ro = np.argsort(rk, kind="stable")
        lks, rks = lk[lo], rk[ro]
        luk, lstart, lcount = _group_index(lks)
        ruk, rstart, rcount = _group_index(rks)
        common, li_idx, ri_idx = np.intersect1d(
            luk, ruk, assume_unique=True, return_indices=True)
        ca, cb = lcount[li_idx], rcount[ri_idx]
        sizes = ca * cb
        rows = int(sizes.sum())
        if rows > cap:
            raise JoinBlowup(rows, cap)
        key_of_out = np.repeat(np.arange(common.shape[0]), sizes)
        within = (np.arange(rows)
                  - np.repeat(_exclusive_cumsum(sizes), sizes))
        cb_out = cb[key_of_out]
        li = lo[lstart[li_idx][key_of_out] + within // cb_out]
        ri = ro[rstart[ri_idx][key_of_out] + within % cb_out]
    new_vars = left.vars + tuple(v for v in right.vars if v not in left.vars)
    rkeep = [right.vars.index(v) for v in right.vars if v not in left.vars]
    data = np.concatenate(
        [left.data[li], right.data[ri][:, rkeep]], axis=1)
    return _Intermediate(new_vars, data)


def _apply_filters(inter: _Intermediate, query: Query,
                   applied: set) -> _Intermediate:
    for f in query.filters:
        if f in applied:
            continue
        if f.left in inter.vars and f.right in inter.vars:
            li, ri = inter.vars.index(f.left), inter.vars.index(f.right)
            keep = inter.data[:, li] < inter.data[:, ri]
            inter = _Intermediate(inter.vars, inter.data[keep])
            applied.add(f)
    return inter


class BinaryJoin:
    """Greedy Selinger-lite planner + materialized sort-merge execution."""

    def __init__(self, query: Query, db: Database,
                 cap: int = 50_000_000,
                 plan: "JoinPlan | None" = None):
        self.query = query
        self.db = db
        self.cap = cap
        # the pairwise baseline orders joins greedily at runtime; the plan
        # is carried for introspection/uniform dispatch only
        self.join_plan = plan
        # max_intermediate/joins are native; rows_expanded / level_rows
        # source the unified schema (ENGINE_STATS_SOURCE_KEYS): each
        # pairwise join feeds the current intermediate's rows into the
        # merge, and level_rows records the intermediate after each join
        self.stats = {"max_intermediate": 0, "joins": 0,
                      "rows_expanded": 0, "level_rows": {}}

    def _estimate(self, inter_size: int, inter_vars, atom, rel_len: int,
                  distincts) -> float:
        shared = [v for v in atom.vars if v in inter_vars]
        if not shared:
            return float(inter_size) * rel_len
        sel = 1.0
        for v in shared:
            sel /= max(1, distincts.get((atom.rel, v), 1))
        return float(inter_size) * rel_len * sel

    def run(self) -> _Intermediate:
        q, db = self.query, self.db
        # per-(relation, var) distinct counts for the cost model
        distincts: dict[tuple[str, str], int] = {}
        for a in q.atoms:
            rel = db.relations[a.rel]
            for i, v in enumerate(a.vars):
                d = int(np.unique(rel.data[:, i]).shape[0]) if len(rel) else 1
                key = (a.rel, v)
                distincts[key] = max(distincts.get(key, 1), d)

        remaining = list(range(len(q.atoms)))
        # start from the smallest atom (unary samples usually)
        start = min(remaining, key=lambda ai: len(db.relations[q.atoms[ai].rel]))
        a0 = q.atoms[start]
        inter = _Intermediate(a0.vars, db.relations[a0.rel].data.copy())
        remaining.remove(start)
        applied: set = set()
        inter = _apply_filters(inter, q, applied)
        while remaining:
            # prefer connected atoms; greedy min estimated output
            connected = [ai for ai in remaining
                         if any(v in inter.vars for v in q.atoms[ai].vars)]
            pool = connected or remaining
            best = min(pool, key=lambda ai: self._estimate(
                len(inter), inter.vars, q.atoms[ai],
                len(db.relations[q.atoms[ai].rel]), distincts))
            atom = q.atoms[best]
            rel = db.relations[atom.rel]
            right = _Intermediate(atom.vars, rel.data)
            self.stats["rows_expanded"] += len(inter)
            inter = _merge_join(inter, right, self.cap)
            self.stats["joins"] += 1
            self.stats["max_intermediate"] = max(
                self.stats["max_intermediate"], len(inter))
            self.stats["level_rows"][self.stats["joins"]] = len(inter)
            inter = _apply_filters(inter, q, applied)
            remaining.remove(best)
        return inter

    def count(self) -> int:
        return len(self.run())

    def enumerate(self, limit: int | None = None) -> np.ndarray:
        """Output tuples: int64, columns in GAO order
        (``self.output_vars`` — the plan's GAO), rows sorted
        lexicographically; ``limit`` truncates after the ordering (the
        shared engine contract, ``repro.results``)."""
        inter = self.run()
        cols = [inter.vars.index(v) for v in self.output_vars]
        data = inter.data[:, cols].astype(np.int64)
        if data.shape[0] > 1:
            data = data[np.lexsort(data.T[::-1])]
        return data if limit is None else data[:limit]

    @property
    def output_vars(self) -> tuple[str, ...]:
        """Column order of :meth:`enumerate`: the plan's GAO when it
        covers every variable, else the legacy heuristic order."""
        plan = self.join_plan
        if plan is not None and set(plan.gao) == set(self.query.variables):
            return plan.gao
        from .gao import choose_gao
        return choose_gao(self.query)


def binary_join_count(query: Query, db: Database,
                      cap: int = 50_000_000) -> int:
    return BinaryJoin(query, db, cap).count()
