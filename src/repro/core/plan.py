"""Physical query-plan IR: the frozen, hashable contract between planner
and engines.

The paper's thesis is that one relational engine covers graph workloads;
EmptyHeaded-style systems push that further by *compiling* a logical plan
once and executing it many times.  This module is the plan half of that
split: a :class:`JoinPlan` captures every decision the engines used to
re-derive at construction time — engine choice, global attribute order
(GAO), per-level constraint sets, hybrid tree/core decomposition,
Yannakakis root — plus cost annotations (AGM bound, per-level estimates)
so plans can be ranked, cached, and shipped to executors.

Everything here is a frozen dataclass built from tuples, so plans are
hashable and usable directly as cache keys.  ``repro.core.planner`` builds
plans; the engines in ``repro.core.*`` execute them.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field

import numpy as np

from .query import Query


def pow2ceil(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


def executor_geometry(max_degree: int, chunk_rows: int = 8192,
                      elem_budget: int = 1 << 22,
                      width: int | None = None) -> tuple[int, int]:
    """(width, chunk_rows) padding geometry of the vectorized executor.

    Single source of truth shared by ``VLFTJ.__init__`` and the planner's
    cost model — a level's true work is the padded element count, so the
    two must price the same geometry.
    """
    width = width or max(8, pow2ceil(max_degree))
    chunk = max(64, min(chunk_rows, pow2ceil(elem_budget // width)))
    return width, chunk


@dataclass(frozen=True)
class LevelPlan:
    """Static per-level constraint sets (indices into frontier columns).

    One entry per GAO level; consumed by the vectorized LFTJ kernels (the
    fields are the static arguments of ``vlftj._expand_level``).
    """

    var: str
    edge_sources: tuple[int, ...]   # frontier cols adjacent via edge atoms
    unary: tuple[str, ...]          # unary relation names constraining var
    lower: tuple[int, ...]          # filters: cand > frontier[:, j]
    upper: tuple[int, ...]          # filters: cand < frontier[:, j]
    needs_degree: bool              # var also appears with later-bound vars


def compile_levels(query: Query, gao: tuple[str, ...]
                   ) -> tuple[LevelPlan, ...]:
    """Compile a query + GAO into per-level constraint sets."""
    pos = {v: i for i, v in enumerate(gao)}
    plans = []
    for level, var in enumerate(gao):
        edge_sources: list[int] = []
        unary: list[str] = []
        needs_degree = False
        for a in query.atoms:
            if var not in a.vars:
                continue
            if a.arity == 1:
                unary.append(a.rel)
            elif a.arity == 2:
                other = a.vars[0] if a.vars[1] == var else a.vars[1]
                if other == var:
                    continue  # self-loop atom edge(v,v); not benchmarked
                if pos[other] < level:
                    edge_sources.append(pos[other])
                else:
                    needs_degree = True
            else:
                raise ValueError("vectorized engine supports graph queries "
                                 "(unary/binary atoms) only")
        lower = [pos[f.left] for f in query.filters
                 if f.right == var and pos[f.left] < level]
        upper = [pos[f.right] for f in query.filters
                 if f.left == var and pos[f.right] < level]
        plans.append(LevelPlan(var, tuple(sorted(set(edge_sources))),
                               tuple(unary), tuple(lower), tuple(upper),
                               needs_degree))
    return tuple(plans)


def stripe_partition(costs: np.ndarray, n_parts: int) -> list[np.ndarray]:
    """Deal items into ``n_parts`` cost-balanced parts (index arrays).

    Items are sorted by cost descending and dealt boustrophedon (snake)
    across the parts, so part sizes differ by at most one and part costs
    track each other even under power-law skew.  Parts past the item
    count come back empty — callers (``dist.PartitionedJoin``) rely on
    getting exactly ``n_parts`` entries.
    """
    costs = np.asarray(costs, dtype=np.float64)
    order = np.argsort(-costs, kind="stable")
    parts: list[list[int]] = [[] for _ in range(n_parts)]
    for rank, item in enumerate(order):
        lap, off = divmod(rank, n_parts)
        slot = off if lap % 2 == 0 else n_parts - 1 - off
        parts[slot].append(int(item))
    return [np.asarray(sorted(p), dtype=np.int64) for p in parts]


def partition_first_level(plan: "JoinPlan", values: np.ndarray,
                          degrees: np.ndarray,
                          n_parts: int) -> list[np.ndarray]:
    """Plan-aware sharding of a plan's first GAO level.

    Splits the seed domain ``values`` (candidate bindings of
    ``plan.gao[0]``) into ``n_parts`` work shards.  Binding the first
    variable partitions the output, so shard counts sum exactly to the
    full count.  The per-seed cost proxy is the adjacency length when
    any later level probes the seed column (frontier work is
    degree-driven there: the padded expansion tile of every descendant
    row gathers that adjacency); uniform otherwise.
    """
    values = np.asarray(values)
    if plan.levels and any(0 in lp.edge_sources for lp in plan.levels[1:]):
        costs = 1.0 + np.asarray(degrees)[values]
    else:
        costs = np.ones(values.shape[0])
    return [values[idx] for idx in stripe_partition(costs, n_parts)]


@dataclass(frozen=True)
class HybridPlan:
    """Tree/core split for the hybrid engine (§4.12 lollipop algorithm)."""

    tree_query: Query
    core_query: Query
    attachment: str
    core_gao: tuple[str, ...]


@dataclass(frozen=True)
class GraphStats:
    """Summary statistics of a :class:`GraphDB` used for cost estimation.

    The planner only ever sees these — never the data — so a plan is a
    pure function of ``(query, stats)`` and can be cached across requests
    that share a stats fingerprint.
    """

    n_nodes: int
    n_edges: int
    max_degree: int
    avg_degree: float
    unary_sizes: tuple[tuple[str, int], ...]  # sorted (name, |set|)
    # hybrid-layout summary (zero on array-only GraphDBs): hub count,
    # degree threshold, fraction of directed edges incident to a hub
    # source (the probability a frontier's bound vertex is bitset-tagged),
    # and the bitset row width in uint32 words
    n_hubs: int = 0
    hub_degree_threshold: int = 0
    hub_edge_fraction: float = 0.0
    bitset_words: int = 0

    @classmethod
    def of(cls, gdb) -> "GraphStats":
        csr = gdb.csr
        n = max(1, csr.n_nodes)
        n_edges = int(csr.indices.shape[0])
        layout = getattr(gdb, "layout", None)
        n_hubs = int(layout.n_hubs) if layout is not None else 0
        hub_frac = 0.0
        if n_hubs:
            hub_frac = float(csr.degrees[:n_hubs].sum()) / max(1, n_edges)
        return cls(
            n_nodes=csr.n_nodes,
            n_edges=n_edges,
            max_degree=int(csr.max_degree),
            avg_degree=n_edges / n,
            unary_sizes=tuple(sorted(
                (name, int(len(ids))) for name, ids in gdb.unary.items())),
            n_hubs=n_hubs,
            hub_degree_threshold=(int(layout.min_degree)
                                  if n_hubs else 0),
            hub_edge_fraction=round(hub_frac, 6),
            bitset_words=int(layout.n_words) if n_hubs else 0,
        )

    def unary_selectivity(self, name: str) -> float:
        """|unary set| / n_nodes, defaulting to 1.0 for unknown names."""
        n = max(1, self.n_nodes)
        for u, size in self.unary_sizes:
            if u == name:
                return min(1.0, size / n)
        return 1.0

    def relation_sizes(self, query: Query) -> dict[str, int]:
        """Relation-name -> cardinality map for the AGM bound."""
        sizes: dict[str, int] = {}
        for name, size in self.unary_sizes:
            sizes[name] = size
        for a in query.atoms:
            if a.rel not in sizes:
                sizes[a.rel] = self.n_edges if a.arity == 2 else self.n_nodes
        return sizes

    def fingerprint(self) -> str:
        """Stable short digest — the plan-cache invalidation token.

        Includes the layout summary, so the same graph with and without
        a hybrid bitset layout plans (and caches) separately."""
        payload = repr((self.n_nodes, self.n_edges, self.max_degree,
                        round(self.avg_degree, 6), self.unary_sizes,
                        self.n_hubs, self.hub_degree_threshold,
                        round(self.hub_edge_fraction, 6),
                        self.bitset_words))
        return hashlib.sha1(payload.encode()).hexdigest()[:16]


@dataclass(frozen=True)
class JoinPlan:
    """A complete physical plan: what to run, in what order, at what cost.

    ``engine`` is the physical operator ('vlftj', 'yannakakis', 'hybrid',
    'lftj_ref', 'minesweeper_ref', 'binary'); ``gao`` the global attribute
    order; ``levels`` the compiled per-level constraints (vectorized-LFTJ
    family); ``decomposition`` the hybrid tree/core split; ``root`` the
    Yannakakis message-passing root.  ``est_cost`` / ``level_costs`` are
    the planner's estimates and ``agm_log2`` the log2 AGM bound — the
    annotations ``benchmarks/bench_planner.py`` correlates against actual
    runtimes.  ``stats_fingerprint`` records the GraphStats the plan was
    costed against.

    ``output_mode`` is what the plan *emits*: ``'count'`` (the default —
    Idea-8 tallies, nothing materialized), ``'flat'`` (int64 tuples) or
    ``'factorized'`` (a trie-compressed
    :class:`~repro.results.FactorizedResult`).  For enumeration plans the
    planner costs flat-vs-factorized emission
    (``planner.estimate_emission``) and records the cheaper mode here.

    ``level_callback`` is the adaptive-execution hook — the *level
    boundary protocol*:

    * the executing engine calls ``callback(level, frontier, mult)`` at
      every interior GAO level boundary, i.e. after level ``level``'s
      frontier is built and before level ``level + 1`` runs.  ``frontier``
      is the ``(rows, level + 1)`` int32 array of partial bindings and
      ``mult`` the ``(rows,)`` int64 multiplicities;
    * the callback may return ``None`` (continue unchanged) or a
      replacement ``(frontier, mult)`` pair — e.g. a row permutation
      that re-deals skewed frontiers across shards
      (``repro.dist.rebalance.FrontierRebalancer``);
    * the callback may also *raise* to suspend execution: the serving
      layer's quantum budget
      (:class:`repro.serve.scheduler.QuantumBudget`) raises
      :class:`~repro.serve.scheduler.Preempted` carrying a
      :class:`~repro.serve.scheduler.PlanSnapshot` of exactly the
      ``(frontier, mult, next level)`` state, which
      ``VLFTJ._run(start_level=)`` / :meth:`VLFTJ.advance` can resume
      loss-free (row-for-row parity with uninterrupted execution).

    The field is excluded from equality/hashing — a plan with a callback
    attached still hits the same
    :class:`~repro.core.planner.PlanCache` entry.  Attach one with
    :meth:`with_level_callback`.
    """

    query: Query
    engine: str
    gao: tuple[str, ...]
    levels: tuple[LevelPlan, ...] = ()
    decomposition: HybridPlan | None = None
    root: str | None = None
    est_cost: float = 0.0
    level_costs: tuple[float, ...] = ()
    #: planner-estimated frontier cardinality after each GAO level binds
    #: (one entry per level; empty when the engine has no level model).
    #: The "est" side of per-level Q-error in ``repro.obs.explain``.
    level_est_rows: tuple[float, ...] = ()
    agm_log2: float | None = None
    stats_fingerprint: str = ""
    output_mode: str = "count"
    #: per-GAO-level adjacency representation chosen by the planner
    #: ('array' | 'bitset' | 'mixed'), one entry per level; empty means
    #: array-only.  'bitset' = nearly all membership checks expected on
    #: hub (bitset-tagged) vertices, 'mixed' = the executor buckets rows
    #: by the tags at runtime.  A tuple of strings, so plans stay
    #: frozen/hashable.
    level_layouts: tuple[str, ...] = ()
    level_callback: object = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.engine in ("vlftj", "lftj_ref") and not self.levels \
                and self.gao:
            try:
                object.__setattr__(
                    self, "levels", compile_levels(self.query, self.gao))
            except ValueError:
                pass  # non-graph atoms: the executing engine decides

    def with_level_callback(self, callback) -> "JoinPlan":
        """A copy of this plan with ``level_callback`` replaced.

        Because the callback is excluded from equality/hashing, the copy
        keys the same :class:`~repro.core.planner.PlanCache` entry as the
        original — cached plans can be instrumented per-request (budget
        accounting, rebalancing) without cache misses.
        """
        import dataclasses
        return dataclasses.replace(self, level_callback=callback)

    @property
    def agm_bound(self) -> float:
        if self.agm_log2 is None:
            return math.inf
        return 2.0 ** self.agm_log2

    def describe(self) -> str:
        """One-line human-readable summary (for logs / benchmarks)."""
        parts = [f"{self.query.name} -> {self.engine}",
                 f"gao={''.join(self.gao)}"]
        if self.decomposition is not None:
            parts.append(f"core={''.join(self.decomposition.core_gao)}"
                         f"@{self.decomposition.attachment}")
        if self.root is not None:
            parts.append(f"root={self.root}")
        if self.output_mode != "count":
            parts.append(f"out={self.output_mode}")
        if any(m != "array" for m in self.level_layouts):
            parts.append("layout=" + ",".join(
                m[0] for m in self.level_layouts))
        parts.append(f"cost~2^{math.log2(max(self.est_cost, 1.0)):.1f}")
        return " ".join(parts)
