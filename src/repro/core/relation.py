"""Sorted-array trie relations — the TPU-native index layout.

The paper assumes every relation is indexed by a search tree consistent with
the GAO (§4.1).  A pointer-based trie/B-tree does not map onto TPU, so the
index here is an *immutable sorted tuple table*: rows sorted
lexicographically in a given attribute order.  Level-``k`` trie nodes are
contiguous row ranges; ``seek``/``seek_lub``/``seek_glb`` are binary
searches (``np.searchsorted``) restricted to the parent range.  Reordering
an index for a different GAO is a sort — the analogue of the paper's
requirement that each relation have a GAO-consistent index.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _lex_sort_rows(data: np.ndarray) -> np.ndarray:
    if data.size == 0:
        return data
    order = np.lexsort(tuple(data[:, c] for c in range(data.shape[1] - 1, -1, -1)))
    return data[order]


class Relation:
    """An immutable relation of int64 tuples, sorted lexicographically."""

    def __init__(self, data: np.ndarray, name: str = "R"):
        data = np.asarray(data, dtype=np.int64)
        if data.ndim == 1:
            data = data[:, None]
        data = _lex_sort_rows(data)
        if data.shape[0]:
            keep = np.ones(data.shape[0], dtype=bool)
            keep[1:] = np.any(data[1:] != data[:-1], axis=1)
            data = data[keep]
        self.data = data
        self.name = name

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray,
                   symmetrize: bool = True, drop_loops: bool = True,
                   name: str = "edge") -> "Relation":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if drop_loops:
            keep = src != dst
            src, dst = src[keep], dst[keep]
        return cls(np.stack([src, dst], axis=1), name)

    @classmethod
    def from_set(cls, values, name: str = "V") -> "Relation":
        return cls(np.asarray(sorted(set(np.asarray(values).tolist()))), name)

    # -- basics --------------------------------------------------------------
    @property
    def arity(self) -> int:
        return self.data.shape[1]

    def __len__(self) -> int:
        return int(self.data.shape[0])

    def reorder(self, perm: tuple[int, ...], name: str | None = None
                ) -> "Relation":
        """Index under a different attribute order (a re-sort)."""
        return Relation(self.data[:, list(perm)], name or self.name)

    # -- trie navigation (range = [lo, hi) of rows, level = column) ----------
    def root_range(self) -> tuple[int, int]:
        return 0, len(self)

    def child_range(self, lo: int, hi: int, level: int, value: int
                    ) -> tuple[int, int]:
        """Rows in [lo,hi) whose column ``level`` equals ``value``."""
        col = self.data[lo:hi, level]
        l = int(np.searchsorted(col, value, side="left"))
        r = int(np.searchsorted(col, value, side="right"))
        return lo + l, lo + r

    def seek_lub(self, lo: int, hi: int, level: int, value: int) -> int:
        """Least row index in [lo,hi) with column ``level`` >= value
        (= the paper's ``seek_lub``); returns ``hi`` if none."""
        col = self.data[lo:hi, level]
        return lo + int(np.searchsorted(col, value, side="left"))

    def gap_around(self, lo: int, hi: int, level: int, value: int
                   ) -> tuple[int, int]:
        """Open interval (l, r) of column-``level`` values within [lo,hi)
        containing ``value`` but no indexed value — Minesweeper's maximal
        per-attribute gap (Idea 3).  Uses -inf/+inf sentinels as the paper
        does; here ``-2**62`` / ``2**62``."""
        col = self.data[lo:hi, level]
        i = int(np.searchsorted(col, value, side="left"))
        j = int(np.searchsorted(col, value, side="right"))
        if i != j:  # value present -> no gap at this level
            return (value, value)
        left = int(col[i - 1]) if i > 0 else NEG_INF
        right = int(col[i]) if i < col.shape[0] else POS_INF
        return (left, right)

    def contains(self, tup) -> bool:
        lo, hi = 0, len(self)
        for level, v in enumerate(tup):
            lo, hi = self.child_range(lo, hi, level, int(v))
            if lo >= hi:
                return False
        return True

    def distinct(self, lo: int, hi: int, level: int) -> np.ndarray:
        """Distinct values of column ``level`` within [lo, hi)."""
        return np.unique(self.data[lo:hi, level])


NEG_INF = -(2 ** 62)
POS_INF = 2 ** 62


@dataclass
class Database:
    """Named relations + per-(relation, attribute-order) index cache."""

    relations: dict[str, Relation]

    def __post_init__(self):
        self._index_cache: dict[tuple[str, tuple[int, ...]], Relation] = {}

    def sizes(self) -> dict[str, int]:
        return {k: len(v) for k, v in self.relations.items()}

    def indexed(self, rel_name: str, perm: tuple[int, ...]) -> Relation:
        """Relation re-indexed under column permutation ``perm`` (cached)."""
        key = (rel_name, tuple(perm))
        if key not in self._index_cache:
            base = self.relations[rel_name]
            if tuple(perm) == tuple(range(base.arity)):
                self._index_cache[key] = base
            else:
                self._index_cache[key] = base.reorder(perm)
        return self._index_cache[key]

    @property
    def domain_size(self) -> int:
        m = 0
        for r in self.relations.values():
            if len(r):
                m = max(m, int(r.data.max()) + 1)
        return m
