"""Unified join-engine API.

``count(query, gdb, engine=...)`` dispatches to any of the engines:

  * ``lftj_ref``        — faithful scalar LeapFrog TrieJoin (oracle)
  * ``minesweeper_ref`` — faithful Minesweeper w/ CDS (oracle)
  * ``binary``          — Selinger-style pairwise baseline
  * ``vlftj``           — vectorized worst-case-optimal join (TPU-native)
  * ``yannakakis``      — vectorized #MS / Yannakakis counting (β-acyclic)
  * ``hybrid``          — tree message passing + seeded core LFTJ
  * ``auto``            — the paper's summary heuristic: Minesweeper-analogue
                          for acyclic, hybrid for lollipop-shaped, LFTJ for
                          cyclic (Table 6/7 winners).
"""
from __future__ import annotations

from .binary_join import BinaryJoin
from .device_graph import GraphDB
from .hybrid import HybridDecomposition, HybridJoin
from .hypergraph import Hypergraph, is_beta_acyclic
from .lftj_ref import LFTJ
from .minesweeper_ref import Minesweeper
from .query import Query
from .vlftj import VLFTJ
from .yannakakis import CountingYannakakis, NotTreeShaped

ENGINES = ("lftj_ref", "minesweeper_ref", "binary", "vlftj", "yannakakis",
           "hybrid", "auto")


def pick_engine(query: Query) -> str:
    if is_beta_acyclic(Hypergraph.of(query)) and not query.filters:
        return "yannakakis"
    if HybridDecomposition(query).applicable:
        return "hybrid"
    return "vlftj"


def count(query: Query, gdb: GraphDB, engine: str = "auto", **kw) -> int:
    if engine == "auto":
        engine = pick_engine(query)
    if engine == "vlftj":
        return VLFTJ(query, gdb, **kw).count()
    if engine == "yannakakis":
        return CountingYannakakis(query, gdb).count()
    if engine == "hybrid":
        return HybridJoin(query, gdb, **kw).count()
    if engine == "lftj_ref":
        return LFTJ(query, gdb.to_database()).count()
    if engine == "minesweeper_ref":
        return Minesweeper(query, gdb.to_database(), **kw).count()
    if engine == "binary":
        return BinaryJoin(query, gdb.to_database(), **kw).count()
    raise ValueError(f"unknown engine {engine!r}; options: {ENGINES}")
