"""Unified join-engine API: ``plan → execute``.

``count(query, gdb, engine=...)`` routes every request through the
cost-based planner (``core/planner.py``): the query + graph stats are
planned into a frozen :class:`~repro.core.plan.JoinPlan`, and
:func:`execute` dispatches the plan to its physical operator:

  * ``lftj_ref``        — faithful scalar LeapFrog TrieJoin (oracle)
  * ``minesweeper_ref`` — faithful Minesweeper w/ CDS (oracle)
  * ``binary``          — Selinger-style pairwise baseline
  * ``vlftj``           — vectorized worst-case-optimal join (TPU-native)
  * ``yannakakis``      — vectorized #MS / Yannakakis counting (β-acyclic)
  * ``hybrid``          — tree message passing + seeded core LFTJ
  * ``auto``            — cheapest estimated plan among the candidates
                          (subsumes the paper's Table 6/7 summary
                          heuristic: Minesweeper-analogue for acyclic,
                          hybrid for lollipop-shaped, LFTJ for cyclic).

Pass ``plan=`` to skip planning (e.g. a :class:`planner.PlanCache` hit),
or ``cache=`` to memoize plans across calls.

Beyond counting, :func:`enumerate` materializes the output tuples (flat
:class:`~repro.results.ResultSet` or trie-compressed
:class:`~repro.results.FactorizedResult`) and :func:`stream` returns a
bounded-memory page cursor — both resolve their plan through the same
planner path (``output='rows'``), so cached enumeration plans carry a
costed ``output_mode``.
"""
from __future__ import annotations

import numpy as np

from .binary_join import BinaryJoin
from .device_graph import GraphDB
from .hybrid import HybridJoin
from .hypergraph import Hypergraph, is_beta_acyclic
from .lftj_ref import LFTJ
from .minesweeper_ref import Minesweeper
from .plan import GraphStats, JoinPlan
from .planner import PlanCache, decompose_hybrid, plan_query
from .query import Query
from .vlftj import VLFTJ
from .yannakakis import CountingYannakakis

ENGINES = ("lftj_ref", "minesweeper_ref", "binary", "vlftj", "yannakakis",
           "hybrid", "auto")


def pick_engine(query: Query, stats: GraphStats | None = None) -> str:
    """Engine routing.  With ``stats`` the choice is cost-based (cheapest
    candidate plan); without, the paper's structural summary heuristic."""
    if stats is not None:
        return plan_query(query, stats, engine="auto").engine
    if is_beta_acyclic(Hypergraph.of(query)) and not query.filters:
        return "yannakakis"
    if decompose_hybrid(query) is not None:
        return "hybrid"
    return "vlftj"


def make_engine(plan: JoinPlan, gdb: GraphDB, **kw):
    """Construct a plan's physical operator instance (the single
    dispatch point shared by ``execute``/``execute_stats``/
    ``_engine_rows``).  Every instance carries a ``stats`` dict —
    harvest it through :func:`repro.obs.normalize_engine_stats`."""
    engine = plan.engine
    query = plan.query
    if engine == "vlftj":
        return VLFTJ(query, gdb, plan=plan, **kw)
    if engine == "yannakakis":
        return CountingYannakakis(query, gdb, plan=plan)
    if engine == "hybrid":
        return HybridJoin(query, gdb, plan=plan, **kw)
    if engine == "lftj_ref":
        return LFTJ(query, gdb.to_database(), plan=plan)
    if engine == "minesweeper_ref":
        return Minesweeper(query, gdb.to_database(), plan=plan, **kw)
    if engine == "binary":
        return BinaryJoin(query, gdb.to_database(), plan=plan, **kw)
    raise ValueError(f"unknown engine {engine!r}; options: {ENGINES}")


def execute(plan: JoinPlan, gdb: GraphDB, **kw) -> int:
    """Run a compiled plan against a graph and return the count."""
    return make_engine(plan, gdb, **kw).count()


def execute_stats(plan: JoinPlan, gdb: GraphDB, **kw) -> tuple[int, dict]:
    """Run a plan and return ``(count, engine_stats)`` with the stats
    normalized onto the unified schema (``repro.obs.schema``).  When a
    :class:`repro.obs.QueryTrace` is active in the context, the per-level
    observations are harvested into it against the plan's
    ``level_est_rows`` annotation — all host-side dict reads, no new
    device work."""
    from ..obs import current_trace, normalize_engine_stats
    eng = make_engine(plan, gdb, **kw)
    out = eng.count()
    stats = normalize_engine_stats(plan.engine, getattr(eng, "stats", None))
    tr = current_trace()
    if tr is not None:
        tr.set_meta(query=plan.query.name, gao=list(plan.gao),
                    engine=plan.engine)
        tr.record_engine(stats["raw"], gao=plan.gao,
                         est_rows=plan.level_est_rows)
        tr.finish(count=out,
                  rows_expanded=stats["rows_expanded"],
                  kernel_dispatches=stats["kernel_dispatches"])
    return out, stats


def _resolve_plan(query: Query, gdb: GraphDB, engine: str,
                  plan: JoinPlan | None, cache: PlanCache | None,
                  gao: tuple[str, ...] | None,
                  output: str = "count", verify: bool = True) -> JoinPlan:
    """Shared plan resolution for ``count``/``enumerate``/``stream``.

    With ``verify`` (the default) the resolved plan — planner-produced
    or caller-supplied — passes static verification
    (:func:`repro.analysis.verify_for_execution`) before any device
    dispatch; error-severity findings raise
    :class:`repro.analysis.PlanVerificationError`.  Verification is
    memoized on ``(plan, stats fingerprint)``, so the steady-state cost
    on the serving path is a dict lookup.
    """
    if plan is None:
        if engine not in ENGINES:
            raise ValueError(
                f"unknown engine {engine!r}; options: {ENGINES}")
        stats = GraphStats.of(gdb)
        if gao is not None:
            # a pinned GAO bypasses the cache (keys don't carry the GAO)
            plan = plan_query(query, stats, engine=engine, gao=gao,
                              output=output)
        elif cache is not None:
            plan = cache.get_or_plan(query, stats, engine, output=output)
        else:
            plan = plan_query(query, stats, engine=engine, output=output)
    else:
        if (plan.query.atoms, plan.query.filters) != (query.atoms,
                                                      query.filters):
            raise ValueError(
                f"plan was built for {plan.query.name!r}, "
                f"not {query.name!r}")
        if engine != "auto" and plan.engine != engine:
            raise ValueError(f"plan uses engine {plan.engine!r} but "
                             f"engine={engine!r} was requested")
        if gao is not None and tuple(gao) != plan.gao:
            raise ValueError("both plan= and a conflicting gao= given")
    if verify:
        from ..analysis import verify_for_execution
        verify_for_execution(plan, gdb)
    return plan


def count(query: Query, gdb: GraphDB, engine: str = "auto",
          plan: JoinPlan | None = None, cache: PlanCache | None = None,
          gao: tuple[str, ...] | None = None, verify: bool = True,
          **kw) -> int:
    plan = _resolve_plan(query, gdb, engine, plan, cache, gao,
                         verify=verify)
    return execute(plan, gdb, **kw)


def _engine_rows(plan: JoinPlan, gdb: GraphDB, limit: int | None = None,
                 **kw) -> tuple[np.ndarray, tuple[str, ...]]:
    """Run a plan's engine enumeration: ``(rows, columns)``.

    Every engine's ``enumerate(limit=)`` follows one contract (int64,
    columns = its ``output_vars``, lex row order, limit truncates after
    ordering), so the limit pushes down uniformly."""
    eng = make_engine(plan, gdb, **kw)
    return eng.enumerate(limit=limit), eng.output_vars


def enumerate(query: Query, gdb: GraphDB, engine: str = "auto",
              limit: int | None = None,
              order: tuple[str, ...] | None = None,
              plan: JoinPlan | None = None, cache: PlanCache | None = None,
              gao: tuple[str, ...] | None = None,
              mode: str | None = None, verify: bool = True, **kw):
    """Enumerate output tuples through the same planner path as ``count``.

    Returns a :class:`repro.results.ResultSet` (flat, the default) or a
    :class:`repro.results.FactorizedResult` (``mode='factorized'``, or
    when the resolved plan's costed ``output_mode`` says so).  Columns
    follow ``order`` (default: ``query.variables`` — engine-independent,
    so any two engines agree row-for-row); rows are int64 and
    lexicographically sorted; ``limit`` truncates after the ordering.
    """
    from ..results import FactorizedResult, ResultSet
    plan = _resolve_plan(query, gdb, engine, plan, cache, gao,
                         output="rows", verify=verify)
    target = tuple(order) if order is not None else query.variables
    if set(target) != set(query.variables):
        raise ValueError(f"order {target} does not cover the query "
                         f"variables {query.variables}")
    mode = mode or (plan.output_mode if plan.output_mode != "count"
                    else "flat")
    if mode not in ("flat", "factorized"):
        raise ValueError(f"unknown mode {mode!r}; "
                         "options: ('flat', 'factorized')")
    if (mode == "factorized" and plan.engine == "vlftj"
            and target == plan.gao and limit is None):
        # native path: trie-compress the penultimate frontier and keep
        # the final level's extensions as leaf segments — the full flat
        # cross-product is never materialized
        from ..results.factorize import factorize_vlftj
        return factorize_vlftj(VLFTJ(query, gdb, plan=plan, **kw))
    push = limit if target == plan.gao else None
    rows, cols = _engine_rows(plan, gdb, limit=push, **kw)
    if cols != target:
        rows = rows[:, [cols.index(v) for v in target]]
        if rows.shape[0] > 1:
            rows = rows[np.lexsort(rows.T[::-1])]
    if limit is not None:
        rows = rows[:limit]
    if mode == "factorized":
        return FactorizedResult.from_rows(target, rows, sort=False)
    return ResultSet(target, rows)


def stream(query: Query, gdb: GraphDB, engine: str = "auto",
           page_rows: int = 1024, plan: JoinPlan | None = None,
           cache: PlanCache | None = None, verify: bool = True, **kw):
    """A :class:`repro.results.ResultCursor` over the query's output.

    Vectorized-LFTJ plans stream with bounded memory (the final level is
    re-entered per frontier chunk); other engines materialize once and
    page the rows.  Columns are the cursor's ``vars`` (the executing
    engine's output order)."""
    from ..results import ResultCursor
    plan = _resolve_plan(query, gdb, engine, plan, cache, None,
                         output="rows", verify=verify)
    if plan.engine == "vlftj":
        return ResultCursor(VLFTJ(query, gdb, plan=plan, **kw),
                            page_rows=page_rows)
    rows, cols = _engine_rows(plan, gdb, **kw)
    return ResultCursor.from_rows(cols, rows, page_rows=page_rows)
