"""Unified join-engine API: ``plan → execute``.

``count(query, gdb, engine=...)`` routes every request through the
cost-based planner (``core/planner.py``): the query + graph stats are
planned into a frozen :class:`~repro.core.plan.JoinPlan`, and
:func:`execute` dispatches the plan to its physical operator:

  * ``lftj_ref``        — faithful scalar LeapFrog TrieJoin (oracle)
  * ``minesweeper_ref`` — faithful Minesweeper w/ CDS (oracle)
  * ``binary``          — Selinger-style pairwise baseline
  * ``vlftj``           — vectorized worst-case-optimal join (TPU-native)
  * ``yannakakis``      — vectorized #MS / Yannakakis counting (β-acyclic)
  * ``hybrid``          — tree message passing + seeded core LFTJ
  * ``auto``            — cheapest estimated plan among the candidates
                          (subsumes the paper's Table 6/7 summary
                          heuristic: Minesweeper-analogue for acyclic,
                          hybrid for lollipop-shaped, LFTJ for cyclic).

Pass ``plan=`` to skip planning (e.g. a :class:`planner.PlanCache` hit),
or ``cache=`` to memoize plans across calls.
"""
from __future__ import annotations

from .binary_join import BinaryJoin
from .device_graph import GraphDB
from .hybrid import HybridJoin
from .hypergraph import Hypergraph, is_beta_acyclic
from .lftj_ref import LFTJ
from .minesweeper_ref import Minesweeper
from .plan import GraphStats, JoinPlan
from .planner import PlanCache, decompose_hybrid, plan_query
from .query import Query
from .vlftj import VLFTJ
from .yannakakis import CountingYannakakis

ENGINES = ("lftj_ref", "minesweeper_ref", "binary", "vlftj", "yannakakis",
           "hybrid", "auto")


def pick_engine(query: Query, stats: GraphStats | None = None) -> str:
    """Engine routing.  With ``stats`` the choice is cost-based (cheapest
    candidate plan); without, the paper's structural summary heuristic."""
    if stats is not None:
        return plan_query(query, stats, engine="auto").engine
    if is_beta_acyclic(Hypergraph.of(query)) and not query.filters:
        return "yannakakis"
    if decompose_hybrid(query) is not None:
        return "hybrid"
    return "vlftj"


def execute(plan: JoinPlan, gdb: GraphDB, **kw) -> int:
    """Run a compiled plan against a graph and return the count."""
    engine = plan.engine
    query = plan.query
    if engine == "vlftj":
        return VLFTJ(query, gdb, plan=plan, **kw).count()
    if engine == "yannakakis":
        return CountingYannakakis(query, gdb, plan=plan).count()
    if engine == "hybrid":
        return HybridJoin(query, gdb, plan=plan, **kw).count()
    if engine == "lftj_ref":
        return LFTJ(query, gdb.to_database(), plan=plan).count()
    if engine == "minesweeper_ref":
        return Minesweeper(query, gdb.to_database(), plan=plan, **kw).count()
    if engine == "binary":
        return BinaryJoin(query, gdb.to_database(), plan=plan, **kw).count()
    raise ValueError(f"unknown engine {engine!r}; options: {ENGINES}")


def count(query: Query, gdb: GraphDB, engine: str = "auto",
          plan: JoinPlan | None = None, cache: PlanCache | None = None,
          gao: tuple[str, ...] | None = None, **kw) -> int:
    if plan is None:
        if engine not in ENGINES:
            raise ValueError(
                f"unknown engine {engine!r}; options: {ENGINES}")
        stats = GraphStats.of(gdb)
        if gao is not None:
            # a pinned GAO bypasses the cache (keys don't carry the GAO)
            plan = plan_query(query, stats, engine=engine, gao=gao)
        elif cache is not None:
            plan = cache.get_or_plan(query, stats, engine)
        else:
            plan = plan_query(query, stats, engine=engine)
    elif (plan.query.atoms, plan.query.filters) != (query.atoms,
                                                    query.filters):
        raise ValueError(
            f"plan was built for {plan.query.name!r}, not {query.name!r}")
    elif engine != "auto" and plan.engine != engine:
        raise ValueError(f"plan uses engine {plan.engine!r} but "
                         f"engine={engine!r} was requested")
    elif gao is not None and tuple(gao) != plan.gao:
        raise ValueError("both plan= and a conflicting gao= given")
    return execute(plan, gdb, **kw)
