"""Join-query IR: atoms, inequality filters, and the paper's benchmark queries.

A (natural) join query ``Q = ⋈_{R ∈ atoms(Q)} R`` is a set of atoms, each a
relation symbol applied to a tuple of variables, plus (for symmetry breaking,
as in the paper's Datalog formulations) strict ``<`` filters between
variables.  Graph patterns are join queries over a binary ``edge`` relation
and unary sample predicates ``v1``, ``v2``, ...
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class Atom:
    """One relational atom ``rel(v_0, ..., v_{k-1})``."""

    rel: str
    vars: tuple[str, ...]

    @property
    def arity(self) -> int:
        return len(self.vars)

    def __str__(self) -> str:
        return f"{self.rel}({', '.join(self.vars)})"


@dataclass(frozen=True)
class LessThan:
    """Strict inequality filter ``left < right`` (symmetry breaking)."""

    left: str
    right: str

    def __str__(self) -> str:
        return f"{self.left}<{self.right}"


@dataclass(frozen=True)
class Query:
    """A join query: atoms + inequality filters + a display name."""

    atoms: tuple[Atom, ...]
    filters: tuple[LessThan, ...] = ()
    name: str = "query"

    @property
    def variables(self) -> tuple[str, ...]:
        """All variables, in first-appearance order."""
        seen: dict[str, None] = {}
        for a in self.atoms:
            for v in a.vars:
                seen.setdefault(v)
        return tuple(seen)

    @property
    def num_vars(self) -> int:
        return len(self.variables)

    def atoms_with(self, var: str) -> tuple[Atom, ...]:
        return tuple(a for a in self.atoms if var in a.vars)

    def relation_names(self) -> tuple[str, ...]:
        seen: dict[str, None] = {}
        for a in self.atoms:
            seen.setdefault(a.rel)
        return tuple(seen)

    def __str__(self) -> str:
        parts = [str(a) for a in self.atoms] + [str(f) for f in self.filters]
        return f"{self.name}: " + ", ".join(parts)


_ATOM_RE = re.compile(r"(\w+)\(([^)]*)\)")
_FILTER_RE = re.compile(r"^(\w+)\s*<\s*(\w+)$")


def parse(text: str, name: str = "query") -> Query:
    """Parse ``"edge(a,b), edge(b,c), edge(a,c), a<b, b<c"`` style strings."""
    atoms: list[Atom] = []
    filters: list[LessThan] = []
    # Split on commas not inside parens.
    parts, depth, cur = [], 0, []
    for ch in text:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    for part in parts:
        part = part.strip()
        if not part:
            continue
        m = _ATOM_RE.fullmatch(part)
        if m:
            rel = m.group(1)
            vs = tuple(v.strip() for v in m.group(2).split(",") if v.strip())
            atoms.append(Atom(rel, vs))
            continue
        m = _FILTER_RE.fullmatch(part)
        if m:
            filters.append(LessThan(m.group(1), m.group(2)))
            continue
        raise ValueError(f"cannot parse query fragment: {part!r}")
    return Query(tuple(atoms), tuple(filters), name)


# ---------------------------------------------------------------------------
# The paper's benchmark queries (§5.1), verbatim Datalog formulations.
# ---------------------------------------------------------------------------

def clique(k: int) -> Query:
    """k-clique with ``a1 < a2 < ... < ak`` symmetry breaking (paper)."""
    names = [chr(ord("a") + i) for i in range(k)]
    atoms = [Atom("edge", (names[i], names[j]))
             for i in range(k) for j in range(i + 1, k)]
    filters = [LessThan(names[i], names[i + 1]) for i in range(k - 1)]
    return Query(tuple(atoms), tuple(filters), f"{k}-clique")


def cycle(k: int) -> Query:
    """k-cycle; paper uses ``a<b<c<d`` for the 4-cycle."""
    names = [chr(ord("a") + i) for i in range(k)]
    atoms = [Atom("edge", (names[i], names[(i + 1) % k])) for i in range(k)]
    filters = [LessThan(names[i], names[i + 1]) for i in range(k - 1)]
    return Query(tuple(atoms), tuple(filters), f"{k}-cycle")


def path(k: int) -> Query:
    """k-path: v1(a0), v2(ak), chain of k edges.  3-path has 4 vars."""
    names = [chr(ord("a") + i) for i in range(k + 1)]
    atoms = [Atom("v1", (names[0],))]
    atoms += [Atom("edge", (names[i], names[i + 1])) for i in range(k)]
    atoms += [Atom("v2", (names[k],))]
    return Query(tuple(atoms), (), f"{k}-path")


def tree(n: int) -> Query:
    """n-tree: complete binary tree with 2^n leaves, each from its own sample.

    1-tree (paper): v1(b), v2(c), edge(a,b), edge(a,c).
    """
    if n == 1:
        return parse("edge(a,b), edge(a,c), v1(b), v2(c)", "1-tree")
    if n == 2:
        return parse(
            "edge(a,b), edge(a,c), edge(b,d), edge(b,e), edge(c,f), "
            "edge(c,g), v1(d), v2(e), v3(f), v4(g)",
            "2-tree",
        )
    raise ValueError("only 1-tree and 2-tree are benchmarked")


def comb(n: int) -> Query:
    """2-comb (paper): v1(c), v2(d), edge(a,b), edge(a,c), edge(b,d)."""
    if n != 2:
        raise ValueError("only the 2-comb is benchmarked")
    return parse("edge(a,b), edge(a,c), edge(b,d), v1(c), v2(d)", "2-comb")


def lollipop(n: int) -> Query:
    """n-lollipop: n-path followed by an (n+1)-clique (paper §4.12).

    2-lollipop: v1(a), edge(a,b), edge(b,c) + 3-clique on (c,d,e), d<e.
    3-lollipop: v1(a), 3-path to d + 4-clique on (d,e,f,g), e<f<g.
    """
    if n == 2:
        return parse(
            "v1(a), edge(a,b), edge(b,c), edge(c,d), edge(c,e), edge(d,e), "
            "d<e",
            "2-lollipop",
        )
    if n == 3:
        return parse(
            "v1(a), edge(a,b), edge(b,c), edge(c,d), "
            "edge(d,e), edge(d,f), edge(d,g), edge(e,f), edge(e,g), "
            "edge(f,g), e<f, f<g",
            "3-lollipop",
        )
    raise ValueError("only 2- and 3-lollipop are benchmarked")


#: name -> constructor for every query in the paper's benchmark.
PAPER_QUERIES = {
    "3-clique": lambda: clique(3),
    "4-clique": lambda: clique(4),
    "4-cycle": lambda: cycle(4),
    "3-path": lambda: path(3),
    "4-path": lambda: path(4),
    "1-tree": lambda: tree(1),
    "2-tree": lambda: tree(2),
    "2-comb": lambda: comb(2),
    "2-lollipop": lambda: lollipop(2),
    "3-lollipop": lambda: lollipop(3),
}


def get_query(name: str) -> Query:
    return PAPER_QUERIES[name]()
