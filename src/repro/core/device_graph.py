"""Device-resident graph database for the vectorized engines.

The edge relation lives as CSR (``indptr``/``indices``) int32 arrays; unary
sample predicates live as dense boolean bitmaps over the node domain — a
gather into a bitmap is the TPU-native membership probe for selective sets.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from ..graphs.csr import CSRGraph
from .relation import Database, Relation


@dataclass
class GraphDB:
    """Host+device view of an ``edge`` CSR plus unary node sets."""

    csr: CSRGraph
    unary: dict[str, np.ndarray] = field(default_factory=dict)

    # device arrays, built lazily
    _dev: dict = field(default_factory=dict, repr=False)

    @property
    def n_nodes(self) -> int:
        return self.csr.n_nodes

    @property
    def max_degree(self) -> int:
        return self.csr.max_degree

    @property
    def bsearch_iters(self) -> int:
        return int(math.ceil(math.log2(max(2, self.max_degree)))) + 1

    def dev(self, key: str):
        if key in self._dev:
            return self._dev[key]
        if key == "indptr":
            v = jnp.asarray(self.csr.indptr, dtype=jnp.int32)
        elif key == "indices":
            v = jnp.asarray(self.csr.indices, dtype=jnp.int32)
        elif key == "src_ids":  # edge -> source node id (for segment ops)
            v = jnp.asarray(
                np.repeat(np.arange(self.csr.n_nodes, dtype=np.int32),
                          self.csr.degrees), dtype=jnp.int32)
        elif key.startswith("summary:"):
            stride = int(key.split(":", 1)[1])
            v = jnp.asarray(self.csr.indices[::stride], dtype=jnp.int32)
        elif key.startswith("bitmap:"):
            name = key.split(":", 1)[1]
            bm = np.zeros(self.csr.n_nodes, dtype=bool)
            ids = self.unary[name]
            bm[ids[ids < self.csr.n_nodes]] = True
            v = jnp.asarray(bm)
        else:
            raise KeyError(key)
        self._dev[key] = v
        return v

    def to_database(self) -> Database:
        """Bridge to the host reference engines."""
        rels = {"edge": self.csr.to_relation()}
        for name, ids in self.unary.items():
            rels[name] = Relation.from_set(ids, name)
        return Database(rels)

    @classmethod
    def from_database(cls, db: Database) -> "GraphDB":
        edge = db.relations["edge"]
        csr = CSRGraph.from_edges(edge.data[:, 0], edge.data[:, 1],
                                  symmetrize=True)
        unary = {name: r.data[:, 0]
                 for name, r in db.relations.items()
                 if r.arity == 1}
        return cls(csr, unary)
