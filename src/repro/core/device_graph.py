"""Device-resident graph database for the vectorized engines.

The edge relation lives as CSR (``indptr``/``indices``) int32 arrays; unary
sample predicates live as dense boolean bitmaps over the node domain — a
gather into a bitmap is the TPU-native membership probe for selective sets.

:class:`HybridGraphDB` extends the base with the degree-adaptive layout
stack (``graphs/layout.py``): vertices renumbered by descending degree,
hub neighborhoods additionally packed as uint32 bitset rows, and per-vertex
representation tags shipped to device so the vectorized engines can route
membership checks to the O(1) bit-test path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from ..graphs.csr import CSRGraph
from ..graphs.layout import (HybridLayout, degree_sort_permutation,
                             map_rows_back, renumber_csr)
from .relation import Database, Relation


@dataclass
class GraphDB:
    """Host+device view of an ``edge`` CSR plus unary node sets."""

    csr: CSRGraph
    unary: dict[str, np.ndarray] = field(default_factory=dict)

    # device arrays, built lazily
    _dev: dict = field(default_factory=dict, repr=False)

    @property
    def n_nodes(self) -> int:
        return self.csr.n_nodes

    @property
    def max_degree(self) -> int:
        return self.csr.max_degree

    @property
    def bsearch_iters(self) -> int:
        return int(math.ceil(math.log2(max(2, self.max_degree)))) + 1

    def dev(self, key: str):
        if key in self._dev:
            return self._dev[key]
        if key == "indptr":
            v = jnp.asarray(self.csr.indptr, dtype=jnp.int32)
        elif key == "indices":
            v = jnp.asarray(self.csr.indices, dtype=jnp.int32)
        elif key == "src_ids":  # edge -> source node id (for segment ops)
            v = jnp.asarray(
                np.repeat(np.arange(self.csr.n_nodes, dtype=np.int32),
                          self.csr.degrees), dtype=jnp.int32)
        elif key.startswith("summary:"):
            stride = int(key.split(":", 1)[1])
            v = jnp.asarray(self.csr.indices[::stride], dtype=jnp.int32)
        elif key.startswith("bitmap:"):
            name = key.split(":", 1)[1]
            bm = np.zeros(self.csr.n_nodes, dtype=bool)
            ids = self.unary[name]
            bm[ids[ids < self.csr.n_nodes]] = True
            v = jnp.asarray(bm)
        else:
            raise KeyError(key)
        self._dev[key] = v
        return v

    def to_database(self) -> Database:
        """Bridge to the host reference engines."""
        rels = {"edge": self.csr.to_relation()}
        for name, ids in self.unary.items():
            rels[name] = Relation.from_set(ids, name)
        return Database(rels)

    @classmethod
    def from_database(cls, db: Database) -> "GraphDB":
        edge = db.relations["edge"]
        csr = CSRGraph.from_edges(edge.data[:, 0], edge.data[:, 1],
                                  symmetrize=True)
        unary = {name: r.data[:, 0]
                 for name, r in db.relations.items()
                 if r.arity == 1}
        return cls(csr, unary)


@dataclass
class HybridGraphDB(GraphDB):
    """A :class:`GraphDB` carrying the degree-adaptive hybrid layout.

    The CSR is (by default) renumbered so hubs occupy the id prefix
    ``[0, layout.n_hubs)``; ``layout`` additionally stores those hubs'
    neighborhoods as uint32 bitset rows.  The sorted arrays remain
    authoritative — every engine that works on a :class:`GraphDB` works
    here unchanged.  Enumerated rows are in renumbered ids and map back
    via :meth:`rows_to_original`.  Counts are renumbering-invariant for
    filter-free queries and for ``LessThan`` chains that exactly quotient
    a query automorphism (cliques); order filters that merely *slice* the
    id space (e.g. the 4-cycle's ``a<b<c<d``) are evaluated in the
    renumbered space, so compare engines on the same db, or pass
    ``renumber=False`` to keep original ids.

    Extra device keys: ``"bitset_words"`` — the (n_hubs, n_words) uint32
    bitset matrix; ``"rep_tag"`` — per-vertex int32 representation tag
    (bitset row index for hubs, -1 for array-only vertices).
    """

    layout: HybridLayout | None = None
    order: np.ndarray | None = None        # new id -> old id
    new_of_old: np.ndarray | None = None   # old id -> new id

    @classmethod
    def build(cls, csr: CSRGraph, unary: dict[str, np.ndarray] | None = None,
              renumber: bool = True, **layout_kw) -> "HybridGraphDB":
        """Renumber by descending degree, remap unary sets, pack hub
        bitsets.  ``layout_kw`` forwards to :meth:`HybridLayout.build`
        (``min_degree``, ``density``, ``word_budget``, ``max_hubs``)."""
        unary = dict(unary or {})
        if renumber:
            order, inv = degree_sort_permutation(csr)
            csr = renumber_csr(csr, inv)
            unary = {name: np.sort(inv[np.asarray(ids, dtype=np.int64)])
                     for name, ids in unary.items()}
        else:
            order = np.arange(csr.n_nodes, dtype=np.int64)
            inv = order
        layout = HybridLayout.build(csr, **layout_kw)
        return cls(csr=csr, unary=unary, layout=layout, order=order,
                   new_of_old=inv)

    @classmethod
    def from_gdb(cls, gdb: GraphDB, renumber: bool = True,
                 **layout_kw) -> "HybridGraphDB":
        return cls.build(gdb.csr, gdb.unary, renumber=renumber, **layout_kw)

    @property
    def n_hubs(self) -> int:
        return self.layout.n_hubs if self.layout is not None else 0

    def rows_to_original(self, rows: np.ndarray) -> np.ndarray:
        """Map result rows (renumbered vertex ids) back to the original
        id space — the renumbering round-trip for query results."""
        return map_rows_back(rows, self.order)

    def dev(self, key: str):
        if key in self._dev:
            return self._dev[key]
        if key == "bitset_words":
            lay = self.layout
            if lay is None:
                raise KeyError(key)
            # keep at least one row so the device array is gatherable
            w = lay.words if lay.n_hubs else np.zeros((1, lay.n_words),
                                                      dtype=np.uint32)
            v = jnp.asarray(w, dtype=jnp.uint32)
        elif key == "rep_tag":
            if self.layout is None:
                raise KeyError(key)
            v = jnp.asarray(self.layout.rep_tags(), dtype=jnp.int32)
        else:
            return super().dev(key)
        self._dev[key] = v
        return v
