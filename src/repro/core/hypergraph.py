"""Query hypergraphs, β-acyclicity, and nested elimination orders (NEO).

The hypergraph of a query has the query variables as vertices and one
hyperedge ``vars(R)`` per atom (§2.1).  β-acyclicity is characterized by the
existence of a *nested elimination order*: an ordering ``u_1, ..., u_n`` such
that ``u_1`` is a *nest point* (the hyperedges containing it form a chain
under ⊆), and after removing ``u_1`` from every hyperedge, ``u_2`` is a nest
point of the residual hypergraph, and so on [Ngo et al., PODS'14].

Minesweeper's GAO must be a NEO (Proposition 4.2): then every principal
filter ``G_i`` in the CDS is a chain.
"""
from __future__ import annotations

from dataclasses import dataclass
from .query import Query


@dataclass(frozen=True)
class Hypergraph:
    vertices: tuple[str, ...]
    edges: tuple[frozenset[str], ...]

    @classmethod
    def of(cls, q: Query) -> "Hypergraph":
        return cls(q.variables, tuple(frozenset(a.vars) for a in q.atoms))


def _edges_with(edges: list[frozenset[str]], v: str) -> list[frozenset[str]]:
    return [e for e in edges if v in e]


def _is_chain(sets: list[frozenset[str]]) -> bool:
    """True iff the sets are totally ordered by inclusion."""
    ss = sorted(set(sets), key=len)
    return all(a <= b for a, b in zip(ss, ss[1:]))


def is_nest_point(edges: list[frozenset[str]], v: str) -> bool:
    return _is_chain(_edges_with(edges, v))


def _eliminate(edges: list[frozenset[str]], v: str) -> list[frozenset[str]]:
    out = []
    for e in edges:
        e2 = e - {v}
        if e2:
            out.append(e2)
    # drop duplicates but keep list type
    return list(dict.fromkeys(out))


def is_neo(hg: Hypergraph, order: tuple[str, ...]) -> bool:
    """Is ``order`` a valid GAO, i.e. a nested elimination order?

    Convention (matches the paper's Table 4): the *last* GAO attribute is
    eliminated first — the GAO is the reverse of the nest-point
    elimination sequence, so the deepest CDS levels are chains.
    """
    if set(order) != set(hg.vertices) or len(order) != len(hg.vertices):
        return False
    edges = list(hg.edges)
    for v in reversed(order):
        if not is_nest_point(edges, v):
            return False
        edges = _eliminate(edges, v)
    return True


def all_neos(hg: Hypergraph, limit: int = 10000) -> list[tuple[str, ...]]:
    """Enumerate NEO GAOs by backtracking (queries are tiny: n ≤ 8).

    Elimination sequences are generated back-to-front and reversed into
    GAOs (see :func:`is_neo`).
    """
    out: list[tuple[str, ...]] = []

    def rec(edges: list[frozenset[str]], remaining: list[str],
            suffix: tuple[str, ...]) -> None:
        if len(out) >= limit:
            return
        if not remaining:
            out.append(tuple(reversed(suffix)))
            return
        for v in remaining:
            if is_nest_point(edges, v):
                rec(_eliminate(edges, v), [u for u in remaining if u != v],
                    suffix + (v,))

    rec(list(hg.edges), list(hg.vertices), ())
    return out


def is_beta_acyclic(hg: Hypergraph) -> bool:
    """β-acyclic ⇔ a NEO exists.  Greedy nest-point elimination is complete
    for β-acyclicity (eliminating any nest point preserves β-acyclicity)."""
    edges = list(hg.edges)
    remaining = list(hg.vertices)
    while remaining:
        for v in remaining:
            if is_nest_point(edges, v):
                edges = _eliminate(edges, v)
                remaining.remove(v)
                break
        else:
            return False
    return True


def adjacency(hg: Hypergraph) -> dict[str, set[str]]:
    adj: dict[str, set[str]] = {v: set() for v in hg.vertices}
    for e in hg.edges:
        for u in e:
            adj[u] |= e - {u}
    return adj
