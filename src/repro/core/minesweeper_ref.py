"""Faithful Minesweeper (§4): CDS, gap boxes, moving frontier, Ideas 1-7.

Minesweeper rules out regions of the output space ("gap boxes") certified
empty by index probes, storing them in a Constraint Data Structure (CDS).
``computeFreeTuple`` finds the lexicographically-smallest candidate tuple
not inside any stored gap; probing the inputs around it either certifies an
output tuple or yields new maximal gaps.  For β-acyclic queries with a NEO
GAO this is instance-optimal up to a log factor [Ngo et al., PODS'14].

Implemented ideas from the paper:
  * Idea 1  (point list): intervals kept merged & children inside a newly
    inserted interval pruned.
  * Idea 2  (moving frontier): free tuples advance lexicographically; output
    tuples advance the frontier instead of inserting unit gaps.
  * Idea 3  (geometric certificate): maximal per-relation gap boxes.
  * Idea 4  (avoid repeated seekGap): a per-relation last-constraint cache
    suppresses probes the previous gap already answers (flag-controlled —
    benchmarked in Tables 1-2).
  * Idea 5  (backtracking and truncating): exhausted nodes truncate their
    first non-wildcard ancestor branch.
  * Idea 6  (complete nodes) is subsumed here by the point-list layout:
    merged free-value knowledge accumulates in the chain-bottom node's
    interval list, so once a subtree has been swept, later visits iterate
    its free values via ``next_free`` in O(log) without re-polling the
    chain — the effect Idea 6's completeness flag buys the paper's
    two-list implementation.  (The Idea-6 *caching* speedup is measured
    on the vectorized analogue in ``benchmarks/bench_ideas.py``.)
  * Idea 7  (skipping gaps): for β-cyclic queries only a β-acyclic skeleton
    inserts constraints; other relations' gaps just advance the frontier.
  * Idea 8  (#Minesweeper micro message passing) is realized exactly by
    the vectorized counting engine (``core/yannakakis.py``) — the paper
    itself frames #MS as message passing; counts here come from
    enumeration (this class is the correctness oracle).

Host-only Python; serves as the correctness oracle and the paper-faithful
baseline that ``core/yannakakis.py`` (the vectorized analogue) is compared
against.
"""
from __future__ import annotations

import numpy as np

from .gao import choose_gao
from .hypergraph import Hypergraph, is_beta_acyclic
from .query import Query
from .relation import Database, NEG_INF, POS_INF

STAR = "*"


class IntervalList:
    """Sorted, disjoint *open* integer intervals with merge-on-insert."""

    __slots__ = ("ivs",)

    def __init__(self):
        self.ivs: list[tuple[int, int]] = []

    def insert(self, l: int, r: int) -> None:
        if r - l <= 1:
            return  # an open interval (l, l+1) contains no integer
        out: list[tuple[int, int]] = []
        for (a, b) in self.ivs:
            if a < r and l < b:  # open-overlap -> merge
                l, r = min(l, a), max(r, b)
            else:
                out.append((a, b))
        out.append((l, r))
        out.sort()
        self.ivs = out

    def next_free(self, x: int) -> int:
        """Smallest y >= x with y inside no stored interval (v.Next)."""
        # binary search over sorted disjoint intervals
        ivs = self.ivs
        lo, hi = 0, len(ivs)
        while lo < hi:
            mid = (lo + hi) // 2
            if ivs[mid][1] <= x:
                lo = mid + 1
            else:
                hi = mid
        if lo < len(ivs):
            a, b = ivs[lo]
            if a < x < b:
                return b
        return x

    def covers_all(self) -> bool:
        return self.next_free(NEG_INF + 1) >= POS_INF

    def __len__(self) -> int:
        return len(self.ivs)


class _Node:
    __slots__ = ("children", "intervals", "parent", "label")

    def __init__(self, parent=None, label=None):
        self.children: dict = {}
        self.intervals = IntervalList()
        self.parent = parent
        self.label = label

    def child(self, label, create: bool = False):
        c = self.children.get(label)
        if c is None and create:
            c = _Node(self, label)
            self.children[label] = c
        return c

    def specificity(self) -> int:
        n, node = 0, self
        while node.parent is not None:
            if node.label != STAR:
                n += 1
            node = node.parent
        return n


class Constraint:
    """``⟨c_0,...,c_{d-1}, (l,r), *,...⟩`` — pattern + one open interval."""

    __slots__ = ("pattern", "pos", "l", "r")

    def __init__(self, pattern: tuple, pos: int, l: int, r: int):
        self.pattern = pattern  # length == pos, entries int or STAR
        self.pos = pos
        self.l = l
        self.r = r

    def pattern_matches(self, t) -> bool:
        for p, v in zip(self.pattern, t):
            if p is not STAR and p != v:
                return False
        return True

    def matches(self, t) -> bool:
        return self.pattern_matches(t) and self.l < t[self.pos] < self.r

    def __repr__(self):  # pragma: no cover - debugging aid
        pat = ",".join("*" if p is STAR else str(p) for p in self.pattern)
        return f"<{pat},({self.l},{self.r}),*...>"


def _pattern_of(node: _Node) -> tuple:
    p = []
    while node.parent is not None:
        p.append(node.label)
        node = node.parent
    return tuple(reversed(p))


def _generalizes(p: tuple, q: tuple) -> bool:
    """p generalizes q: same length, p_i == q_i or p_i == '*'."""
    return all(a is STAR or a == b for a, b in zip(p, q))


def _chain_bottom(G: list["_Node"]) -> "_Node | None":
    """If G is a chain under specialization, return its bottom (the node
    every other node generalizes); else None.  Prop 4.2 guarantees a chain
    for β-acyclic queries under a NEO GAO — the soundness condition for
    caching merged intervals at the bottom (Idea 5).  For general posets
    (cyclic queries, filter constraints) caching at a non-bottom node would
    poison sibling prefixes, so the caller skips the cache."""
    pats = [_pattern_of(nd) for nd in G]
    bottom_i = 0
    for i in range(1, len(G)):
        if _generalizes(pats[bottom_i], pats[i]):
            bottom_i = i
    bp = pats[bottom_i]
    for i, p in enumerate(pats):
        if i != bottom_i and not _generalizes(p, bp):
            return None
    return G[bottom_i]


class CDS:
    """The constraint data structure: a tree with one level per GAO attr."""

    def __init__(self, n: int):
        self.n = n
        self.root = _Node()
        self.num_constraints = 0

    def insert(self, c: Constraint) -> None:
        node = self.root
        for label in c.pattern:
            node = node.child(label, create=True)
        node.intervals.insert(c.l, c.r)
        self.num_constraints += 1
        # Idea 1: prune children whose numeric labels fall inside the
        # interval — they are unreachable now.
        dead = [k for k in node.children
                if k is not STAR and c.l < k < c.r]
        for k in dead:
            del node.children[k]

    def spec_node(self, prefix: tuple) -> _Node:
        """The full-equality specialization node for ``prefix`` (§4.8:
        cyclic queries cache into specialization branches)."""
        node = self.root
        for v in prefix:
            node = node.child(v, create=True)
        return node

    def generalizing(self, prefix) -> list[_Node]:
        """All depth-``len(prefix)`` nodes whose pattern generalizes
        ``prefix`` and that carry intervals (the principal filter G_i)."""
        nodes = [self.root]
        for v in prefix:
            nxt = []
            for nd in nodes:
                c = nd.children.get(v)
                if c is not None:
                    nxt.append(c)
                c = nd.children.get(STAR)
                if c is not None:
                    nxt.append(c)
            nodes = nxt
            if not nodes:
                return []
        return [nd for nd in nodes if len(nd.intervals)]


class Minesweeper:
    """Paper-faithful Minesweeper over sorted-array tries."""

    def __init__(self, query: Query, db: Database,
                 gao: tuple[str, ...] | None = None,
                 skip_probes: bool = True,   # Idea 4
                 use_skeleton: bool = True,  # Idea 7
                 plan: "JoinPlan | None" = None,
                 ):
        self.query = query
        self.db = db
        self.join_plan = plan
        if gao is None:
            gao = plan.gao if plan is not None else choose_gao(query)
        self.gao = tuple(gao)
        self.n = len(self.gao)
        self.var_pos = {v: i for i, v in enumerate(self.gao)}
        self.skip_probes = skip_probes
        # GAO-consistent index per atom.
        self.atom_perm = []
        self.atom_gao_pos = []  # GAO coordinate of each index column
        for a in query.atoms:
            perm = tuple(sorted(range(a.arity),
                                key=lambda i: self.var_pos[a.vars[i]]))
            self.atom_perm.append(perm)
            self.atom_gao_pos.append(
                tuple(self.var_pos[a.vars[i]] for i in perm))
        self.indexes = [db.indexed(a.rel, self.atom_perm[ai])
                        for ai, a in enumerate(query.atoms)]
        # Idea 7: β-acyclic skeleton (greedy, unary atoms first).
        self.in_skeleton = [True] * len(query.atoms)
        if use_skeleton and not is_beta_acyclic(Hypergraph.of(query)):
            chosen: list[int] = []
            order = sorted(range(len(query.atoms)),
                           key=lambda ai: (query.atoms[ai].arity, ai))
            for ai in order:
                trial = chosen + [ai]
                hg = Hypergraph(
                    query.variables,
                    tuple(frozenset(query.atoms[i].vars) for i in trial))
                if is_beta_acyclic(hg):
                    chosen.append(ai)
            self.in_skeleton = [ai in chosen
                                for ai in range(len(query.atoms))]
        # filters, applied as implicit constraints on free tuples
        self.filters = [(self.var_pos[f.left], self.var_pos[f.right])
                        for f in query.filters]
        # probes/gaps/... are native; rows_expanded / level_rows source
        # the unified schema (ENGINE_STATS_SOURCE_KEYS): each candidate
        # free tuple is one unit of expansion work, and the final GAO
        # level's observed cardinality is the output count
        self.stats = {"probes": 0, "gaps": 0, "outputs": 0,
                      "free_tuples": 0, "probe_skips": 0,
                      "rows_expanded": 0, "level_rows": {}}
        # Attributes range over the active domain [0, universe): any value
        # >= universe cannot participate in a join output, so the free-tuple
        # search treats it as exhausted.
        self.universe = max(1, db.domain_size)

    # -- gap probing (Idea 3) ------------------------------------------------
    def seek_gap(self, ai: int, t) -> Constraint | None:
        """Maximal gap box around free tuple ``t`` from atom ``ai`` — or
        ``None`` if the projection of ``t`` is present in the relation."""
        self.stats["probes"] += 1
        rel = self.indexes[ai]
        gao_pos = self.atom_gao_pos[ai]
        proj = [t[p] for p in gao_pos]
        lo, hi = rel.root_range()
        for j, v in enumerate(proj):
            l, r = rel.gap_around(lo, hi, j, v)
            if (l, r) != (v, v):
                # gap at column j: equalities before, interval at gao_pos[j]
                pattern: list = [STAR] * gao_pos[j]
                for jj in range(j):
                    pattern[gao_pos[jj]] = proj[jj]
                return Constraint(tuple(pattern), gao_pos[j], l, r)
            lo, hi = rel.child_range(lo, hi, j, v)
        return None

    # -- filter handling -------------------------------------------------
    def _filter_gap(self, t) -> Constraint | None:
        """Treat ``u < v`` symmetry filters as implicit relations: if
        t[v] <= t[u], the box (pattern = t[:u+1] equalities, interval
        (-inf, t[u]+1) at v's coordinate... ) is output-free."""
        for (u, v) in self.filters:
            lo_pos, hi_pos = min(u, v), max(u, v)
            violated = not (t[u] < t[v])
            if violated:
                pattern: list = [STAR] * hi_pos
                pattern[lo_pos] = t[lo_pos]
                if u < v:
                    # need t[v] > t[u]: rule out (-inf, t[u]] at coord v
                    return Constraint(tuple(pattern), v, NEG_INF, t[u] + 1)
                else:
                    # u > v in GAO: need t[u] < t[v] ... rule out values
                    # at coord u in [t[v], +inf)
                    return Constraint(tuple(pattern), u, t[v] - 1, POS_INF)
        return None

    # -- computeFreeTuple (Algorithms 4-6, generic-poset variant) -----------
    def _truncate(self, cds: CDS, node: _Node) -> bool:
        """Algorithm 6: rule out the first non-wildcard branch above
        ``node``.  Returns False if the whole space is exhausted."""
        while node.parent is not None:
            if node.label is not STAR:
                x = node.label
                node.parent.intervals.insert(x - 1, x + 1)
                if node.label in node.parent.children:
                    del node.parent.children[node.label]
                return True
            node = node.parent
        return False

    def _compute_free_tuple(self, cds: CDS, t: list[int]) -> bool:
        """Advance ``t`` (in place) to the next free tuple >= t; False if
        the output space is exhausted."""
        n = self.n
        depth = 0
        guard = 0
        while True:
            guard += 1
            if guard > 10_000_000:  # pragma: no cover
                raise RuntimeError("computeFreeTuple did not terminate")
            G = cds.generalizing(tuple(t[:depth]))
            x = t[depth]
            # fixpoint of next_free across all nodes in G (chain for NEO)
            y = x
            while True:
                y2 = y
                for nd in G:
                    y2 = nd.intervals.next_free(y2)
                if y2 == y:
                    break
                y = y2
            if y >= self.universe:
                y = POS_INF
            # Idea 5: cache merged knowledge into the chain's bottom node.
            # Caching at the bottom is sound only when G is a chain (always
            # the case for NEO GAOs on β-acyclic queries, Prop 4.2); for
            # general posets we cache into the full-equality specialization
            # branch instead (§4.8).
            if G and y > x:
                bottom = _chain_bottom(G) if len(G) > 1 else G[0]
                if bottom is None:
                    bottom = cds.spec_node(tuple(t[:depth]))
                bottom.intervals.insert(x - 1, y if y < POS_INF else POS_INF)
                if bottom.intervals.next_free(0) >= self.universe:
                    if not self._truncate(cds, bottom):
                        return False
                    depth = 0
                    continue
            if y >= POS_INF:
                # backtrack (Algorithm 4 line 6-9)
                if depth == 0:
                    return False
                depth -= 1
                t[depth] += 1
                for i in range(depth + 1, n):
                    t[i] = 0
                continue
            if y > x:
                t[depth] = y
                for i in range(depth + 1, n):
                    t[i] = 0
            if depth == n - 1:
                return True
            depth += 1

    # -- outer loop (Algorithm 3) -------------------------------------------
    def run(self, emit=None) -> int:
        n = self.n
        cds = CDS(n)
        t = [0] * n
        count = 0
        natoms = len(self.query.atoms)
        last_gap: list[Constraint | None] = [None] * natoms
        while self._compute_free_tuple(cds, t):
            self.stats["free_tuples"] += 1
            self.stats["rows_expanded"] += 1
            found_gap = False
            # implicit filter constraints first (cheap)
            fc = self._filter_gap(t)
            if fc is not None:
                cds.insert(fc)
                continue
            advance_to: Constraint | None = None
            for ai in range(natoms):
                prev = last_gap[ai]
                if self.skip_probes and prev is not None:
                    # Idea 4a: the previous gap's right endpoint is a value
                    # known to be *present* — if the gap was at the atom's
                    # last column and t sits exactly on that endpoint with
                    # the same pattern, the projection of t is in R: no gap.
                    if (prev.pos == self.atom_gao_pos[ai][-1]
                            and prev.r < POS_INF
                            and t[prev.pos] == prev.r
                            and prev.pattern_matches(t)):
                        self.stats["probe_skips"] += 1
                        continue
                    # Idea 4b: t still inside the previous gap (possible for
                    # non-skeleton atoms, whose gaps are not in the CDS).
                    if prev.matches(t):
                        self.stats["probe_skips"] += 1
                        found_gap = True
                        if advance_to is None:
                            advance_to = prev
                        continue
                c = self.seek_gap(ai, t)
                if c is None:
                    continue
                last_gap[ai] = c
                found_gap = True
                self.stats["gaps"] += 1
                if self.in_skeleton[ai]:
                    cds.insert(c)
                else:
                    # Idea 7: remember the gap to advance the frontier, but
                    # do not grow the CDS with cyclic-part constraints.
                    advance_to = c
            if advance_to is not None:
                self._advance_past(t, advance_to)
            if not found_gap:
                count += 1
                self.stats["outputs"] += 1
                if emit is not None:
                    emit(tuple(t))
                # Idea 2: move the frontier, do not insert a unit gap.
                t[n - 1] += 1
        self.stats["level_rows"][n - 1] = count
        return count

    def _advance_past(self, t: list[int], c: Constraint) -> None:
        d = c.pos
        if c.r < POS_INF:
            t[d] = c.r
            for i in range(d + 1, self.n):
                t[i] = 0
        else:
            # carry into the previous coordinate
            if d == 0:
                t[0] = POS_INF  # exhausts on next computeFreeTuple
                return
            t[d - 1] += 1
            for i in range(d, self.n):
                t[i] = 0

    def count(self) -> int:
        return self.run()

    def enumerate(self, limit: int | None = None) -> np.ndarray:
        """Output tuples: int64, columns in GAO order
        (``self.output_vars``), rows in lexicographic order; ``limit``
        truncates after the ordering (the shared engine contract — the
        moving frontier advances lexicographically, so emission order is
        already the sorted order)."""
        from .lftj_ref import _Done

        if limit is not None and limit <= 0:
            return np.zeros((0, self.n), dtype=np.int64)
        out: list[tuple[int, ...]] = []

        def emit(t):
            out.append(t)
            if limit is not None and len(out) >= limit:
                raise _Done

        try:
            self.run(emit)
        except _Done:
            pass
        return np.array(out, dtype=np.int64).reshape(-1, self.n)

    @property
    def output_vars(self) -> tuple[str, ...]:
        """Column order of :meth:`enumerate` (the GAO)."""
        return self.gao


def minesweeper_count(query: Query, db: Database,
                      gao: tuple[str, ...] | None = None, **kw) -> int:
    return Minesweeper(query, db, gao, **kw).count()
