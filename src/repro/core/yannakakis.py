"""Vectorized counting Yannakakis — the TPU-native Minesweeper analogue.

The paper (§4.11): "#Minesweeper is to message passing what Minesweeper was
to Yannakakis".  For β-acyclic graph-pattern queries the work Minesweeper's
CDS caches away is exactly the work semijoin reduction + count message
passing never performs: every sub-pattern count is computed once per node,
not once per occurrence.  That is why Minesweeper dominates the acyclic,
low-selectivity benchmarks (Table 7, Figures 3-5) — and this engine
reproduces that behaviour with two fully-vectorized passes:

  1. bottom-up over the query's variable tree: per node-id count vectors
     ``c_leaf = [x ∈ v_i]``; ``c_parent = unary ⊙ ∏_children (A @ c_child)``
     where ``A @ c`` is a CSR gather + ``segment_sum`` (one SpMV per query
     edge — O(#edges) total work, the instance-optimal flavour);
  2. the root vector's sum is the count (#Minesweeper's Idea-8 tallies).

For enumeration, the same messages act as semijoin filters: a node value
stays active iff every child message is nonzero, and the reduced frontier
is handed to the vectorized LFTJ for top-down materialization — classic
Yannakakis, zero dangling intermediates.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .device_graph import GraphDB
from .hypergraph import Hypergraph, is_beta_acyclic
from .plan import JoinPlan
from .query import Query


class NotTreeShaped(ValueError):
    pass


def variable_tree(query: Query) -> dict[str, list[str]]:
    """Adjacency of the query's variable graph; raises if not a forest."""
    adj: dict[str, list[str]] = {v: [] for v in query.variables}
    seen_edges = set()
    n_edges = 0
    for a in query.atoms:
        if a.arity == 1:
            continue
        if a.arity != 2:
            raise NotTreeShaped("binary atoms only")
        u, v = a.vars
        if u == v:
            raise NotTreeShaped("self loop")
        key = frozenset((u, v))
        if key in seen_edges:
            continue  # parallel atoms collapse (same constraint)
        seen_edges.add(key)
        adj[u].append(v)
        adj[v].append(u)
        n_edges += 1
    # forest check: every connected component must satisfy |E| = |V| - 1
    if query.filters:
        raise NotTreeShaped("filters break tree message passing")
    visited: set[str] = set()
    for root in adj:
        if root in visited:
            continue
        stack, comp_v = [root], 0
        comp_nodes = set()
        while stack:
            x = stack.pop()
            if x in comp_nodes:
                continue
            comp_nodes.add(x)
            stack.extend(adj[x])
        comp_e = sum(len(adj[x]) for x in comp_nodes) // 2
        if comp_e != len(comp_nodes) - 1:
            raise NotTreeShaped("variable graph is cyclic")
        visited |= comp_nodes
    return adj


@partial(jax.jit, static_argnames=("num_segments",))
def _spmv(indptr, indices, src_ids, c, *, num_segments):
    """y[x] = Σ_{(x,z) ∈ E} c[z]  — gather + segment_sum over the CSR."""
    msg = c[indices]
    return jax.ops.segment_sum(msg, src_ids, num_segments=num_segments)


class CountingYannakakis:
    """Count β-acyclic graph patterns in O(#query-edges) SpMV passes."""

    def __init__(self, query: Query, gdb: GraphDB,
                 root: str | None = None,
                 plan: JoinPlan | None = None):
        hg = Hypergraph.of(query)
        if not is_beta_acyclic(hg):
            raise NotTreeShaped("query is β-cyclic; use vlftj or hybrid")
        self.query = query
        self.gdb = gdb
        self.join_plan = plan
        self.adj = variable_tree(query)
        self.unary_of: dict[str, list[str]] = {v: [] for v in query.variables}
        for a in query.atoms:
            if a.arity == 1:
                self.unary_of[a.vars[0]].append(a.rel)
        if root is None and plan is not None and plan.root is not None:
            root = plan.root
        self.root = root or query.variables[0]
        # enumeration column order: the plan's GAO covers every variable
        # (yannakakis plans carry choose_gao(query)); plan-free
        # construction derives the same order directly
        if plan is not None and set(plan.gao) == set(query.variables):
            self.gao = plan.gao
        else:
            from .gao import choose_gao
            self.gao = choose_gao(query)
        # spmvs is the native counter; rows_expanded / level_rows source
        # the unified engine schema (obs/schema.ENGINE_STATS_SOURCE_KEYS):
        # every SpMV propagates one message over the n_nodes id domain,
        # and the root tally vector is the engine's one "frontier"
        self.stats = {"spmvs": 0, "rows_expanded": 0, "level_rows": {}}

    def _unary_mask(self, var: str) -> jnp.ndarray:
        n = self.gdb.n_nodes
        vec = jnp.ones(n, dtype=jnp.int64)
        for u in self.unary_of[var]:
            vec = vec * self.gdb.dev(f"bitmap:{u}").astype(jnp.int64)
        return vec

    def message_to_root(self, root: str | None = None) -> jnp.ndarray:
        """Per-node-id count vector at the root variable (Idea 8 tallies)."""
        root = root or self.root
        indptr = self.gdb.dev("indptr")
        indices = self.gdb.dev("indices")
        src_ids = self.gdb.dev("src_ids")
        n = self.gdb.n_nodes

        def up(var: str, parent: str | None) -> jnp.ndarray:
            c = self._unary_mask(var)
            for ch in self.adj[var]:
                if ch == parent:
                    continue
                c_ch = up(ch, var)
                self.stats["spmvs"] += 1
                self.stats["rows_expanded"] += n
                c = c * _spmv(indptr, indices, src_ids, c_ch,
                              num_segments=n)
            return c

        # product over the root's own component; other components multiply
        # as scalar factors (cross products)
        comp_roots = self._component_roots(root)
        self.stats["level_rows"][0] = n
        c_root = up(root, None)
        self._cross_factor = 1
        for r in comp_roots:
            if r != root:
                self._cross_factor *= int(up(r, None).sum())
        return c_root

    def _component_roots(self, root: str) -> list[str]:
        roots, visited = [], set()
        order = [root] + [v for v in self.query.variables if v != root]
        for v in order:
            if v in visited:
                continue
            roots.append(v)
            stack = [v]
            while stack:
                x = stack.pop()
                if x in visited:
                    continue
                visited.add(x)
                stack.extend(self.adj[x])
        return roots

    def count(self) -> int:
        c_root = self.message_to_root()
        return int(c_root.sum()) * self._cross_factor

    def semijoin_reduce(self) -> dict[str, np.ndarray]:
        """Active-value masks per variable after full semijoin reduction
        (upward + downward passes) — the enumeration prefilter."""
        indptr = self.gdb.dev("indptr")
        indices = self.gdb.dev("indices")
        src_ids = self.gdb.dev("src_ids")
        n = self.gdb.n_nodes
        up_msg: dict[tuple[str, str], jnp.ndarray] = {}

        def up(var: str, parent: str | None) -> jnp.ndarray:
            c = self._unary_mask(var) > 0
            for ch in self.adj[var]:
                if ch == parent:
                    continue
                m = up(ch, var)
                self.stats["spmvs"] += 1
                self.stats["rows_expanded"] += n
                c = c & (_spmv(indptr, indices, src_ids,
                               m.astype(jnp.int64), num_segments=n) > 0)
            if parent is not None:
                up_msg[(var, parent)] = c
            return c

        active: dict[str, jnp.ndarray] = {}

        def down(var: str, parent: str | None, mask_from_parent):
            c = self._unary_mask(var) > 0
            if mask_from_parent is not None:
                c = c & mask_from_parent
            for ch in self.adj[var]:
                if ch == parent:
                    continue
                c = c & (_spmv(indptr, indices, src_ids,
                               up_msg[(ch, var)].astype(jnp.int64),
                               num_segments=n) > 0)
            active[var] = c
            for ch in self.adj[var]:
                if ch == parent:
                    continue
                m = _spmv(indptr, indices, src_ids, c.astype(jnp.int64),
                          num_segments=n) > 0
                down(ch, var, m)

        for r in self._component_roots(self.root):
            up(r, None)
            down(r, None, None)
        return {v: np.asarray(m) for v, m in active.items()}

    def enumerate(self, limit: int | None = None) -> np.ndarray:
        """Backward-expansion enumeration: int64 tuples, columns in GAO
        order (``self.output_vars``), rows lex-sorted; ``limit``
        truncates after the ordering.  See
        ``repro.results.backward.yannakakis_rows``."""
        from ..results.backward import yannakakis_rows
        rows, _ = yannakakis_rows(self)
        return rows if limit is None else rows[:limit]

    @property
    def output_vars(self) -> tuple[str, ...]:
        """Column order of :meth:`enumerate`."""
        return self.gao


def yannakakis_count(query: Query, gdb: GraphDB) -> int:
    return CountingYannakakis(query, gdb).count()
