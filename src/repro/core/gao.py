"""Global attribute order (GAO) selection (paper §4.9).

For β-acyclic queries the GAO must be a NEO; among NEOs the paper picks the
one with the *longest path length* — longer runs of consecutive attributes
that are adjacent in the query graph allow more CDS caching (Table 4 shows
ABCDE beating the other NEOs on 4-path).

For cyclic queries (no NEO exists) we use the standard WCOJ heuristic:
greedily order variables so that each next variable is covered by as many
atoms shared with already-bound variables as possible (maximizes
intersection pruning for the leapfrog), tie-broken by total degree.
"""
from __future__ import annotations

from .hypergraph import Hypergraph, adjacency, all_neos, is_beta_acyclic
from .query import Query


def _path_score(order: tuple[str, ...], adj: dict[str, set[str]]) -> int:
    """Length of the longest run of consecutive order-adjacent variables."""
    best = run = 0
    for u, v in zip(order, order[1:]):
        if v in adj[u]:
            run += 1
            best = max(best, run)
        else:
            run = 0
    return best


def _cyclic_heuristic_order(q: Query) -> tuple[str, ...]:
    hg = Hypergraph.of(q)
    adj = adjacency(hg)
    degree = {v: sum(v in a.vars for a in q.atoms) for v in hg.vertices}
    order: list[str] = []
    remaining = set(hg.vertices)
    while remaining:
        bound = set(order)

        def key(v: str) -> tuple[int, int, str]:
            # atoms that connect v to already-bound variables
            connect = sum(
                1 for a in q.atoms
                if v in a.vars and any(u in bound for u in a.vars)
            )
            return (connect, degree[v], v)

        # lexicographically max (connectivity, degree), stable by name
        nxt = max(sorted(remaining), key=key)
        order.append(nxt)
        remaining.remove(nxt)
    return tuple(order)


def choose_gao(q: Query) -> tuple[str, ...]:
    """GAO: best NEO for β-acyclic queries, WCOJ heuristic otherwise."""
    hg = Hypergraph.of(q)
    if is_beta_acyclic(hg):
        neos = all_neos(hg)
        adj = adjacency(hg)
        # longest-path NEO; stable tie-break by variable-name order
        return max(sorted(neos), key=lambda o: _path_score(o, adj))
    return _cyclic_heuristic_order(q)
