"""Cost-based query planner: ``(Query, GraphStats) -> JoinPlan``.

This is the other half of the plan/execute split (see ``core/plan.py``).
It absorbs the planning logic that used to be fused into the engines'
constructors — ``engine.pick_engine``'s heuristic routing, ``gao.choose_gao``,
``vlftj.compile_plan``, the hybrid tree/core bridge decomposition, and
Yannakakis' tree-shape check — and replaces the first-heuristic-hit GAO
choice with cost-based selection among enumerated candidates:

  * **GAO candidates**: all NEOs for β-acyclic queries (capped), plus
    greedy connected-expansion orders from every start variable, plus the
    legacy heuristic pick — each costed with a System-R-flavoured
    independence model over :class:`GraphStats`.
  * **Engine candidates** (``engine="auto"``): counting Yannakakis when
    the query is a filter-free β-acyclic forest, the hybrid tree/core
    split when the bridge decomposition applies, and vectorized LFTJ
    always; the cheapest estimated plan wins.
  * **Cost annotations**: every plan carries its per-level estimates and
    the AGM bound, so ``bench_planner.py`` can correlate the model's
    ranking against measured runtimes.

Plans are pure functions of ``(query structure, stats fingerprint)``, so
:class:`PlanCache` memoizes them LRU-style; ``serve.QueryServer`` uses it
to serve repeated pattern shapes without re-planning.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import replace
from itertools import permutations

from .agm import fractional_edge_cover
from .gao import _cyclic_heuristic_order, choose_gao
from .hypergraph import Hypergraph, all_neos, is_beta_acyclic
from .plan import (GraphStats, HybridPlan, JoinPlan, compile_levels,
                   executor_geometry)
from .query import Atom, Query

# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def choose_level_layouts(query: Query, gao: tuple[str, ...],
                         stats: GraphStats) -> tuple[str, ...]:
    """Per-GAO-level adjacency representation for the hybrid layout.

    A level benefits from bitsets only where membership *checks* happen:
    it has >= 2 bound edge sources (the probe source pays gathers either
    way — candidate expansion needs the sorted array).  There the check
    against a hub vertex is one word-gather + bit-test instead of
    ``log2(deg)`` binary-search rounds, so with any hubs present the
    bitset path is picked for the hub-tagged rows: ``'bitset'`` when the
    adjacency mass is almost entirely hub-owned (the executor still
    falls back row-wise), ``'mixed'`` otherwise, ``'array'`` when the
    stats carry no layout.  Deterministic in ``(query, gao, stats)`` —
    the same inputs the cost model prices, so plans stay cacheable.
    """
    levels = compile_levels(query, gao)
    out = []
    for lp in levels:
        if stats.n_hubs == 0 or len(lp.edge_sources) < 2:
            out.append("array")
        elif stats.hub_edge_fraction >= 0.95:
            out.append("bitset")
        else:
            out.append("mixed")
    return tuple(out)


def _cost_model(query: Query, gao: tuple[str, ...], stats: GraphStats,
                seed_frontier: float | None = None,
                ) -> tuple[float, tuple[float, ...], tuple[float, ...]]:
    """Shared survivor model: ``(total_cost, level_costs, frontiers)``
    where ``frontiers[i]`` estimates the frontier size *after* level i
    (``frontiers[-1]`` is the estimated output cardinality)."""
    levels = compile_levels(query, gao)
    layouts = choose_level_layouts(query, gao, stats)
    n = max(1, stats.n_nodes)
    logd = math.log2(max(2, stats.max_degree))
    # the executor's padding defaults (shared with VLFTJ.__init__)
    width, chunk_rows = executor_geometry(stats.max_degree)
    frontier = 1.0
    costs: list[float] = []
    frontiers: list[float] = []
    for i, lp in enumerate(levels):
        sel_unary = 1.0
        for u in lp.unary:
            sel_unary *= stats.unary_selectivity(u)
        if i == 0:
            frontier = n * sel_unary if seed_frontier is None \
                else seed_frontier
            costs.append(float(n))          # bitmap-filtered domain scan
            frontiers.append(frontier)
            continue
        # per-row survivor rate: the one survivor model, shared with the
        # dist layer's re-balance pricing (estimate_extension_degree)
        survive = estimate_extension_degree(lp, stats)
        if lp.edge_sources:
            extra_checks = max(0, len(lp.edge_sources) - 1)
            # per-check gather rounds: binary search pays ~log2(d); a
            # hub-tagged check source pays one bitset word-gather.  The
            # hub fraction of adjacency mass approximates how often a
            # bound frontier vertex is a hub.
            check_rounds = logd
            if layouts[i] in ("bitset", "mixed"):
                hf = stats.hub_edge_fraction
                check_rounds = hf * 1.0 + (1.0 - hf) * logd
            padded = math.ceil(frontier / chunk_rows) * chunk_rows * width
            work = padded * (1.0 + extra_checks * check_rounds)
        else:
            # no bound edge neighbor: host cross product with the domain
            work = frontier * n * sel_unary
        costs.append(max(work, 1.0))
        frontier = max(frontier * survive, 1e-6)
        frontiers.append(frontier)
    return sum(costs), tuple(costs), tuple(frontiers)


def estimate_vlftj_cost(query: Query, gao: tuple[str, ...],
                        stats: GraphStats,
                        seed_frontier: float | None = None,
                        ) -> tuple[float, tuple[float, ...]]:
    """Estimated work (VPU lanes touched) of a vectorized-LFTJ run.

    The executor pads every frontier chunk to ``chunk_rows`` rows of
    ``width`` candidate lanes (``width`` = pow2ceil(max degree)), so a
    level's cost is the *padded* element count — lanes execute whether
    or not they hold live candidates — times one log-degree membership
    check per extra bound edge source.  Survivor counts use the
    independence model: ``d/n`` per membership check, ``|u|/n`` per
    unary predicate, ``1/2`` per inequality filter.
    """
    total, costs, _ = _cost_model(query, gao, stats, seed_frontier)
    return total, costs


def estimate_emission(query: Query, gao: tuple[str, ...],
                      stats: GraphStats) -> tuple[float, float]:
    """Estimated materialization cells for ``(flat, factorized)`` output.

    Flat emission stores ``est_out × k`` int64 cells; the trie-factorized
    form stores two cells (value, parent) per trie node, and the per-level
    node counts are exactly the frontier estimates the survivor model
    already tracks.  The planner records the cheaper mode in
    ``JoinPlan.output_mode`` for enumeration plans."""
    _, _, frontiers = _cost_model(query, gao, stats)
    out = frontiers[-1]
    flat = out * len(gao)
    fact = 2.0 * sum(frontiers)
    return flat, fact


def estimate_extension_degree(lp, stats: GraphStats) -> float:
    """Expected per-row extension fanout of one GAO level.

    The survivor model's per-level multiplier, factored out for the
    distributed layer: a frontier shard's cost is (rows × this), and
    ``repro.dist.rebalance`` compares shards on exactly that product when
    deciding whether a mid-join re-deal is worth a shuffle.  Rows whose
    probe vertex is known use the true adjacency length instead
    (``rebalance.row_extension_costs``); this estimate is the fallback
    when only :class:`GraphStats` is available."""
    n = max(1, stats.n_nodes)
    d = max(1.0, stats.avg_degree)
    sel_unary = 1.0
    for u in lp.unary:
        sel_unary *= stats.unary_selectivity(u)
    sel_ineq = 0.5 ** (len(lp.lower) + len(lp.upper))
    if lp.edge_sources:
        extra = max(0, len(lp.edge_sources) - 1)
        return max(d * ((d / n) ** extra) * sel_unary * sel_ineq, 1e-6)
    return max(n * sel_unary * sel_ineq, 1e-6)


def estimate_yannakakis_cost(query: Query, stats: GraphStats) -> float:
    """One SpMV per distinct variable-graph edge + one mask per unary."""
    var_edges = {frozenset(a.vars) for a in query.atoms
                 if a.arity == 2 and a.vars[0] != a.vars[1]}
    n_unary = sum(1 for a in query.atoms if a.arity == 1)
    return (len(var_edges) * max(1, stats.n_edges)
            + n_unary * max(1, stats.n_nodes))


# ---------------------------------------------------------------------------
# hybrid tree/core decomposition (absorbed from hybrid.HybridDecomposition)
# ---------------------------------------------------------------------------

def _var_edges(query: Query) -> list[tuple[str, str]]:
    out = []
    seen = set()
    for a in query.atoms:
        if a.arity == 2 and a.vars[0] != a.vars[1]:
            key = frozenset(a.vars)
            if key not in seen:
                seen.add(key)
                out.append((a.vars[0], a.vars[1]))
    return out


def _bridges(vertices, edges) -> set[frozenset]:
    """Bridges via DFS low-link (tiny graphs)."""
    adj: dict[str, list[str]] = {v: [] for v in vertices}
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    disc: dict[str, int] = {}
    low: dict[str, int] = {}
    bridges: set[frozenset] = set()
    timer = [0]

    def dfs(u: str, parent: str | None):
        disc[u] = low[u] = timer[0]
        timer[0] += 1
        skipped_parent_edge = False
        for w in adj[u]:
            if w == parent and not skipped_parent_edge:
                skipped_parent_edge = True
                continue
            if w in disc:
                low[u] = min(low[u], disc[w])
            else:
                dfs(w, u)
                low[u] = min(low[u], low[w])
                if low[w] > disc[u]:
                    bridges.add(frozenset((u, w)))

    for v in vertices:
        if v not in disc:
            dfs(v, None)
    return bridges


def decompose_hybrid(query: Query) -> HybridPlan | None:
    """Tree/core split for §4.12 lollipop-shaped queries, or None.

    Supported shape: one cyclic core, trees hanging off a single
    attachment variable, filters confined to one side, no filters in the
    tree part (counting message passing cannot apply ``<``).
    """
    edges = _var_edges(query)
    if not edges:
        return None
    bridges = _bridges(query.variables, edges)
    core_edges = [e for e in edges if frozenset(e) not in bridges]
    if not core_edges or len(core_edges) == len(edges):
        return None  # fully acyclic or fully cyclic: no hybrid split
    core_vars = sorted({v for e in core_edges for v in e})
    # attachment vars: core vars incident to a bridge
    attach = sorted({v for e in bridges for v in e if v in core_vars})
    if len(attach) != 1:
        return None
    attachment = attach[0]
    core_set = set(core_vars)
    tree_vars = [v for v in query.variables
                 if v not in core_set or v == attachment]
    tree_set = set(tree_vars)
    # filters must stay within one side
    for f in query.filters:
        inside_core = f.left in core_set and f.right in core_set
        inside_tree = f.left in tree_set and f.right in tree_set
        if not (inside_core or inside_tree):
            return None
    tree_atoms: list[Atom] = []
    core_atoms: list[Atom] = []
    for a in query.atoms:
        if a.arity == 1:
            (tree_atoms if a.vars[0] in tree_set else core_atoms).append(a)
        elif frozenset(a.vars) in bridges:
            tree_atoms.append(a)
        else:
            core_atoms.append(a)
    tree_filters = [f for f in query.filters
                    if f.left in tree_set and f.right in tree_set]
    core_filters = [f for f in query.filters
                    if f.left in core_set and f.right in core_set]
    if tree_filters:
        return None  # counting message passing cannot apply < filters
    tree_query = Query(tuple(tree_atoms), (), f"{query.name}-tree")
    core_query = Query(tuple(core_atoms), tuple(core_filters),
                       f"{query.name}-core")
    rest = _cyclic_heuristic_order(core_query)
    core_gao = (attachment,) + tuple(v for v in rest if v != attachment)
    return HybridPlan(tree_query, core_query, attachment, core_gao)


# ---------------------------------------------------------------------------
# GAO candidate enumeration
# ---------------------------------------------------------------------------

_EXHAUSTIVE_VARS = 5     # full permutation search up to this many variables
_NEO_CAP = 64            # NEO candidates considered for β-acyclic queries


def candidate_gaos(query: Query, limit: int = 160) -> list[tuple[str, ...]]:
    """Candidate GAOs: NEOs (acyclic), exhaustive permutations (tiny),
    greedy connected expansions from every start, legacy heuristic pick."""
    hg = Hypergraph.of(query)
    cands: "OrderedDict[tuple[str, ...], None]" = OrderedDict()
    cands[choose_gao(query)] = None          # legacy pick always considered
    if is_beta_acyclic(hg):
        for neo in all_neos(hg, limit=_NEO_CAP):
            cands[neo] = None
    if query.num_vars <= _EXHAUSTIVE_VARS:
        for perm in permutations(query.variables):
            cands[perm] = None
    else:
        # greedy connected expansion from each start variable
        adj = {v: set() for v in query.variables}
        for a in query.atoms:
            if a.arity == 2:
                u, w = a.vars
                if u != w:
                    adj[u].add(w)
                    adj[w].add(u)
        degree = {v: sum(v in a.vars for a in query.atoms)
                  for v in query.variables}
        for start in query.variables:
            order = [start]
            remaining = set(query.variables) - {start}
            while remaining:
                bound = set(order)
                nxt = max(sorted(remaining),
                          key=lambda v: (len(adj[v] & bound), degree[v]))
                order.append(nxt)
                remaining.remove(nxt)
            cands[tuple(order)] = None
    return list(cands)[:limit]


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------

def _safe_estimate(query: Query, gao: tuple[str, ...], stats: GraphStats
                   ) -> tuple[float, tuple[float, ...], tuple[float, ...]]:
    """Cost estimate ``(total, level_costs, level_frontiers)``,
    tolerating non-graph atoms the model cannot price."""
    try:
        return _cost_model(query, gao, stats)
    except ValueError:
        return math.inf, (), ()


def _agm_log2(query: Query, stats: GraphStats) -> float | None:
    try:
        _, log2_bound = fractional_edge_cover(
            query, stats.relation_sizes(query))
        return log2_bound
    except Exception:  # pragma: no cover - LP failure is environmental
        return None


def _plan_vlftj(query: Query, stats: GraphStats,
                gao: tuple[str, ...] | None = None,
                engine: str = "vlftj") -> JoinPlan:
    # the AGM LP is an annotation, not a decision input — skip it when the
    # caller pins the GAO (plan-free engine wrappers on hot paths)
    agm = None
    if gao is None:
        best, best_cost = choose_gao(query), math.inf
        best_levels, best_fronts = (), ()
        for cand in candidate_gaos(query):
            cost, levels, fronts = _safe_estimate(query, cand, stats)
            if cost < best_cost:
                best, best_cost = cand, cost
                best_levels, best_fronts = levels, fronts
        gao, est_cost = best, best_cost
        level_costs, level_fronts = best_levels, best_fronts
        agm = _agm_log2(query, stats)
    else:
        gao = tuple(gao)
        est_cost, level_costs, level_fronts = _safe_estimate(
            query, gao, stats)
    try:
        layouts = choose_level_layouts(query, gao, stats)
    except ValueError:
        layouts = ()        # non-graph atoms: executor stays array-only
    return JoinPlan(query=query, engine=engine, gao=gao,
                    est_cost=est_cost, level_costs=level_costs,
                    level_est_rows=level_fronts,
                    agm_log2=agm, level_layouts=layouts,
                    stats_fingerprint=stats.fingerprint())


def _plan_yannakakis(query: Query, stats: GraphStats,
                     root: str | None = None) -> JoinPlan | None:
    if query.filters or not is_beta_acyclic(Hypergraph.of(query)):
        return None
    # forest check (variable_tree raises NotTreeShaped on cyclic shapes)
    from .yannakakis import NotTreeShaped, variable_tree
    try:
        variable_tree(query)
    except NotTreeShaped:
        return None
    return JoinPlan(query=query, engine="yannakakis",
                    gao=choose_gao(query),
                    root=root or query.variables[0],
                    est_cost=estimate_yannakakis_cost(query, stats),
                    agm_log2=_agm_log2(query, stats),
                    stats_fingerprint=stats.fingerprint())


def _plan_hybrid(query: Query, stats: GraphStats) -> JoinPlan | None:
    hp = decompose_hybrid(query)
    if hp is None:
        return None
    tree_cost = estimate_yannakakis_cost(hp.tree_query, stats)
    # seeded core: the tree pass leaves ≈ sel-filtered attachment values
    seed = max(1.0, stats.n_nodes * 0.5)
    core_cost, level_costs, level_fronts = _cost_model(
        hp.core_query, hp.core_gao, stats, seed_frontier=seed)
    return JoinPlan(query=query, engine="hybrid", gao=hp.core_gao,
                    decomposition=hp,
                    est_cost=tree_cost + core_cost,
                    level_costs=level_costs,
                    level_est_rows=level_fronts,
                    agm_log2=_agm_log2(query, stats),
                    level_layouts=choose_level_layouts(
                        hp.core_query, hp.core_gao, stats),
                    stats_fingerprint=stats.fingerprint())


def candidate_plans(query: Query, stats: GraphStats) -> list[JoinPlan]:
    """All auto-routable plans for a query, unsorted."""
    out: list[JoinPlan] = []
    yp = _plan_yannakakis(query, stats)
    if yp is not None:
        out.append(yp)
    hp = _plan_hybrid(query, stats)
    if hp is not None:
        out.append(hp)
    out.append(_plan_vlftj(query, stats))
    return out


def _with_output_mode(plan: JoinPlan, stats: GraphStats,
                      output: str) -> JoinPlan:
    """Stamp the emission mode onto an enumeration plan.

    ``output='rows'`` costs flat-vs-factorized emission when the plan's
    GAO covers every variable (the trie form needs a total column
    order); the message-passing engines always emit flat."""
    if output == "count":
        return plan
    mode = "flat"
    if plan.engine not in ("yannakakis", "hybrid") \
            and set(plan.gao) == set(plan.query.variables):
        try:
            flat, fact = estimate_emission(plan.query, plan.gao, stats)
            if fact < flat:
                mode = "factorized"
        except ValueError:
            pass  # non-graph atoms: the model cannot price emission
    return replace(plan, output_mode=mode)


def plan_query(query: Query, stats: GraphStats, engine: str = "auto",
               gao: tuple[str, ...] | None = None,
               output: str = "count") -> JoinPlan:
    """Build the physical plan for ``query`` against ``stats``.

    ``engine="auto"`` picks the cheapest of the candidate plans;
    an explicit engine name forces that physical operator (the reference
    engines — ``lftj_ref``, ``minesweeper_ref``, ``binary`` — are only
    reachable this way).  ``output='rows'`` builds an enumeration plan:
    the result carries ``output_mode`` ('flat' or 'factorized', costed
    by :func:`estimate_emission`) instead of the default 'count'.
    """
    if output not in ("count", "rows"):
        raise ValueError(f"unknown output {output!r}; "
                         "options: ('count', 'rows')")
    if output == "rows":
        plan = plan_query(query, stats, engine=engine, gao=gao)
        return _with_output_mode(plan, stats, output)
    if engine in ("auto", "yannakakis") and gao is not None:
        # neither auto routing nor message passing honors a pinned
        # attribute order — reject rather than silently ignore it
        raise ValueError(
            f"gao= is not supported with engine={engine!r}; pin a "
            "GAO-driven engine (vlftj/lftj_ref/minesweeper_ref/binary)")
    if engine == "auto":
        return min(candidate_plans(query, stats),
                   key=lambda p: p.est_cost)
    if engine == "vlftj":
        return _plan_vlftj(query, stats, gao=gao)
    if engine == "yannakakis":
        p = _plan_yannakakis(query, stats)
        if p is None:
            from .yannakakis import NotTreeShaped
            raise NotTreeShaped(
                f"{query.name}: not a filter-free β-acyclic forest")
        return p
    if engine == "hybrid":
        p = _plan_hybrid(query, stats)
        if p is not None:
            if gao is not None:
                raise ValueError("gao= is not supported when the hybrid "
                                 "decomposition applies (the core GAO is "
                                 "attachment-pinned)")
            return p
        # unsupported shape: hybrid degrades to plain vectorized LFTJ
        return _plan_vlftj(query, stats, gao=gao, engine="hybrid")
    if engine in ("lftj_ref", "binary"):
        return _plan_vlftj(query, stats, gao=gao, engine=engine)
    if engine == "minesweeper_ref":
        # Minesweeper's GAO must be a NEO when one exists (Prop. 4.2)
        ms_gao = tuple(gao) if gao is not None else choose_gao(query)
        est, levels, fronts = _safe_estimate(query, ms_gao, stats)
        return JoinPlan(query=query, engine="minesweeper_ref", gao=ms_gao,
                        est_cost=est, level_costs=levels,
                        level_est_rows=fronts,
                        agm_log2=None if gao is not None
                        else _agm_log2(query, stats),
                        stats_fingerprint=stats.fingerprint())
    raise ValueError(f"unknown engine {engine!r}")


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

class PlanCache:
    """LRU cache of :class:`JoinPlan`, keyed by query *structure*
    (atoms + filters, display name ignored), requested engine, the
    requested output ('count' vs 'rows' — enumeration plans carry an
    emission mode), and the graph-stats fingerprint — so a stats change
    invalidates entries."""

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._entries: "OrderedDict[tuple, JoinPlan]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(query: Query, stats: GraphStats, engine: str = "auto",
            output: str = "count") -> tuple:
        return (query.atoms, query.filters, engine, output,
                stats.fingerprint())

    def get(self, query: Query, stats: GraphStats,
            engine: str = "auto", output: str = "count") -> JoinPlan | None:
        k = self.key(query, stats, engine, output)
        plan = self._entries.get(k)
        if plan is not None:
            self.hits += 1
            self._entries.move_to_end(k)
        return plan

    def get_or_plan(self, query: Query, stats: GraphStats,
                    engine: str = "auto",
                    output: str = "count") -> JoinPlan:
        plan = self.get(query, stats, engine, output)
        if plan is None:
            self.misses += 1
            plan = plan_query(query, stats, engine=engine, output=output)
            self._entries[self.key(query, stats, engine, output)] = plan
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
        return plan

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0
