"""Vectorized LeapFrog TrieJoin — the TPU-native worst-case-optimal join.

The scalar LFTJ binds one variable at a time with leapfrogging iterators.
Here a *frontier* of thousands of partial bindings advances one GAO level
per step:

  1. **probe**: per frontier row, pick the shortest adjacency segment among
     the row's bound edge-neighbors (the leapfrog "smallest iterator first"
     rule, chosen per row with vector ops);
  2. **candidates**: the probe segment's values, a (rows, W) padded tile;
  3. **checks**: every other edge constraint via segmented binary search
     (``seek_lub``), every unary predicate via bitmap gather, every ``<``
     filter via vector compare — all lanes parallel;
  4. **expand**: count → compact into the next frontier (host numpy between
     jitted steps; static shapes inside).

The final level never materializes: surviving candidates are counted and
dotted with row multiplicities (the #Minesweeper trick, Idea 8).

Worst-case optimality carries over: each level emits exactly the scalar
LFTJ's bindings, and per-level work is O(probe segment + emitted · log N)
≤ Õ(AGM(Q)) for the same GAO.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .device_graph import GraphDB
from .plan import (GraphStats, JoinPlan, LevelPlan, compile_levels,
                   executor_geometry)
from .query import Query

#: backward-compatible alias — the per-level compiler now lives in
#: ``core.plan`` so the planner and the engine share one definition.
compile_plan = compile_levels


# ---------------------------------------------------------------------------
# jitted level kernels
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=(
    "probe_cols", "n_unary", "lower_cols", "upper_cols",
    "width", "n_iter", "count_only", "needs_degree", "unroll",
    "check_mode", "check_width", "rotate_checks", "summary_stride",
    "n_iter2"))
def _expand_level(indptr, indices, bitmaps, frontier, mult,
                  row_valid, *, probe_cols, n_unary,
                  lower_cols, upper_cols, width, n_iter, count_only,
                  needs_degree, unroll=False, check_mode="bsearch",
                  check_width=0, rotate_checks=False, summary=None,
                  summary_stride=128, n_iter2=9, rep_tag=None,
                  bitset_words=None):
    """One GAO level for a frontier chunk.

    frontier: (C, n_bound) int32; mult: (C,) int64; row_valid: (C,) bool
    Returns weighted counts (C,) if count_only else (cand, keep).

    ``check_mode='bitset'`` (hybrid layout): every bound edge source in
    the chunk is a hub — membership is one gather into its
    ``bitset_words`` row plus a bit test, instead of ``n_iter``
    binary-search gather rounds.  ``rep_tag`` maps vertex id -> bitset
    row (the caller's bucketing guarantees tags >= 0 here).
    """
    m = indices.shape[0]
    xs = frontier[:, list(probe_cols)]                        # (C, P)
    starts = indptr[xs]
    degs = indptr[xs + 1] - starts                            # (C, P)
    p = jnp.argmin(degs, axis=1)                              # (C,)

    def sel(a):
        return jnp.take_along_axis(a, p[:, None], axis=1)[:, 0]

    start_star = sel(starts)
    deg_star = sel(degs)

    j = jnp.arange(width, dtype=jnp.int32)
    cand_idx = start_star[:, None] + j[None, :]
    cand = indices[jnp.clip(cand_idx, 0, max(0, m - 1))]      # (C, W)
    keep = (j[None, :] < deg_star[:, None]) & row_valid[:, None]

    # membership checks against every other bound edge-neighbor's segment.
    # rotate_checks synthesizes exactly the P-1 non-probe sources per row
    # (rotating from the argmin) — no wasted self-check lanes.
    n_probe = len(probe_cols)
    if rotate_checks and n_probe > 1:
        check_sources = []
        for s in range(1, n_probe):
            rot = (p[:, None] + s) % n_probe
            check_sources.append(
                (jnp.take_along_axis(xs, rot, axis=1)[:, 0], None))
    else:
        check_sources = [(xs[:, ci], ci) for ci in range(n_probe)]
    for y, ci in check_sources:
        lo = indptr[y][:, None]
        hi = (indptr[y + 1])[:, None]
        if check_mode == "bitset":
            # hybrid-layout membership: gather the check vertex's bitset
            # word at cand>>5 and test bit cand&31 — O(1) per lane
            # (kernels/intersect_bitset.py is the standalone form)
            row = rep_tag[y]                               # (C,) >= 0
            wordv = bitset_words[row[:, None],
                                 (cand >> 5).astype(jnp.int32)]  # (C, W)
            found = ((wordv >> (cand & 31).astype(jnp.uint32)) & 1) != 0
        elif check_mode == "tile":
            # tile-leapfrog membership (the Pallas-kernel strategy in
            # HLO): gather the check segment ONCE and dense-compare —
            # one table gather instead of n_iter binary-search rounds.
            # Caller guarantees every check segment fits check_width
            # (the engine buckets rows by degree).
            j2 = jnp.arange(check_width, dtype=jnp.int32)
            seg_idx = lo + j2[None, :]
            seg = indices[jnp.clip(seg_idx, 0, max(0, m - 1))]   # (C, W2)
            seg_ok = seg_idx < hi
            eq = (cand[:, :, None] == seg[:, None, :])
            eq &= seg_ok[:, None, :]
            found = eq.any(axis=2)
        elif check_mode == "bsearch2":
            from ..kernels.ref import searchsorted_segments_2level_ref
            _, found = searchsorted_segments_2level_ref(
                indices, summary, lo, hi, cand, stride=summary_stride,
                n1=n_iter, n2=n_iter2, unroll=unroll)
        else:
            _, found = kops.searchsorted_segments(
                indices, lo, hi, cand, n_iter, unroll=unroll)
        if ci is None:
            keep &= found
        else:
            is_probe = p == ci  # the chosen probe needs no self-check
            keep &= jnp.where(is_probe[:, None], True, found)

    for b in range(n_unary):
        keep &= bitmaps[b][jnp.clip(cand, 0, bitmaps[b].shape[0] - 1)]
    for col in lower_cols:
        keep &= cand > frontier[:, col][:, None]
    for col in upper_cols:
        keep &= cand < frontier[:, col][:, None]
    if needs_degree:
        keep &= (indptr[cand + 1] - indptr[cand]) > 0

    if count_only:
        counts = keep.sum(axis=1).astype(jnp.int64)
        return counts * mult
    return cand, keep


@partial(jax.jit, static_argnames=("n_unary", "needs_degree"))
def _filter_values(indptr, bitmaps, values, *, n_unary, needs_degree):
    keep = jnp.ones_like(values, dtype=bool)
    for b in range(n_unary):
        keep &= bitmaps[b][values]
    if needs_degree:
        keep &= (indptr[values + 1] - indptr[values]) > 0
    return keep


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class VLFTJ:
    """Host-orchestrated, device-vectorized LFTJ over a :class:`GraphDB`."""

    def __init__(self, query: Query, gdb: GraphDB,
                 gao: tuple[str, ...] | None = None,
                 chunk_rows: int = 8192,
                 elem_budget: int = 1 << 22,
                 width: int | None = None,
                 check_mode: str = "bsearch",
                 tile_width: int = 512,
                 rotate_checks: bool = False,
                 summary_stride: int = 128,
                 plan: JoinPlan | None = None):
        if plan is None:
            # plan-free construction is a thin wrapper over the planner
            from .planner import plan_query
            plan = plan_query(query, GraphStats.of(gdb), engine="vlftj",
                              gao=gao)
        elif gao is not None and tuple(gao) != plan.gao:
            raise ValueError("both plan= and a conflicting gao= given")
        self.query = query
        self.gdb = gdb
        self.join_plan = plan
        self.gao = plan.gao
        self.plan = plan.levels or compile_levels(query, self.gao)
        self.n_iter = gdb.bsearch_iters
        self.width, self._chunk_cap = executor_geometry(
            gdb.max_degree, chunk_rows, elem_budget, width)
        # membership strategy: 'bsearch' (log-round binary search),
        # 'auto' (degree-bucketed: rows whose check segments fit
        # ``tile_width`` take the gather-once tile-compare path — the
        # Pallas kernel's schedule; the heavy tail keeps binary search)
        self.check_mode = check_mode
        self.tile_width = tile_width
        self.rotate_checks = rotate_checks
        self.summary_stride = summary_stride
        if check_mode == "bsearch2":
            import math as _math
            blocks = max(2, gdb.max_degree // summary_stride + 2)
            self.n_iter1 = int(_math.ceil(_math.log2(blocks))) + 1
            self.n_iter2 = int(_math.ceil(_math.log2(2 * summary_stride
                                                     + 2))) + 1
        # hybrid-layout routing: the planner's per-level representation
        # choice is honoured only when the GraphDB actually carries a
        # bitset layout (hubs occupy the renumbered id prefix)
        layout = getattr(gdb, "layout", None)
        self._n_hubs = int(layout.n_hubs) if layout is not None else 0
        lv = plan.level_layouts
        self.level_layouts = (lv if len(lv) == len(self.plan)
                              else ("array",) * len(self.plan))
        # keep chunk x width under the element budget
        self.chunk_rows = self._chunk_cap
        # the unified stats namespace (docs/OBSERVABILITY.md): scalar
        # counters plus per-GAO-level observations — level_rows maps
        # level -> observed frontier cardinality after it binds (the
        # "obs" side of Q-error), level_wall_s the host wall time spent
        # in that level, level_paths the kernel path taken per row
        # (bitset/tile/bsearch).  All plain host dict writes: tracing
        # harvests these after the run, so hot loops gain no device work.
        self.stats = {"chunks": 0, "frontier_peak": 0, "candidates": 0,
                      "tile_rows": 0, "bsearch_rows": 0, "bitset_rows": 0,
                      "ll_compiles": 0, "ll_calls": 0, "rows_expanded": 0,
                      "level_rows": {}, "level_wall_s": {},
                      "level_paths": {}}
        # AOT-compiled final-level executables keyed on frontier geometry
        # (see last_level_extensions) — one compile per shape, then the
        # page loop skips the jitted dispatch path entirely
        self._ll_compiled: dict = {}

    # -- host helpers --------------------------------------------------------
    def _domain_values(self, lp: LevelPlan) -> np.ndarray:
        """Unary-filtered candidate domain for an edge-unconstrained var."""
        if lp.unary:
            base = min((self.gdb.unary[u] for u in lp.unary), key=len)
            values = np.asarray(base, dtype=np.int32)
        else:
            values = np.arange(self.gdb.n_nodes, dtype=np.int32)
        bitmaps = tuple(self.gdb.dev(f"bitmap:{u}") for u in lp.unary)
        keep = np.asarray(_filter_values(
            self.gdb.dev("indptr"), bitmaps, jnp.asarray(values),
            n_unary=len(bitmaps), needs_degree=lp.needs_degree))
        return values[keep]

    def _expand_dense(self, frontier, mult, lp, last_count):
        """A level with no bound edge neighbor: cross product with the
        (unary-filtered) domain.  Rare; GAO choice avoids it."""
        values = self._domain_values(lp)
        C = frontier.shape[0]
        if last_count and not lp.lower and not lp.upper:
            return None, None, int(mult.sum()) * values.shape[0]
        reps = np.repeat(np.arange(C), values.shape[0])
        vals = np.tile(values, C)
        ok = np.ones(vals.shape[0], dtype=bool)
        for col in lp.lower:
            ok &= vals > frontier[reps, col]
        for col in lp.upper:
            ok &= vals < frontier[reps, col]
        reps, vals = reps[ok], vals[ok]
        if last_count:
            return None, None, int(mult[reps].sum())
        nf = np.concatenate([frontier[reps], vals[:, None].astype(np.int32)],
                            axis=1)
        return nf, mult[reps], 0

    def _bucket(self, frontier, mult, lp, layout: str = "array"):
        """Bucket rows by membership strategy: representation tags first
        (hybrid layout), then degree (``check_mode='auto'``).

        When the plan marked this level ``'bitset'``/``'mixed'`` and the
        graph carries a layout, rows whose bound edge sources are *all*
        hubs take the bitset gather-test path; the remainder falls
        through to the configured array strategy.  Hubs are the
        renumbered id prefix, so the tag test is one compare.
        """
        out = []
        if (layout != "array" and self._n_hubs and lp.edge_sources
                and len(lp.edge_sources) >= 2 and frontier.shape[0]):
            elig = (frontier[:, list(lp.edge_sources)]
                    < self._n_hubs).all(axis=1)
            if elig.any():
                self.stats["bitset_rows"] += int(elig.sum())
                out.append((frontier[elig], mult[elig], "bitset"))
                rest = ~elig
                frontier, mult = frontier[rest], mult[rest]
            if frontier.shape[0] == 0:
                return out
        if self.check_mode != "auto" or not lp.edge_sources:
            mode = (self.check_mode if self.check_mode in
                    ("tile", "bsearch2") else "bsearch")
            return out + [(frontier, mult, mode)]
        deg = self.gdb.csr.degrees
        maxdeg = np.max(
            deg[frontier[:, list(lp.edge_sources)]], axis=1)
        tile = maxdeg <= self.tile_width
        self.stats["tile_rows"] += int(tile.sum())
        self.stats["bsearch_rows"] += int((~tile).sum())
        if tile.any():
            out.append((frontier[tile], mult[tile], "tile"))
        if (~tile).any():
            out.append((frontier[~tile], mult[~tile], "bsearch"))
        return out

    # -- main loop -----------------------------------------------------------
    def _run(self, count_only: bool = True, frontier: np.ndarray | None = None,
             mult: np.ndarray | None = None, max_levels: int | None = None,
             start_level: int | None = None):
        """Advance the frontier through GAO levels ``< max_levels``
        (default: all).  ``repro.results.ResultCursor`` passes
        ``max_levels=len(plan)-1`` to materialize only the penultimate
        frontier and re-enter the final level itself, page by page.

        ``start_level`` resumes mid-join from a frontier with that many
        columns already bound (default: inferred from the frontier width)
        — the level-synchronous distributed driver
        (``repro.dist.rebalance.AdaptiveJoin``) advances shards one level
        at a time this way.  When the plan carries a ``level_callback``
        it runs at every interior level boundary and may replace the
        ``(frontier, mult)`` pair (e.g. re-dealing rows across shards)
        or *raise* to suspend — the quantum scheduler's budget callback
        raises ``repro.serve.scheduler.Preempted`` carrying exactly this
        ``(frontier, mult, next level)`` state, which a later
        ``_run(frontier=..., mult=..., start_level=...)`` call resumes
        without losing or repeating any work (level boundaries are the
        engine's only host-visible synchronization points, so suspension
        there is lossless by construction).
        """
        gdb = self.gdb
        indptr, indices = gdb.dev("indptr"), gdb.dev("indices")
        # device profiling (repro.obs.profile): resolved once per run —
        # None (the default) keeps every hook below a dead branch, so a
        # disabled profile adds zero work beyond this contextvar read
        # (lazy import: repro.obs pulls in repro.core at package level)
        from ..obs.profile import current_profile
        prof = current_profile()
        n_levels = len(self.plan) if max_levels is None else max_levels
        lv_rows = self.stats["level_rows"]
        lv_wall = self.stats["level_wall_s"]
        lv_paths = self.stats["level_paths"]
        if frontier is None:
            t0 = time.perf_counter()
            frontier = self._domain_values(self.plan[0])[:, None]
            lv_rows[0] = int(frontier.shape[0])
            lv_wall[0] = round(time.perf_counter() - t0, 6)
        frontier = np.asarray(frontier, dtype=np.int32)
        if mult is None:
            mult = np.ones(frontier.shape[0], dtype=np.int64)
        start = frontier.shape[1] if start_level is None else start_level
        cb = self.join_plan.level_callback

        def boundary(level, frontier, mult):
            if cb is None or level >= n_levels - 1:
                return frontier, mult
            upd = cb(level, frontier, mult)
            if upd is None:
                return frontier, mult
            return (np.asarray(upd[0], dtype=np.int32),
                    np.asarray(upd[1], dtype=np.int64))

        total = 0
        for level in range(start, n_levels):
            t_lv = time.perf_counter()
            lp = self.plan[level]
            bitmaps = tuple(gdb.dev(f"bitmap:{u}") for u in lp.unary)
            last = level == n_levels - 1
            last_count = last and count_only
            self.stats["rows_expanded"] += int(frontier.shape[0])
            if not lp.edge_sources:
                frontier, mult, add = self._expand_dense(
                    frontier, mult, lp, last_count)
                total += add
                if last_count:
                    lv_rows[level] = int(total)
                    lv_wall[level] = (lv_wall.get(level, 0.0)
                                      + round(time.perf_counter() - t_lv, 6))
                    return total
                lv_rows[level] = int(frontier.shape[0])
                lv_wall[level] = (lv_wall.get(level, 0.0)
                                  + round(time.perf_counter() - t_lv, 6))
                if prof is not None:
                    prof.sample_memory()
                frontier, mult = boundary(level, frontier, mult)
                continue
            C = frontier.shape[0]
            if C == 0:
                lv_rows[level] = 0
                break
            groups = self._bucket(frontier, mult, lp,
                                  layout=self.level_layouts[level])
            paths = lv_paths.setdefault(level, {})
            for gfrontier, _, mode in groups:
                paths[mode] = paths.get(mode, 0) + int(gfrontier.shape[0])
            new_rows, new_vals, new_mult = [], [], []
            for gfrontier, gmult, mode in groups:
                for s in range(0, gfrontier.shape[0], self.chunk_rows):
                    e = min(gfrontier.shape[0], s + self.chunk_rows)
                    # pad a partial chunk only to the next power of two:
                    # kernel cost tracks live rows (a 100-row tail no
                    # longer dispatches a full chunk_rows kernel) while
                    # the jit cache stays bounded at log2(chunk_rows)
                    # shapes per static-arg combo
                    crows = min(self.chunk_rows,
                                max(8, 1 << (e - s - 1).bit_length()))
                    pad = crows - (e - s)
                    fchunk = np.pad(gfrontier[s:e], ((0, pad), (0, 0)))
                    mchunk = np.pad(gmult[s:e], (0, pad))
                    rv = np.zeros(crows, dtype=bool)
                    rv[: e - s] = True
                    args = (indptr, indices, bitmaps, jnp.asarray(fchunk),
                            jnp.asarray(mchunk), jnp.asarray(rv))
                    kw = dict(probe_cols=lp.edge_sources,
                              n_unary=len(bitmaps), lower_cols=lp.lower,
                              upper_cols=lp.upper, width=self.width,
                              n_iter=self.n_iter,
                              needs_degree=lp.needs_degree,
                              check_mode=mode,
                              check_width=(self.tile_width
                                           if mode == "tile" else 0),
                              rotate_checks=self.rotate_checks)
                    if mode == "bsearch2":
                        kw.update(
                            n_iter=self.n_iter1, n_iter2=self.n_iter2,
                            summary=self.gdb.dev(
                                f"summary:{self.summary_stride}"),
                            summary_stride=self.summary_stride)
                    elif mode == "bitset":
                        kw.update(rep_tag=self.gdb.dev("rep_tag"),
                                  bitset_words=self.gdb.dev("bitset_words"))
                    self.stats["chunks"] += 1
                    self.stats["candidates"] += crows * self.width
                    # kernel-wall breakdown: bracket the dispatch (and
                    # the host conversion that blocks on it) with two
                    # clock reads — no extra device work either way
                    t_k = 0.0 if prof is None else time.perf_counter()
                    if last_count:
                        total += int(np.asarray(_expand_level(
                            *args, count_only=True, **kw)).sum())
                    else:
                        cand, keep = (np.asarray(x) for x in _expand_level(
                            *args, count_only=False, **kw))
                        rows, cols = np.nonzero(keep)
                        new_rows.append(fchunk[rows])
                        new_vals.append(cand[rows, cols])
                        new_mult.append(mchunk[rows])
                    if prof is not None:
                        prof.record_jit_call()
                        prof.record_kernel(
                            "intersect_bitset" if mode == "bitset"
                            else "intersect",
                            time.perf_counter() - t_k)
            if last_count:
                lv_rows[level] = int(total)
                lv_wall[level] = (lv_wall.get(level, 0.0)
                                  + round(time.perf_counter() - t_lv, 6))
                if prof is not None:
                    prof.sample_memory()
                return total
            frontier = np.concatenate(
                [np.concatenate(new_rows, 0) if new_rows else
                 np.zeros((0, frontier.shape[1]), np.int32),
                 (np.concatenate(new_vals)[:, None].astype(np.int32)
                  if new_vals else np.zeros((0, 1), np.int32))], axis=1)
            mult = (np.concatenate(new_mult) if new_mult
                    else np.zeros(0, np.int64))
            # record before the boundary callback: a budget callback may
            # raise (preemption) and the observation must survive it
            lv_rows[level] = int(frontier.shape[0])
            lv_wall[level] = (lv_wall.get(level, 0.0)
                              + round(time.perf_counter() - t_lv, 6))
            if prof is not None:
                # memory watermark at the level boundary — the engine's
                # host-visible synchronization point, where the frontier
                # for the next level is fully materialized
                prof.sample_memory()
            frontier, mult = boundary(level, frontier, mult)
            self.stats["frontier_peak"] = max(self.stats["frontier_peak"],
                                              frontier.shape[0])
        if count_only:
            return int(mult.sum())
        return frontier

    # -- enumeration support -------------------------------------------------
    def last_level_counts(self, frontier: np.ndarray,
                          row_valid: np.ndarray | None = None) -> np.ndarray:
        """Surviving final-level extension *counts* per penultimate-
        frontier row (unit multiplicity) — the cheap pass the adaptive
        cursor uses to size expansion chunks by actual fanout instead of
        the worst-case tile width.  Same constraint semantics as
        :meth:`last_level_extensions`; shares its AOT-compile cache."""
        lp = self.plan[-1]
        frontier = np.asarray(frontier, dtype=np.int32)
        C = frontier.shape[0]
        if row_valid is None:
            row_valid = np.ones(C, dtype=bool)
        if C == 0:
            return np.zeros(0, dtype=np.int64)
        if not lp.edge_sources:
            counts, _ = self.last_level_extensions(frontier, row_valid)
            return counts
        out = self._final_level_call(frontier, row_valid, count_only=True)
        return np.asarray(out, dtype=np.int64)

    def last_level_extensions(self, frontier: np.ndarray,
                              row_valid: np.ndarray | None = None
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Surviving final-level extensions for one penultimate-frontier
        chunk: ``(counts (C,), values (counts.sum(),))`` with each row's
        values ascending (CSR adjacencies are sorted).  Membership checks
        use the binary-search path — the degree-bucketing of
        ``check_mode='auto'`` reorders rows, which would break the
        row-aligned counts the cursor pages by."""
        lp = self.plan[-1]
        frontier = np.asarray(frontier, dtype=np.int32)
        C = frontier.shape[0]
        if row_valid is None:
            row_valid = np.ones(C, dtype=bool)
        if C == 0:
            return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        if not lp.edge_sources:
            # dense level: per-row cross product with the sorted domain
            values = np.sort(self._domain_values(lp))
            counts = np.zeros(C, dtype=np.int64)
            out: list[np.ndarray] = []
            for r in range(C):
                if not row_valid[r]:
                    continue
                vals = values
                for col in lp.lower:
                    vals = vals[vals > frontier[r, col]]
                for col in lp.upper:
                    vals = vals[vals < frontier[r, col]]
                counts[r] = vals.shape[0]
                out.append(vals)
            flat = (np.concatenate(out) if out
                    else np.zeros(0, dtype=np.int64))
            return counts, flat.astype(np.int64)
        cand, keep = self._final_level_call(frontier, row_valid,
                                            count_only=False)
        counts = keep.sum(axis=1).astype(np.int64)
        return counts, cand[keep].astype(np.int64)

    def _final_level_call(self, frontier: np.ndarray, row_valid: np.ndarray,
                          count_only: bool):
        """Dispatch the final-level kernel for one frontier chunk.

        ``repro.results.ResultCursor`` re-enters this level once per
        page with an identical geometry, so non-``bsearch2`` modes are
        AOT-compiled once per ``(shape, count_only)`` and the compiled
        executable is dispatched directly — no per-page jit cache probe
        (static-arg hashing + aval matching).
        """
        lp = self.plan[-1]
        bitmaps = tuple(self.gdb.dev(f"bitmap:{u}") for u in lp.unary)
        mode = self.check_mode if self.check_mode in ("tile", "bsearch2") \
            else "bsearch"
        kw = dict(probe_cols=lp.edge_sources, n_unary=len(bitmaps),
                  lower_cols=lp.lower, upper_cols=lp.upper,
                  width=self.width, n_iter=self.n_iter,
                  needs_degree=lp.needs_degree, count_only=count_only,
                  check_mode=mode,
                  check_width=self.tile_width if mode == "tile" else 0,
                  rotate_checks=self.rotate_checks)
        if mode == "bsearch2":
            kw.update(n_iter=self.n_iter1, n_iter2=self.n_iter2,
                      summary=self.gdb.dev(f"summary:{self.summary_stride}"),
                      summary_stride=self.summary_stride)
        args = (self.gdb.dev("indptr"), self.gdb.dev("indices"), bitmaps,
                jnp.asarray(frontier),
                jnp.ones(frontier.shape[0], dtype=jnp.int64),
                jnp.asarray(row_valid))
        self.stats["ll_calls"] += 1
        from ..obs.profile import current_profile
        prof = current_profile()
        if prof is not None:
            prof.record_jit_call()
            t_k = time.perf_counter()
        if mode == "bsearch2":
            # summary is a traced kwarg, not a static — the AOT signature
            # below would drop it; this mode keeps the jitted dispatch
            out = _expand_level(*args, **kw)
        else:
            key = (frontier.shape, count_only)
            fn = self._ll_compiled.get(key)
            if fn is None:
                self.stats["ll_compiles"] += 1
                t_c = time.perf_counter()
                fn = _expand_level.lower(*args, **kw).compile()
                if prof is not None:
                    prof.record_compile(
                        f"final_level{frontier.shape}"
                        f"/count={count_only}",
                        time.perf_counter() - t_c)
                    t_k = time.perf_counter()   # compile wall kept apart
                self._ll_compiled[key] = fn
            out = fn(*args)
        if count_only:
            out = np.asarray(out)
            if prof is not None:
                prof.record_kernel("intersect", time.perf_counter() - t_k)
            return out
        out = tuple(np.asarray(x) for x in out)
        if prof is not None:
            prof.record_kernel("intersect", time.perf_counter() - t_k)
        return out

    # -- public API ----------------------------------------------------------
    def count(self) -> int:
        return int(self._run(count_only=True))

    def enumerate(self, limit: int | None = None,
                  seeds: np.ndarray | None = None) -> np.ndarray:
        """All output tuples: int64, columns in GAO order
        (``self.output_vars``), rows lexicographically sorted; ``limit``
        truncates *after* the ordering (the shared engine contract —
        ``repro.results``).  ``seeds`` pre-binds the first GAO variable
        (the enumeration analogue of :meth:`seeded_count`)."""
        frontier = None if seeds is None \
            else np.asarray(seeds, dtype=np.int32)[:, None]
        out = self._run(count_only=False, frontier=frontier)
        rows = np.asarray(out, dtype=np.int64)
        k = len(self.plan)
        if rows.shape[0] == 0:
            return np.zeros((0, k), dtype=np.int64)
        rows = rows[np.lexsort(rows.T[::-1])]
        return rows if limit is None else rows[:limit]

    @property
    def output_vars(self) -> tuple[str, ...]:
        """Column order of :meth:`enumerate` (the plan's GAO)."""
        return self.gao

    # -- suspend / resume ----------------------------------------------------
    def advance(self, frontier: np.ndarray | None = None,
                mult: np.ndarray | None = None,
                start_level: int | None = None,
                max_levels: int | None = None) -> np.ndarray:
        """Advance a partial-binding frontier through GAO levels — the
        public suspend/resume hook.

        Args:
            frontier: ``(rows, w)`` int32 partial bindings with ``w``
                GAO columns already bound (``None``: start fresh from
                the level-0 domain).
            mult: ``(rows,)`` int64 multiplicities (``None``: ones).
            start_level: resume level (``None``: inferred as ``w``).
            max_levels: stop after building the frontier of this many
                bound columns (``None``: all levels).

        Returns:
            The ``(rows', max_levels)`` frontier of surviving bindings.

        Raises:
            Whatever the plan's ``level_callback`` raises — the serving
            scheduler's budget callback raises
            :class:`repro.serve.scheduler.Preempted` carrying a
            :class:`repro.serve.scheduler.PlanSnapshot`; feeding that
            snapshot's ``(frontier, mult, start_level)`` back into this
            method continues the join exactly where it stopped.

        Example::

            ex = VLFTJ(query, gdb, plan=plan)
            penult = ex.advance(max_levels=len(ex.plan) - 1)
            counts = ex.last_level_counts(penult.astype(np.int32))
        """
        out = self._run(count_only=False, frontier=frontier, mult=mult,
                        start_level=start_level, max_levels=max_levels)
        return np.asarray(out, dtype=np.int64)

    def resume_count(self, frontier: np.ndarray, mult: np.ndarray,
                     start_level: int | None = None) -> int:
        """Finish a suspended *count* from a snapshot's ``(frontier,
        mult)`` state: the weighted count of all completions of the
        partial bindings.  ``resume_count(snap.frontier, snap.mult)``
        after an uninterrupted prefix equals the uninterrupted
        :meth:`count` — asserted in ``tests/test_scheduler.py``."""
        return int(self._run(
            count_only=True,
            frontier=np.asarray(frontier, dtype=np.int32),
            mult=np.asarray(mult, dtype=np.int64),
            start_level=start_level))

    def seeded_count(self, seed_values: np.ndarray,
                     seed_mult: np.ndarray) -> int:
        """Count with the first GAO variable pre-bound and weighted (the
        hybrid engine seeds the clique part with path-part counts)."""
        return int(self._run(
            count_only=True,
            frontier=np.asarray(seed_values, dtype=np.int32)[:, None],
            mult=np.asarray(seed_mult, dtype=np.int64)))


def vlftj_count(query: Query, gdb: GraphDB,
                gao: tuple[str, ...] | None = None, **kw) -> int:
    return VLFTJ(query, gdb, gao, **kw).count()
