"""Faithful LeapFrog TrieJoin (Algorithm 1 + [Veldhuizen'14] iterators).

This is the paper-faithful reference: variables are bound one at a time in
GAO order; at each level the participating relations' candidate value lists
are intersected by *leapfrogging* — round-robin ``seek_lub`` jumps that skip
large swaths of tuples that cannot produce output.  Runtime is
``Õ(N + AGM(Q))`` [Veldhuizen'14].

Scalar and host-only: this is the correctness oracle and the baseline the
vectorized TPU engine (``core/vlftj.py``) is validated against.
"""
from __future__ import annotations

import numpy as np

from .gao import choose_gao
from .plan import JoinPlan
from .query import Query
from .relation import Database, Relation, POS_INF


class _TrieIter:
    """Leapfrog trie iterator over one GAO-consistent sorted-array index."""

    def __init__(self, rel: Relation):
        self.rel = rel
        # stack of [lo, hi) ranges; level = len(stack) - 1 is current column
        self.ranges: list[tuple[int, int]] = [rel.root_range()]

    @property
    def level(self) -> int:
        return len(self.ranges) - 1

    def open_(self, value: int) -> bool:
        lo, hi = self.ranges[-1]
        lo2, hi2 = self.rel.child_range(lo, hi, self.level, value)
        if lo2 >= hi2:
            return False
        self.ranges.append((lo2, hi2))
        return True

    def up(self) -> None:
        self.ranges.pop()

    def seek_lub(self, value: int) -> int:
        """Smallest indexed value >= ``value`` at the current level
        (``POS_INF`` if exhausted)."""
        lo, hi = self.ranges[-1]
        pos = self.rel.seek_lub(lo, hi, self.level, value)
        if pos >= hi:
            return POS_INF
        return int(self.rel.data[pos, self.level])


class LFTJ:
    """Paper-faithful LeapFrog TrieJoin over a :class:`Database`."""

    def __init__(self, query: Query, db: Database,
                 gao: tuple[str, ...] | None = None,
                 plan: JoinPlan | None = None):
        self.query = query
        self.db = db
        self.join_plan = plan
        if gao is None:
            gao = plan.gao if plan is not None else choose_gao(query)
        self.gao = tuple(gao)
        self.var_pos = {v: i for i, v in enumerate(self.gao)}
        # GAO-consistent index per atom: columns sorted by GAO position.
        self.atom_perm = []
        self.atom_gao_levels = []  # GAO position of each index column
        for a in query.atoms:
            perm = tuple(sorted(range(a.arity),
                                key=lambda i: self.var_pos[a.vars[i]]))
            self.atom_perm.append(perm)
            self.atom_gao_levels.append(
                tuple(self.var_pos[a.vars[i]] for i in perm))
        # For each GAO level: (atom_idx, column_level_within_atom)
        self.level_atoms: list[list[tuple[int, int]]] = [
            [] for _ in self.gao]
        for ai, levels in enumerate(self.atom_gao_levels):
            for col, gpos in enumerate(levels):
                self.level_atoms[gpos].append((ai, col))
        # Inequality filters indexed by the *later* GAO variable.
        self.lower_of: list[list[int]] = [[] for _ in self.gao]  # v > t[j]
        self.upper_of: list[list[int]] = [[] for _ in self.gao]  # v < t[j]
        for f in query.filters:
            li, ri = self.var_pos[f.left], self.var_pos[f.right]
            if li < ri:
                self.lower_of[ri].append(li)   # right var bound later
            else:
                self.upper_of[li].append(ri)   # left var bound later
        # unified stats namespace (docs/OBSERVABILITY.md): seeks counts
        # leapfrog seek_lub rounds, rows_expanded the bindings descended
        # into, level_rows the per-GAO-level binding tallies (the "obs"
        # side of Q-error) — plain host integer adds in the recursion.
        self.stats = {"seeks": 0, "rows_expanded": 0,
                      "level_rows": {}}

    # ------------------------------------------------------------------
    def run(self, emit=None) -> int:
        """Count all output tuples; call ``emit(tuple)`` per result if given."""
        iters = [_TrieIter(self.db.indexed(a.rel, self.atom_perm[ai]))
                 for ai, a in enumerate(self.query.atoms)]
        binding = [0] * len(self.gao)
        return self._join(0, iters, binding, emit)

    def _join(self, level: int, iters, binding, emit) -> int:
        if level == len(self.gao):
            if emit is not None:
                emit(tuple(binding))
            return 1
        parts = self.level_atoms[level]
        lv_rows = self.stats["level_rows"]
        lower = 0
        for j in self.lower_of[level]:
            lower = max(lower, binding[j] + 1)
        upper = POS_INF
        for j in self.upper_of[level]:
            upper = min(upper, binding[j])
        count = 0
        # Leapfrog: round-robin seek_lub until all participating iterators
        # agree on a value (the multiway intersection).
        value = lower
        while True:
            agreed = True
            for ai, _col in parts:
                self.stats["seeks"] += 1
                nxt = iters[ai].seek_lub(value)
                if nxt != value:
                    value = nxt
                    agreed = False
                    break
            if value >= upper or value >= POS_INF:
                break
            if not agreed:
                continue
            # all agree on `value`: descend
            opened = []
            ok = True
            for ai, _col in parts:
                if iters[ai].open_(value):
                    opened.append(ai)
                else:  # pragma: no cover - agreed value always opens
                    ok = False
                    break
            if ok:
                binding[level] = value
                self.stats["rows_expanded"] += 1
                lv_rows[level] = lv_rows.get(level, 0) + 1
                count += self._join(level + 1, iters, binding, emit)
            for ai in opened:
                iters[ai].up()
            value += 1
        return count

    def count(self) -> int:
        return self.run()

    def enumerate(self, limit: int | None = None) -> np.ndarray:
        """Output tuples: int64, columns in GAO order
        (``self.output_vars``), rows in lexicographic order.

        ``limit`` truncates *after* the deterministic ordering — the
        shared engine contract (``repro.results``).  The leapfrog visits
        each level's values ascending, so emission order *is* the
        lexicographic order and early termination at ``limit`` rows
        coincides with post-sort truncation (tested in
        ``tests/test_enumerate.py``); it also matches
        ``ResultCursor.take(limit)`` over the vectorized engine."""
        if limit is not None and limit <= 0:
            return np.zeros((0, len(self.gao)), dtype=np.int64)
        out: list[tuple[int, ...]] = []

        def emit(t):
            out.append(t)
            if limit is not None and len(out) >= limit:
                raise _Done

        try:
            self.run(emit)
        except _Done:
            pass
        arr = np.array(out, dtype=np.int64)
        return arr.reshape(-1, len(self.gao))

    @property
    def output_vars(self) -> tuple[str, ...]:
        """Column order of :meth:`enumerate` (the GAO)."""
        return self.gao


class _Done(Exception):
    pass


def lftj_count(query: Query, db: Database,
               gao: tuple[str, ...] | None = None) -> int:
    return LFTJ(query, db, gao).count()
