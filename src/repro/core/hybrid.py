"""Hybrid engine (§4.12): message passing on the acyclic part, vectorized
LFTJ on the cyclic core — the paper's lollipop algorithm.

Decomposition: bridges of the query's variable graph separate the tree
part from the 2-edge-connected cyclic core.  The tree hanging off the
core's attachment variable is folded into a per-node *multiplicity vector*
by counting message passing (≙ Minesweeper Idea 6 caching + Idea 8
tallies); the core is then joined by vectorized LFTJ seeded with those
multiplicities (≙ Idea 7: clique-part gaps only advance the frontier).

Supported shape: one cyclic core, trees hanging off a single attachment
variable (covers the paper's {2,3}-lollipop); anything else falls back to
plain vectorized LFTJ.
"""
from __future__ import annotations

import numpy as np

from .device_graph import GraphDB
from .gao import _cyclic_heuristic_order
from .query import Atom, LessThan, Query
from .vlftj import VLFTJ
from .yannakakis import CountingYannakakis


def _var_edges(query: Query) -> list[tuple[str, str]]:
    out = []
    seen = set()
    for a in query.atoms:
        if a.arity == 2 and a.vars[0] != a.vars[1]:
            key = frozenset(a.vars)
            if key not in seen:
                seen.add(key)
                out.append((a.vars[0], a.vars[1]))
    return out


def _bridges(vertices, edges) -> set[frozenset]:
    """Bridges via DFS low-link (tiny graphs)."""
    adj: dict[str, list[str]] = {v: [] for v in vertices}
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    disc: dict[str, int] = {}
    low: dict[str, int] = {}
    bridges: set[frozenset] = set()
    timer = [0]

    def dfs(u: str, parent: str | None):
        disc[u] = low[u] = timer[0]
        timer[0] += 1
        skipped_parent_edge = False
        for w in adj[u]:
            if w == parent and not skipped_parent_edge:
                skipped_parent_edge = True
                continue
            if w in disc:
                low[u] = min(low[u], disc[w])
            else:
                dfs(w, u)
                low[u] = min(low[u], low[w])
                if low[w] > disc[u]:
                    bridges.add(frozenset((u, w)))

    for v in vertices:
        if v not in disc:
            dfs(v, None)
    return bridges


class HybridDecomposition:
    """Splits a query into (tree subquery -> attachment var, core subquery).

    ``applicable`` is False when the shape is unsupported.
    """

    def __init__(self, query: Query):
        self.query = query
        self.applicable = False
        edges = _var_edges(query)
        if not edges:
            return
        bridges = _bridges(query.variables, edges)
        core_edges = [e for e in edges if frozenset(e) not in bridges]
        if not core_edges or len(core_edges) == len(edges):
            return  # fully acyclic or fully cyclic: no hybrid split
        core_vars = sorted({v for e in core_edges for v in e})
        # attachment vars: core vars incident to a bridge
        attach = sorted({v for e in bridges for v in e if v in core_vars})
        if len(attach) != 1:
            return
        self.attachment = attach[0]
        core_set = set(core_vars)
        tree_vars = [v for v in query.variables
                     if v not in core_set or v == self.attachment]
        tree_set = set(tree_vars)
        # filters must stay within one side
        for f in query.filters:
            inside_core = f.left in core_set and f.right in core_set
            inside_tree = f.left in tree_set and f.right in tree_set
            if not (inside_core or inside_tree):
                return
        tree_atoms = []
        core_atoms = []
        for a in query.atoms:
            if a.arity == 1:
                (tree_atoms if a.vars[0] in tree_set else core_atoms).append(a)
            elif frozenset(a.vars) in bridges:
                tree_atoms.append(a)
            else:
                core_atoms.append(a)
        tree_filters = [f for f in query.filters
                        if f.left in tree_set and f.right in tree_set]
        core_filters = [f for f in query.filters
                        if f.left in core_set and f.right in core_set]
        if tree_filters:
            return  # counting message passing cannot apply < filters
        self.tree_query = Query(tuple(tree_atoms), (),
                                f"{query.name}-tree")
        self.core_query = Query(tuple(core_atoms), tuple(core_filters),
                                f"{query.name}-core")
        self.core_vars = core_vars
        self.applicable = True


class HybridJoin:
    """Tree counts × seeded core LFTJ (the paper's hybrid algorithm)."""

    def __init__(self, query: Query, gdb: GraphDB, **vlftj_kw):
        self.query = query
        self.gdb = gdb
        self.decomp = HybridDecomposition(query)
        self.vlftj_kw = vlftj_kw

    def count(self) -> int:
        d = self.decomp
        if not d.applicable:
            return VLFTJ(self.query, self.gdb, **self.vlftj_kw).count()
        # 1) tree part -> multiplicity vector at the attachment variable
        cy = CountingYannakakis(d.tree_query, self.gdb, root=d.attachment)
        msg = np.asarray(cy.message_to_root(d.attachment))
        if cy._cross_factor != 1:  # disconnected tree pieces: cross factor
            msg = msg * cy._cross_factor
        seeds = np.flatnonzero(msg > 0).astype(np.int32)
        if seeds.size == 0:
            return 0
        # 2) core part: GAO = attachment first, then cyclic heuristic
        rest = _cyclic_heuristic_order(d.core_query)
        gao = (d.attachment,) + tuple(v for v in rest if v != d.attachment)
        engine = VLFTJ(d.core_query, self.gdb, gao=gao, **self.vlftj_kw)
        return engine.seeded_count(seeds, msg[seeds])


def hybrid_count(query: Query, gdb: GraphDB, **kw) -> int:
    return HybridJoin(query, gdb, **kw).count()
