"""Hybrid engine (§4.12): message passing on the acyclic part, vectorized
LFTJ on the cyclic core — the paper's lollipop algorithm.

Decomposition: bridges of the query's variable graph separate the tree
part from the 2-edge-connected cyclic core.  The tree hanging off the
core's attachment variable is folded into a per-node *multiplicity vector*
by counting message passing (≙ Minesweeper Idea 6 caching + Idea 8
tallies); the core is then joined by vectorized LFTJ seeded with those
multiplicities (≙ Idea 7: clique-part gaps only advance the frontier).

The tree/core split itself is a *planning* decision and lives in
``core.planner.decompose_hybrid``; this module only executes
:class:`~repro.core.plan.HybridPlan`.  Supported shape: one cyclic core,
trees hanging off a single attachment variable (covers the paper's
{2,3}-lollipop); anything else falls back to plain vectorized LFTJ.
"""
from __future__ import annotations

import numpy as np

from .device_graph import GraphDB
from .plan import GraphStats, HybridPlan, JoinPlan
from .query import Query
from .vlftj import VLFTJ
from .yannakakis import CountingYannakakis


class HybridDecomposition:
    """Back-compat view over :func:`repro.core.planner.decompose_hybrid`.

    ``applicable`` is False when the shape is unsupported.
    """

    def __init__(self, query: Query,
                 plan: HybridPlan | None = None):
        self.query = query
        if plan is None:
            from .planner import decompose_hybrid
            plan = decompose_hybrid(query)
        self.plan = plan
        self.applicable = plan is not None
        if plan is not None:
            self.tree_query = plan.tree_query
            self.core_query = plan.core_query
            self.attachment = plan.attachment
            self.core_vars = sorted(
                {v for a in plan.core_query.atoms for v in a.vars})


class HybridJoin:
    """Tree counts × seeded core LFTJ (the paper's hybrid algorithm)."""

    def __init__(self, query: Query, gdb: GraphDB,
                 plan: JoinPlan | None = None, **vlftj_kw):
        if plan is None:
            from .planner import plan_query
            plan = plan_query(query, GraphStats.of(gdb), engine="hybrid")
        self.query = query
        self.gdb = gdb
        self.join_plan = plan
        self.decomp = HybridDecomposition(query, plan=plan.decomposition)
        self.vlftj_kw = vlftj_kw
        # precompile the core (or fallback) executor plan so repeated
        # executions of a cached hybrid plan never re-enter the planner
        d = plan.decomposition
        if d is not None:
            # the hybrid plan's gao IS the core gao, so its per-level
            # layout choices carry over to the core executor plan
            self._core_plan = JoinPlan(query=d.core_query, engine="vlftj",
                                       gao=d.core_gao,
                                       level_layouts=plan.level_layouts)
        elif plan.gao:
            self._core_plan = JoinPlan(query=query, engine="vlftj",
                                       gao=plan.gao,
                                       level_layouts=plan.level_layouts)
        else:
            self._core_plan = None
        # unified stats namespace (docs/OBSERVABILITY.md): the tree
        # pass's SpMV count plus the core executor's per-level stats,
        # merged after count() runs.  rows_expanded / level_rows source
        # the schema (ENGINE_STATS_SOURCE_KEYS) from construction on —
        # the tree pass contributes its SpMV row work, the core its
        # per-level frontiers.
        self.stats: dict = {"spmvs": 0, "rows_expanded": 0,
                            "level_rows": {}}

    def _absorb_core_stats(self, engine: VLFTJ) -> None:
        tree_rows = self.stats.get("rows_expanded", 0)
        self.stats.update(engine.stats)
        self.stats["rows_expanded"] = (
            tree_rows + engine.stats.get("rows_expanded", 0))

    def count(self) -> int:
        d = self.join_plan.decomposition
        if d is None:
            if self._core_plan is not None:
                engine = VLFTJ(self.query, self.gdb, plan=self._core_plan,
                               **self.vlftj_kw)
            else:
                engine = VLFTJ(self.query, self.gdb, **self.vlftj_kw)
            out = engine.count()
            self._absorb_core_stats(engine)
            return out
        # 1) tree part -> multiplicity vector at the attachment variable
        cy = CountingYannakakis(d.tree_query, self.gdb, root=d.attachment)
        msg = np.asarray(cy.message_to_root(d.attachment))
        if cy._cross_factor != 1:  # disconnected tree pieces: cross factor
            msg = msg * cy._cross_factor
        self.stats["spmvs"] = cy.stats.get("spmvs", 0)
        self.stats["rows_expanded"] = cy.stats.get("rows_expanded", 0)
        seeds = np.flatnonzero(msg > 0).astype(np.int32)
        if seeds.size == 0:
            return 0
        # 2) core part: GAO = attachment first, then cyclic heuristic
        engine = VLFTJ(d.core_query, self.gdb, plan=self._core_plan,
                       **self.vlftj_kw)
        out = engine.seeded_count(seeds, msg[seeds])
        self._absorb_core_stats(engine)
        return out

    def enumerate(self, limit: int | None = None) -> np.ndarray:
        """Full-binding enumeration: int64 tuples, columns in
        ``self.output_vars`` (core GAO first, then tree variables), rows
        lex-sorted; ``limit`` truncates after the ordering.  The tree
        part is expanded *backward* behind each core attachment value —
        see ``repro.results.backward.hybrid_rows``."""
        from ..results.backward import hybrid_rows
        rows, _ = hybrid_rows(self)
        if rows.shape[0] > 1:
            rows = rows[np.lexsort(rows.T[::-1])]
        return rows if limit is None else rows[:limit]

    @property
    def output_vars(self) -> tuple[str, ...]:
        """Column order of :meth:`enumerate`."""
        d = self.join_plan.decomposition
        if d is None:
            return (self._core_plan.gao if self._core_plan is not None
                    else tuple(self.query.variables))
        return d.core_gao + tuple(v for v in d.tree_query.variables
                                  if v != d.attachment)


def hybrid_count(query: Query, gdb: GraphDB, **kw) -> int:
    return HybridJoin(query, gdb, **kw).count()
