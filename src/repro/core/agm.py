"""AGM bound (Appendix A): optimal fractional edge cover via LP.

``AGM(Q) = min Π_F |R_F|^{x_F}`` over fractional edge covers ``x`` — i.e.
minimize ``Σ_F log2|R_F|·x_F`` subject to ``Σ_{F ∋ v} x_F ≥ 1`` for every
variable ``v`` and ``x ≥ 0``.  Worst-case optimal joins run in
``Õ(N + AGM(Q))``.
"""
from __future__ import annotations

import math

import numpy as np
from scipy.optimize import linprog

from .query import Query


def fractional_edge_cover(q: Query, sizes: dict[str, int]
                          ) -> tuple[np.ndarray, float]:
    """Return (x, log2 AGM).  ``sizes`` maps relation name -> |R|.

    Each *atom* is its own hyperedge (self-joins contribute separately, with
    the same base-relation size).
    """
    variables = q.variables
    atoms = q.atoms
    n, m = len(variables), len(atoms)
    if any(sizes[a.rel] <= 0 for a in atoms):
        # an empty relation annihilates the join
        return np.zeros(m), float("-inf")
    c = np.array(
        [math.log2(sizes[a.rel]) for a in atoms], dtype=np.float64
    )
    # A_ub @ x <= b_ub encodes -(Σ_{F∋v} x_F) <= -1
    A = np.zeros((n, m))
    for j, a in enumerate(atoms):
        for i, v in enumerate(variables):
            if v in a.vars:
                A[i, j] = -1.0
    b = -np.ones(n)
    res = linprog(c, A_ub=A, b_ub=b, bounds=[(0, None)] * m, method="highs")
    if not res.success:  # pragma: no cover - LP on a cover polytope is feasible
        raise RuntimeError(f"AGM LP failed: {res.message}")
    return res.x, float(res.fun)


def agm_bound(q: Query, sizes: dict[str, int]) -> float:
    """The AGM bound in number of tuples (may be large; returns float)."""
    _, log2_bound = fractional_edge_cover(q, sizes)
    return 2.0 ** log2_bound
