from .agm import agm_bound, fractional_edge_cover
from .binary_join import BinaryJoin, JoinBlowup, binary_join_count
from .device_graph import GraphDB, HybridGraphDB
from .engine import (ENGINES, count, execute, execute_stats,
                     make_engine, pick_engine)
from .gao import choose_gao
from .hybrid import HybridJoin, hybrid_count
from .hypergraph import Hypergraph, all_neos, is_beta_acyclic, is_neo
from .lftj_ref import LFTJ, lftj_count
from .minesweeper_ref import Minesweeper, minesweeper_count
from .plan import (GraphStats, HybridPlan, JoinPlan, LevelPlan,
                   compile_levels, partition_first_level, stripe_partition)
from .planner import (PlanCache, candidate_gaos, candidate_plans,
                      choose_level_layouts, decompose_hybrid,
                      estimate_vlftj_cost, plan_query)
from .query import (Atom, LessThan, PAPER_QUERIES, Query, clique, comb,
                    cycle, get_query, lollipop, parse, path, tree)
from .relation import Database, Relation
from .vlftj import VLFTJ, vlftj_count
from .yannakakis import CountingYannakakis, yannakakis_count

__all__ = [
    "agm_bound", "fractional_edge_cover", "BinaryJoin", "JoinBlowup",
    "binary_join_count", "GraphDB", "HybridGraphDB", "ENGINES", "count",
    "execute", "execute_stats", "make_engine",
    "pick_engine", "choose_gao", "HybridJoin", "hybrid_count",
    "Hypergraph", "all_neos", "is_beta_acyclic", "is_neo", "LFTJ",
    "lftj_count", "Minesweeper", "minesweeper_count", "GraphStats",
    "HybridPlan", "JoinPlan", "LevelPlan", "compile_levels",
    "partition_first_level", "stripe_partition", "PlanCache",
    "candidate_gaos", "candidate_plans", "choose_level_layouts",
    "decompose_hybrid", "estimate_vlftj_cost", "plan_query", "Atom",
    "LessThan",
    "PAPER_QUERIES", "Query", "clique", "comb", "cycle", "get_query",
    "lollipop", "parse", "path", "tree", "Database", "Relation", "VLFTJ",
    "vlftj_count", "CountingYannakakis", "yannakakis_count",
]
