"""repro: worst-case-optimal join processing for graph patterns on TPU.

x64 is enabled package-wide: join counts are exact int64 on device (the
paper's benchmark outputs overflow int32 at Pokec/LiveJournal scale).
Model code uses explicit bf16/f32 dtypes throughout, so the x64 default
only affects the integer join/count paths.  Opt out with ``REPRO_X64=0``.
"""
import os as _os

import jax as _jax

if _os.environ.get("REPRO_X64", "1") == "1":
    _jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
