"""repro: worst-case-optimal join processing for graph patterns on TPU.

x64 is enabled package-wide: join counts are exact int64 on device (the
paper's benchmark outputs overflow int32 at Pokec/LiveJournal scale).
Model code uses explicit bf16/f32 dtypes throughout, so the x64 default
only affects the integer join/count paths.  Opt out with ``REPRO_X64=0``.
"""
import os as _os

import jax as _jax

if _os.environ.get("REPRO_X64", "1") == "1":
    _jax.config.update("jax_enable_x64", True)

if not hasattr(_jax, "shard_map"):
    # jax >= 0.6 promotes shard_map to the top-level namespace and renames
    # check_rep -> check_vma; older jax only has the experimental spelling.
    # repro.dist and the multi-device tests target the new API, so bridge
    # it here (importing any repro subpackage runs this first).
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                   **kwargs):
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma,
                                 **kwargs)

    _jax.shard_map = _shard_map

__version__ = "1.0.0"
