"""Deterministic data pipelines.

Fault-tolerance contract: a batch is a pure function of (seed, step,
shard), so a restarted/resharded worker regenerates exactly the batches it
owes — no data-loader state in checkpoints beyond the step counter.
File-backed mode memory-maps a token binary and slices it by the same
(step, shard) arithmetic.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def lm_synthetic_batch(step: int, batch: int, seq: int, vocab: int,
                       seed: int = 0, shard: int = 0, n_shards: int = 1):
    """Deterministic (tokens, labels) for (step, shard)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))
    b_local = batch // n_shards
    toks = rng.integers(0, vocab, (b_local, seq + 1), dtype=np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class LMTokenPipeline:
    batch: int
    seq: int
    vocab: int
    seed: int = 0
    token_file: str | None = None   # optional binary int32 token stream

    def __post_init__(self):
        self._mm = (np.memmap(self.token_file, dtype=np.int32, mode="r")
                    if self.token_file else None)

    def get_batch(self, step: int, shard: int = 0, n_shards: int = 1):
        if self._mm is None:
            return lm_synthetic_batch(step, self.batch, self.seq,
                                      self.vocab, self.seed, shard, n_shards)
        b_local = self.batch // n_shards
        span = b_local * (self.seq + 1)
        start = (step * n_shards + shard) * span % max(
            1, self._mm.shape[0] - span)
        chunk = np.asarray(self._mm[start:start + span]).reshape(
            b_local, self.seq + 1)
        return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}


def recsys_synthetic_batch(step: int, batch: int, n_sparse: int,
                           vocab_per_field: int, seed: int = 0,
                           shard: int = 0, n_shards: int = 1):
    """Zipf-ish categorical ids + click labels, deterministic per step."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, shard]))
    b_local = batch // n_shards
    u = rng.random((b_local, n_sparse))
    ids = np.minimum((vocab_per_field * u ** 3).astype(np.int64),
                     vocab_per_field - 1)
    labels = (rng.random(b_local) < 0.25).astype(np.int32)
    return {"ids": ids.astype(np.int32), "labels": labels}


@dataclass
class RecSysPipeline:
    batch: int
    n_sparse: int
    vocab_per_field: int
    seed: int = 0

    def get_batch(self, step: int, shard: int = 0, n_shards: int = 1):
        return recsys_synthetic_batch(step, self.batch, self.n_sparse,
                                      self.vocab_per_field, self.seed,
                                      shard, n_shards)
