from .pipeline import (LMTokenPipeline, RecSysPipeline, lm_synthetic_batch,
                       recsys_synthetic_batch)

__all__ = ["LMTokenPipeline", "RecSysPipeline", "lm_synthetic_batch",
           "recsys_synthetic_batch"]
